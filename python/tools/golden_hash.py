#!/usr/bin/env python3
"""Compute the blessed decoded-weight hash for the golden-format suite.

`rust/tests/golden_format.rs::decoded_weight_hash_matches_blessed_value`
pins the FNV-1a hash of the weights decoded from the committed
`tests/data/tiny_v2.mrc` fixture. The hash cannot be authored by hand —
the candidate normals go through platform libm — so this script is a
bit-exact port of the native decode path (Pcg64 seed tree -> Box-Muller
-> sigma_p scaling), calling the *same* libm symbols the Rust build links
(`log`, `sin`, `cos` for the f64 Box-Muller, `expf` for the f32 sigma
scale) via ctypes. Run it on the platform family CI uses and commit the
output to `rust/tests/data/tiny_weights.fnv1a`.

Port of: rust/src/prng/mod.rs (SplitMix64, mix64, Pcg64, candidate_stream,
skip_normals, fill_normals_f32), rust/src/model/mod.rs (Layout layer_map),
rust/src/runtime/native.rs (decode_block), rust/src/coordinator/encoder.rs
(decode_model) — over the fixture parameters of golden_format.rs.
"""

import ctypes
import ctypes.util
import math
import struct

MASK64 = (1 << 64) - 1

_libm = ctypes.CDLL(ctypes.util.find_library("m"))
_libm.log.restype, _libm.log.argtypes = ctypes.c_double, [ctypes.c_double]
_libm.sin.restype, _libm.sin.argtypes = ctypes.c_double, [ctypes.c_double]
_libm.cos.restype, _libm.cos.argtypes = ctypes.c_double, [ctypes.c_double]
_libm.expf.restype, _libm.expf.argtypes = ctypes.c_float, [ctypes.c_float]

F64_MIN_POSITIVE = 2.2250738585072014e-308
PI = 3.141592653589793  # std::f64::consts::PI


def f32(x):
    """Round a Python float to f32 precision (Rust `as f32`)."""
    return struct.unpack("f", struct.pack("f", x))[0]


def f32_bits(x):
    return struct.unpack("I", struct.pack("f", x))[0]


def mix64(z):
    z = (z + 0x9E3779B97F4A7C15) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


class SplitMix64:
    def __init__(self, s):
        self.state = s & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)


class Pcg64:
    """PCG-XSH-RR 64/32, bit-identical to rust/src/prng/mod.rs."""

    def __init__(self, state, inc):
        self.state = state
        self.inc = inc
        self.spare = None

    @classmethod
    def seed(cls, s):
        sm = SplitMix64(s)
        p = cls(sm.next_u64(), sm.next_u64() | 1)
        p.next_u32()
        return p

    def fold_in(self, tag):
        return Pcg64.seed(mix64(self.state ^ mix64((tag ^ self.inc) & MASK64)))

    def next_u32(self):
        old = self.state
        self.state = (old * 6364136223846793005 + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << (32 - rot) & 0xFFFFFFFF)) & 0xFFFFFFFF \
            if rot else xorshifted

    def next_u64(self):
        return (self.next_u32() << 32) | self.next_u32()

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        zone = MASK64 - (MASK64 % n)
        while True:
            v = self.next_u64()
            if v < zone:
                return v % n

    def box_muller_pair(self):
        while True:
            u1 = self.next_f64()
            if u1 <= F64_MIN_POSITIVE:
                continue
            u2 = self.next_f64()
            # math.sqrt is the IEEE-exact sqrt instruction, like Rust's
            r = math.sqrt(-2.0 * _libm.log(u1))
            a = 2.0 * PI * u2
            return r * _libm.cos(a), r * _libm.sin(a)

    def next_normal(self):
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        a, b = self.box_muller_pair()
        self.spare = b
        return a

    def fill_normals_f32(self, n):
        out = []
        if n and self.spare is not None:
            out.append(f32(self.spare))
            self.spare = None
        while len(out) + 2 <= n:
            a, b = self.box_muller_pair()
            out.append(f32(a))
            out.append(f32(b))
        if len(out) < n:
            a, b = self.box_muller_pair()
            out.append(f32(a))
            self.spare = b
        return out

    def skip_normals(self, n):
        if n > 0 and self.spare is not None:
            self.spare = None
            n -= 1
        while n >= 2:
            u1 = self.next_f64()
            if u1 <= F64_MIN_POSITIVE:
                continue
            self.next_f64()
            n -= 2
        if n == 1:
            self.next_normal()

    def permutation(self, n):
        v = list(range(n))
        for i in range(n - 1, 0, -1):
            j = self.below(i + 1)
            v[i], v[j] = v[j], v[i]
        return v


TAG_PROTOCOL = 0x4D52_4331_5052_4F54  # "MRC1PROT"


def candidate_stream(protocol_seed, block, chunk):
    return (
        Pcg64.seed(mix64((protocol_seed & 0xFFFFFFFF) ^ TAG_PROTOCOL))
        .fold_in(block & 0xFFFFFFFF)
        .fold_in(chunk & 0xFFFFFFFF)
    )


def tiny_mlp_layer_map(layout_seed):
    """layer_map of Layout::generate for tiny_mlp (dense: 136 + 36 slots,
    22 blocks x 8, 4 padding slots mapped to layer 0)."""
    b, s = 22, 8
    n_pad = b * s
    layer_slots = [136, 36]  # 16x8+8, 8x4+4
    n_slots = sum(layer_slots)
    slot_layer = [0] * n_pad
    base = 0
    for l, m in enumerate(layer_slots):
        for i in range(m):
            slot_layer[base + i] = l
        base += m
    perm = Pcg64.seed(layout_seed ^ 0xB10C5EED).permutation(n_pad)
    layer_map = [0] * n_pad
    for slot, bpos in enumerate(perm):
        if slot < n_slots:
            layer_map[bpos] = slot_layer[slot]
    return layer_map


def decode_tiny_v2():
    """decode_model over the golden_format.rs fixture parameters."""
    b_total, s, k_chunk = 22, 8, 64
    layout_seed, protocol_seed = 0x4D31_7261, 7
    lsp = [f32(-1.5), f32(-2.25)]
    indices = [(i * 37 + 11) % 1024 for i in range(b_total)]
    layer_map = tiny_mlp_layer_map(layout_seed)
    exp_lsp = [_libm.expf(v) for v in lsp]
    w = []
    for b in range(b_total):
        chunk, row = indices[b] // k_chunk, indices[b] % k_chunk
        rng = candidate_stream(protocol_seed, b, chunk)
        rng.skip_normals(row * s)
        out = rng.fill_normals_f32(s)
        for j in range(s):
            scale = exp_lsp[layer_map[b * s + j]]
            # product of two f32s is exact in double; one rounding to f32
            w.append(f32(out[j] * scale))
    return w


def fnv1a(ws):
    h = 0xCBF29CE484222325
    for v in ws:
        for byte in struct.pack("<I", f32_bits(v)):
            h = ((h ^ byte) * 0x00000100000001B3) & MASK64
    return h


if __name__ == "__main__":
    w = decode_tiny_v2()
    assert len(w) == 176
    print(f"{fnv1a(w):016x}")
