"""L2 graph tests: train_step learns, freezing works, encode/decode agree."""

import jax
import numpy as np
import pytest

from compile.configs import load_config
from compile.model import (
    entry_points,
    make_decode_chunk,
    make_eval_batch,
    make_sample_weights,
    make_score_chunk,
    make_train_step,
)
from compile.kernels.ref import importance_logits_ref
from .conftest import config_path

CFG = load_config(config_path("tiny_mlp"))


def _runtime_maps(cfg, rng):
    """Mimic the rust-side map generation: identity hash, random permutation."""
    n_pad = cfg.B * cfg.S
    perm = rng.permutation(n_pad).astype(np.int32)  # slot -> block position
    # identity hash for the test: flat position i -> slot i (slot layout is
    # layers concatenated, truncated per-layer to layer_slots)
    pos_to_slot = np.zeros(cfg.n_total, dtype=np.int32)
    slot_layer = np.zeros(n_pad, dtype=np.int32)
    slot_base = 0
    off = 0
    for li, (spec, m) in enumerate(zip(cfg.layers, cfg.layer_slots)):
        idx = np.arange(spec.count)
        pos_to_slot[off:off + spec.count] = slot_base + (idx % m)
        off += spec.count
        slot_base += m
    assemble_map = perm[pos_to_slot]  # flat position -> block-layout index
    inv = np.empty(n_pad, dtype=np.int64)
    inv[perm] = np.arange(n_pad)
    # layer of each slot
    slot_id = 0
    for li, m in enumerate(cfg.layer_slots):
        slot_layer[slot_id:slot_id + m] = li
        slot_id += m
    layer_map = np.zeros(n_pad, dtype=np.int32)
    layer_map[perm] = slot_layer  # block position -> layer id
    slot_mask = np.zeros(n_pad, dtype=np.float32)
    real = np.zeros(n_pad, dtype=np.float32)
    real[:cfg.n_slots] = 1.0
    slot_mask[perm] = real
    return (assemble_map,
            layer_map.reshape(cfg.B, cfg.S),
            slot_mask.reshape(cfg.B, cfg.S))


def _init_state(cfg, rng):
    bs = (cfg.B, cfg.S)
    mu = (rng.normal(size=bs) * 0.1).astype(np.float32)
    rho = np.full(bs, -3.0, dtype=np.float32)
    lsp = np.full(cfg.n_layers, -1.0, dtype=np.float32)
    zeros = lambda s: np.zeros(s, dtype=np.float32)
    return dict(
        mu=mu, rho=rho, lsp=lsp,
        m_mu=zeros(bs), v_mu=zeros(bs), m_rho=zeros(bs), v_rho=zeros(bs),
        m_lsp=zeros(cfg.n_layers), v_lsp=zeros(cfg.n_layers),
    )


def _toy_batch(cfg, rng, n):
    """Linearly separable-ish toy task."""
    x = rng.normal(size=(n, cfg.arch["input_dim"])).astype(np.float32)
    w_true = rng.normal(size=(cfg.arch["input_dim"], cfg.classes))
    y = np.argmax(x @ w_true, axis=1).astype(np.int32)
    return x, y


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(7)
    maps = _runtime_maps(CFG, rng)
    st = _init_state(CFG, rng)
    x, y = _toy_batch(CFG, rng, CFG.batch)
    step_fn = jax.jit(make_train_step(CFG))
    return rng, maps, st, x, y, step_fn


def _run_steps(setup, n_steps, beta_val=1e-6, frozen=None, lr=1e-3):
    rng, maps, st0, x, y, step_fn = setup
    st = {k: v.copy() for k, v in st0.items()}
    assemble_map, layer_map, slot_mask = maps
    beta = np.full(CFG.B, beta_val, dtype=np.float32)
    if frozen is None:
        fm = np.zeros(CFG.B, dtype=np.float32)
    else:
        fm = frozen
    fw = np.zeros((CFG.B, CFG.S), dtype=np.float32)
    losses, kls = [], None
    for t in range(1, n_steps + 1):
        out = step_fn(
            st["mu"], st["rho"], st["lsp"],
            st["m_mu"], st["v_mu"], st["m_rho"], st["v_rho"],
            st["m_lsp"], st["v_lsp"], np.int32(t),
            x, y, beta, fm, fw, np.int32(t),
            assemble_map, layer_map, slot_mask,
            np.float32(1.0), np.float32(1.0), np.float32(lr),
        )
        (st["mu"], st["rho"], st["lsp"], st["m_mu"], st["v_mu"],
         st["m_rho"], st["v_rho"], st["m_lsp"], st["v_lsp"],
         loss, ce, acc, kl_b) = out
        losses.append(float(loss))
        kls = np.asarray(kl_b)
    return st, losses, kls, float(acc)


def test_train_step_reduces_loss(setup):
    _, losses, _, acc = _run_steps(setup, 150, lr=1e-2)
    assert losses[-1] < losses[0] * 0.5, losses[::30]
    assert acc > 0.5


def test_frozen_blocks_do_not_move(setup):
    rng, maps, st0, x, y, step_fn = setup
    fm = np.zeros(CFG.B, dtype=np.float32)
    fm[:5] = 1.0
    st, _, kls, _ = _run_steps(setup, 10, frozen=fm)
    np.testing.assert_array_equal(st["mu"][:5], st0["mu"][:5])
    np.testing.assert_array_equal(st["rho"][:5], st0["rho"][:5])
    assert not np.allclose(st["mu"][5:], st0["mu"][5:])


def test_high_beta_crushes_kl(setup):
    _, _, kl_low, _ = _run_steps(setup, 40, beta_val=1e-8)
    _, _, kl_high, _ = _run_steps(setup, 40, beta_val=10.0)
    assert kl_high.mean() < kl_low.mean()


def test_score_decode_consistency(setup):
    """score_chunk logits must equal ref-scoring of decode_chunk candidates —
    the encoder/decoder shared-randomness contract."""
    rng, maps, st, *_ = setup
    _, layer_map, slot_mask = maps
    score = jax.jit(make_score_chunk(CFG))
    decode = jax.jit(make_decode_chunk(CFG))
    b = 3
    lsp_b = st["lsp"][layer_map[b]].astype(np.float32)
    mu_b = st["mu"][b]
    rho_b = st["rho"][b]
    mask_b = slot_mask[b]
    for chunk in (0, 1, 7):
        logits = np.asarray(score(np.int32(99), np.int32(b), np.int32(chunk),
                                  mu_b, rho_b, lsp_b, mask_b)[0])
        cand = np.asarray(decode(np.int32(99), np.int32(b), np.int32(chunk),
                                 lsp_b)[0])
        z = cand / np.exp(lsp_b)[None, :]
        want = np.asarray(importance_logits_ref(z, mu_b, rho_b, lsp_b, mask_b))
        np.testing.assert_allclose(logits, want, rtol=1e-4, atol=1e-4)


def test_decode_chunks_differ_by_block_and_chunk(setup):
    decode = jax.jit(make_decode_chunk(CFG))
    lsp_b = np.zeros(CFG.S, dtype=np.float32)
    a = np.asarray(decode(np.int32(1), np.int32(0), np.int32(0), lsp_b)[0])
    b = np.asarray(decode(np.int32(1), np.int32(1), np.int32(0), lsp_b)[0])
    c = np.asarray(decode(np.int32(1), np.int32(0), np.int32(1), lsp_b)[0])
    d = np.asarray(decode(np.int32(2), np.int32(0), np.int32(0), lsp_b)[0])
    assert not np.allclose(a, b)
    assert not np.allclose(a, c)
    assert not np.allclose(a, d)
    # determinism
    a2 = np.asarray(decode(np.int32(1), np.int32(0), np.int32(0), lsp_b)[0])
    np.testing.assert_array_equal(a, a2)


def test_eval_batch_matches_forward(setup):
    rng, maps, st, x, y, _ = setup
    assemble_map, _, _ = maps
    ev = jax.jit(make_eval_batch(CFG))
    w_blocks = st["mu"]
    xe = np.zeros((CFG.eval_batch,) + CFG.input_shape, dtype=np.float32)
    xe[: x.shape[0]] = x
    logits = np.asarray(ev(w_blocks, assemble_map, xe)[0])
    assert logits.shape == (CFG.eval_batch, CFG.classes)
    assert np.isfinite(logits).all()


def test_sample_weights_respects_freezing(setup):
    rng, maps, st, *_ = setup
    sw = jax.jit(make_sample_weights(CFG))
    fm = np.zeros(CFG.B, dtype=np.float32)
    fm[2] = 1.0
    fw = np.full((CFG.B, CFG.S), 42.0, dtype=np.float32)
    w = np.asarray(sw(st["mu"], st["rho"], fm, fw, np.int32(5))[0])
    np.testing.assert_array_equal(w[2], fw[2])
    assert not np.allclose(w[3], fw[3])
    # seeded determinism
    w2 = np.asarray(sw(st["mu"], st["rho"], fm, fw, np.int32(5))[0])
    np.testing.assert_array_equal(w, w2)
    w3 = np.asarray(sw(st["mu"], st["rho"], fm, fw, np.int32(6))[0])
    assert not np.allclose(w, w3)


def test_entry_points_complete():
    eps = entry_points(CFG)
    assert set(eps) == {"train_step", "score_chunk", "decode_chunk",
                        "eval_batch", "eval_full", "sample_weights"}
