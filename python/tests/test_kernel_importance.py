"""Pallas importance-scoring kernel vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import importance_logits
from compile.kernels.ref import importance_logits_ref

SETTINGS = dict(deadline=None, max_examples=25)


def _mk(rng, k, s):
    z = rng.normal(size=(k, s)).astype(np.float32)
    mu = rng.normal(size=s).astype(np.float32)
    lsq = (rng.normal(size=s) * 0.5 - 1.0).astype(np.float32)
    lsp = (rng.normal(size=s) * 0.5 - 1.0).astype(np.float32)
    mask = (rng.random(s) > 0.25).astype(np.float32)
    return z, mu, lsq, lsp, mask


@given(
    k=st.sampled_from([1, 2, 8, 64, 256, 512]),
    s=st.integers(min_value=1, max_value=33),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**SETTINGS)
def test_matches_ref(k, s, seed):
    rng = np.random.default_rng(seed)
    args = _mk(rng, k, s)
    got = np.asarray(importance_logits(*args))
    want = np.asarray(importance_logits_ref(*args))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_all_masked_gives_zero():
    rng = np.random.default_rng(0)
    z, mu, lsq, lsp, _ = _mk(rng, 16, 7)
    mask = np.zeros(7, dtype=np.float32)
    got = np.asarray(importance_logits(z, mu, lsq, lsp, mask))
    np.testing.assert_allclose(got, np.zeros(16), atol=1e-6)


def test_q_equals_p_gives_zero():
    """If q == p the importance weights are exactly uniform (log a_k = 0
    iff the candidate equals... no: log q/p at w = sigma_p z with mu=0,
    sq=sp gives identically zero)."""
    rng = np.random.default_rng(1)
    s = 9
    z = rng.normal(size=(32, s)).astype(np.float32)
    lsp = (rng.normal(size=s) * 0.3).astype(np.float32)
    mu = np.zeros(s, dtype=np.float32)
    mask = np.ones(s, dtype=np.float32)
    got = np.asarray(importance_logits(z, mu, lsp, lsp, mask))
    np.testing.assert_allclose(got, np.zeros(32), atol=1e-5)


def test_shift_invariance_in_best_candidate():
    """The candidate closest to mu/sigma_p direction should win when
    sigma_q is small: argmax of logits == argmax of -||sigma_p z - mu||^2."""
    rng = np.random.default_rng(2)
    s = 6
    z = rng.normal(size=(128, s)).astype(np.float32)
    mu = rng.normal(size=s).astype(np.float32)
    lsq = np.full(s, -3.0, dtype=np.float32)  # tiny q stddev
    lsp = np.zeros(s, dtype=np.float32)
    mask = np.ones(s, dtype=np.float32)
    logits = np.asarray(importance_logits(z, mu, lsq, lsp, mask))
    dist = np.sum((z - mu[None, :]) ** 2, axis=1)
    assert np.argmax(logits) == np.argmin(dist)
