"""Structural perf model sanity checks (the L1 'profile' substitute —
interpret-mode wallclock is not a TPU proxy, the BlockSpec structure is)."""

from compile.kernels import analysis


def test_default_tiles_fit_vmem():
    for r in analysis.report():
        assert r.vmem_ok, f"{r.name} exceeds VMEM: {r.vmem_bytes}"


def test_importance_is_bandwidth_bound():
    # elementwise + reduce: ~2.25 flops/byte -> far below the VPU ridge
    r = analysis.importance_report(256, 16)
    assert 1.0 < r.intensity < 4.0
    assert r.roofline_flops(analysis.VPU_FLOPS) < analysis.VPU_FLOPS


def test_bigger_tiles_dont_change_intensity_much():
    a = analysis.importance_report(64, 16)
    b = analysis.importance_report(1024, 16)
    assert abs(a.intensity - b.intensity) / a.intensity < 0.05


def test_sample_linear_is_compute_bound_for_big_tiles():
    r = analysis.sample_linear_report(batch=128, d_in=784, o_tile=128)
    # matmul reuse across the batch drives intensity above the MXU ridge
    ridge = analysis.MXU_FLOPS / analysis.HBM_BW
    # batch=128 bounds weight-panel reuse: ~18% of MXU roofline, an order
    # of magnitude above the elementwise kernels
    assert r.intensity > ridge * 0.15
    assert r.efficiency(analysis.MXU_FLOPS) > 0.15
    kl = analysis.kl_report(128, 16)
    assert r.intensity > 10 * kl.intensity


def test_vmem_overflow_detected():
    r = analysis.importance_report(k_tile=2**20, s=64)
    assert not r.vmem_ok


def test_kl_kernel_streams_all_inputs():
    r = analysis.kl_report(128, 16)
    assert r.hbm_bytes_per_step >= 4 * 128 * 16 * 4
