"""Pallas block-KL kernel: forward vs oracle, custom VJP vs autodiff of oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import block_kl
from compile.kernels.ref import block_kl_ref

SETTINGS = dict(deadline=None, max_examples=25)


def _mk(rng, b, s):
    mu = rng.normal(size=(b, s)).astype(np.float32)
    lsq = (rng.normal(size=(b, s)) * 0.5 - 1.0).astype(np.float32)
    lsp = (rng.normal(size=(b, s)) * 0.5 - 1.0).astype(np.float32)
    mask = (rng.random((b, s)) > 0.25).astype(np.float32)
    return mu, lsq, lsp, mask


@given(
    b=st.integers(min_value=1, max_value=140),
    s=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**SETTINGS)
def test_matches_ref(b, s, seed):
    rng = np.random.default_rng(seed)
    args = _mk(rng, b, s)
    np.testing.assert_allclose(
        np.asarray(block_kl(*args)), np.asarray(block_kl_ref(*args)),
        rtol=1e-5, atol=1e-5,
    )


def test_kl_nonnegative_and_zero_iff_equal():
    rng = np.random.default_rng(3)
    mu, lsq, lsp, mask = _mk(rng, 17, 8)
    kl = np.asarray(block_kl(mu, lsq, lsp, mask))
    assert (kl >= -1e-5).all()
    # q == p  ->  KL == 0
    zero = np.asarray(block_kl(np.zeros_like(mu), lsp, lsp, mask))
    np.testing.assert_allclose(zero, 0.0, atol=1e-6)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(**SETTINGS)
def test_grads_match_oracle_autodiff(seed):
    rng = np.random.default_rng(seed)
    mu, lsq, lsp, mask = _mk(rng, 11, 5)
    cot = rng.normal(size=11).astype(np.float32)

    def loss_k(m, q, p):
        return jnp.sum(block_kl(m, q, p, mask) * cot)

    def loss_r(m, q, p):
        return jnp.sum(block_kl_ref(m, q, p, mask) * cot)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(mu, lsq, lsp)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(mu, lsq, lsp)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_scales_linearly_with_duplicated_blocks():
    rng = np.random.default_rng(4)
    mu, lsq, lsp, mask = _mk(rng, 1, 12)
    one = np.asarray(block_kl(mu, lsq, lsp, mask))
    many = np.asarray(block_kl(
        np.repeat(mu, 64, 0), np.repeat(lsq, 64, 0),
        np.repeat(lsp, 64, 0), np.repeat(mask, 64, 0)))
    np.testing.assert_allclose(many, np.full(64, one[0]), rtol=1e-5)
