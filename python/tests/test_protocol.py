"""Protocol-level checks on the coding-path graphs: statistics of the shared
candidate generator, KL consistency between the training graph and the
analytic oracle, and chunk-id independence."""

import jax
import numpy as np
import pytest

from compile.configs import load_config
from compile.model import make_decode_chunk, make_score_chunk, make_train_step
from compile.kernels.ref import block_kl_ref
from .conftest import config_path

CFG = load_config(config_path("tiny_mlp"))


@pytest.fixture(scope="module")
def fns():
    return (
        jax.jit(make_score_chunk(CFG)),
        jax.jit(make_decode_chunk(CFG)),
    )


def test_candidates_follow_encoding_distribution(fns):
    """Decoded candidates are w = sigma_p * z with z ~ N(0,1): their sample
    stddev must match sigma_p per column and mean must be ~0."""
    _, decode = fns
    lsp_b = np.linspace(-2.0, 0.0, CFG.S).astype(np.float32)
    rows = []
    for chunk in range(24):
        c = np.asarray(decode(np.int32(3), np.int32(1), np.int32(chunk), lsp_b)[0])
        rows.append(c)
    cand = np.concatenate(rows, axis=0)  # [24*K_chunk, S]
    std = cand.std(axis=0)
    np.testing.assert_allclose(std, np.exp(lsp_b), rtol=0.08)
    assert np.abs(cand.mean(axis=0)).max() < 0.1


def test_scores_are_chunk_independent_draws(fns):
    """Different chunk ids give different candidate sets; scoring is a pure
    function of (seed, block, chunk, params)."""
    score, _ = fns
    mu = np.zeros(CFG.S, dtype=np.float32)
    rho = np.full(CFG.S, -1.0, dtype=np.float32)
    lsp = np.full(CFG.S, -1.0, dtype=np.float32)
    mask = np.ones(CFG.S, dtype=np.float32)
    a = np.asarray(score(np.int32(1), np.int32(0), np.int32(0), mu, rho, lsp, mask)[0])
    b = np.asarray(score(np.int32(1), np.int32(0), np.int32(1), mu, rho, lsp, mask)[0])
    a2 = np.asarray(score(np.int32(1), np.int32(0), np.int32(0), mu, rho, lsp, mask)[0])
    assert not np.allclose(a, b)
    np.testing.assert_array_equal(a, a2)


def test_train_step_kl_matches_analytic_oracle():
    """The KL vector returned by the lowered train_step (computed by the
    Pallas kernel inside the graph) equals the closed-form KL of the input
    parameters — the quantity the β controller and Table-1 accounting use."""
    rng = np.random.default_rng(0)
    step_fn = jax.jit(make_train_step(CFG))
    B, S, L = CFG.B, CFG.S, CFG.n_layers
    mu = (rng.normal(size=(B, S)) * 0.2).astype(np.float32)
    rho = (rng.normal(size=(B, S)) * 0.3 - 2.0).astype(np.float32)
    lsp = np.array([-1.0, -1.5], dtype=np.float32)[:L]
    zeros = lambda *s: np.zeros(s, dtype=np.float32)
    # identity-ish maps: position i -> slot i (n_total <= B*S), layer split
    n_pad = B * S
    amap = np.arange(CFG.n_total, dtype=np.int32)
    lmap = np.zeros(n_pad, dtype=np.int32)
    lmap[136:172] = 1  # second layer slots in flat order
    mask = np.zeros(n_pad, dtype=np.float32)
    mask[: CFG.n_total] = 1.0
    x = rng.normal(size=(CFG.batch, 16)).astype(np.float32)
    y = rng.integers(0, 4, CFG.batch).astype(np.int32)
    out = step_fn(
        mu, rho, lsp, zeros(B, S), zeros(B, S), zeros(B, S), zeros(B, S),
        zeros(L), zeros(L), np.int32(1), x, y,
        zeros(B), zeros(B), zeros(B, S), np.int32(0),
        amap, lmap.reshape(B, S), mask.reshape(B, S),
        np.float32(1.0), np.float32(1.0), np.float32(1e-3),
    )
    kl_graph = np.asarray(out[12])
    lsp_elems = lsp[lmap].reshape(B, S)
    kl_ref = np.asarray(
        block_kl_ref(mu, rho, lsp_elems, mask.reshape(B, S))
    )
    np.testing.assert_allclose(kl_graph, kl_ref, rtol=1e-4, atol=1e-5)


def test_masked_padding_does_not_affect_scores(fns):
    """Padding slots (mask=0) must not influence logits — the invariant that
    lets B*S exceed the real slot count."""
    score, _ = fns
    rng = np.random.default_rng(1)
    mu = rng.normal(size=CFG.S).astype(np.float32)
    rho = (rng.normal(size=CFG.S) * 0.3 - 1).astype(np.float32)
    lsp = (rng.normal(size=CFG.S) * 0.3 - 1).astype(np.float32)
    mask = np.ones(CFG.S, dtype=np.float32)
    mask[-2:] = 0.0
    base = np.asarray(score(np.int32(9), np.int32(2), np.int32(0), mu, rho, lsp, mask)[0])
    mu2 = mu.copy()
    mu2[-2:] = 999.0  # garbage in padding slots
    rho2 = rho.copy()
    rho2[-2:] = 5.0
    pert = np.asarray(score(np.int32(9), np.int32(2), np.int32(0), mu2, rho2, lsp, mask)[0])
    np.testing.assert_array_equal(base, pert)
