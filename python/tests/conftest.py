import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


def config_path(name: str) -> str:
    return os.path.join(REPO, "configs", f"{name}.json")
