"""AOT pipeline: manifest correctness, HLO text sanity, determinism."""

import json
import os

import pytest

from compile.aot import lower_config
from compile.configs import load_config
from .conftest import config_path


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = lower_config(config_path("tiny_mlp"), out)
    return out, manifest


def test_manifest_fields(lowered):
    _, m = lowered
    cfg = load_config(config_path("tiny_mlp"))
    assert m["B"] == cfg.B and m["S"] == cfg.S
    assert m["n_total"] == cfg.n_total
    assert m["n_slots"] == sum(cfg.layer_slots)
    assert set(m["entries"]) == {"train_step", "score_chunk", "decode_chunk",
                                 "eval_batch", "eval_full", "sample_weights"}


def test_hlo_text_is_parseable_hlo(lowered):
    out, m = lowered
    for name, e in m["entries"].items():
        path = os.path.join(out, "tiny_mlp", e["file"])
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text, name


def test_train_step_io_counts(lowered):
    _, m = lowered
    e = m["entries"]["train_step"]
    assert len(e["inputs"]) == 22
    assert len(e["outputs"]) == 13


def test_score_chunk_shapes(lowered):
    _, m = lowered
    cfg = load_config(config_path("tiny_mlp"))
    e = m["entries"]["score_chunk"]
    assert e["outputs"][0]["shape"] == [cfg.k_chunk]
    e = m["entries"]["decode_chunk"]
    assert e["outputs"][0]["shape"] == [cfg.k_chunk, cfg.S]


def test_lowering_is_deterministic(lowered, tmp_path):
    out, m = lowered
    m2 = lower_config(config_path("tiny_mlp"), str(tmp_path))
    for name in m["entries"]:
        assert m["entries"][name]["sha256"] == m2["entries"][name]["sha256"], name


def test_manifest_json_on_disk_matches(lowered):
    out, m = lowered
    disk = json.load(open(os.path.join(out, "tiny_mlp", "manifest.json")))
    assert disk == m
