"""Pallas fused reparameterized linear: forward + custom VJP vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import sample_linear
from compile.kernels.ref import sample_linear_ref

SETTINGS = dict(deadline=None, max_examples=20)


def _mk(rng, batch, din, dout):
    x = rng.normal(size=(batch, din)).astype(np.float32)
    mu = (rng.normal(size=(din, dout)) * 0.3).astype(np.float32)
    lsq = (rng.normal(size=(din, dout)) * 0.3 - 2.0).astype(np.float32)
    eps = rng.normal(size=(din, dout)).astype(np.float32)
    b = rng.normal(size=dout).astype(np.float32)
    return x, mu, lsq, eps, b


@given(
    batch=st.integers(min_value=1, max_value=16),
    din=st.integers(min_value=1, max_value=40),
    dout=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**SETTINGS)
def test_matches_ref(batch, din, dout, seed):
    rng = np.random.default_rng(seed)
    args = _mk(rng, batch, din, dout)
    np.testing.assert_allclose(
        np.asarray(sample_linear(*args)), np.asarray(sample_linear_ref(*args)),
        rtol=1e-4, atol=1e-4,
    )


def test_zero_eps_is_mean_forward():
    rng = np.random.default_rng(5)
    x, mu, lsq, _, b = _mk(rng, 4, 8, 6)
    eps = np.zeros_like(mu)
    got = np.asarray(sample_linear(x, mu, lsq, eps, b))
    np.testing.assert_allclose(got, x @ mu + b, rtol=1e-5, atol=1e-5)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(**SETTINGS)
def test_grads_match_oracle_autodiff(seed):
    rng = np.random.default_rng(seed)
    x, mu, lsq, eps, b = _mk(rng, 5, 7, 9)
    cot = rng.normal(size=(5, 9)).astype(np.float32)

    def loss_k(xx, m, q, bb):
        return jnp.sum(sample_linear(xx, m, q, eps, bb) * cot)

    def loss_r(xx, m, q, bb):
        return jnp.sum(sample_linear_ref(xx, m, q, eps, bb) * cot)

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(x, mu, lsq, b)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(x, mu, lsq, b)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-3, atol=1e-4)
