"""Model/compression configuration shared between the python compile path and
the rust coordinator.

A config JSON (see ``configs/*.json`` at the repo root) fully determines the
AOT artifact shapes:

* ``arch``         — network architecture (mlp or conv+mlp head).
* ``layer_slots``  — number of *trainable slots* per layer after the hashing
                     trick (slots <= raw parameter count; rust generates the
                     actual position->slot hash map at runtime).
* ``blocks``       — ``B`` blocks of ``S`` slots each; ``B*S >= sum(layer_slots)``
                     (the tail is padding, masked out of KL and scoring).
* ``k_chunk``      — candidates scored per artifact invocation; the total
                     sample budget ``K = 2**bits`` is swept at runtime by
                     invoking more chunks.

Both sides agree on the *layer parameter layout*: layers are enumerated in
forward order, and each layer contributes ``W`` then ``b`` to the flat
parameter vector.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class LayerSpec:
    """One parameterized layer: weight shape + bias shape + flat offsets."""

    kind: str  # "dense" | "conv"
    w_shape: tuple
    b_shape: tuple
    offset: int  # offset of W in the flat parameter vector

    @property
    def w_count(self) -> int:
        return int(math.prod(self.w_shape))

    @property
    def b_count(self) -> int:
        return int(math.prod(self.b_shape))

    @property
    def count(self) -> int:
        return self.w_count + self.b_count


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: dict
    image: dict
    layer_slots: tuple
    B: int
    S: int
    k_chunk: int
    batch: int
    eval_batch: int
    layers: tuple = field(default=())  # tuple[LayerSpec]

    @property
    def n_total(self) -> int:
        return sum(l.count for l in self.layers)

    @property
    def n_slots(self) -> int:
        return sum(self.layer_slots)

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def classes(self) -> int:
        return int(self.arch["classes"])

    @property
    def input_shape(self) -> tuple:
        """Per-example input shape fed to the forward pass."""
        if self.arch["type"] == "mlp":
            return (int(self.arch["input_dim"]),)
        img = self.arch["image"]
        return (int(img["h"]), int(img["w"]), int(img["c"]))


def _mlp_layers(arch: dict) -> list:
    dims = [int(arch["input_dim"])] + [int(h) for h in arch["hidden"]] + [
        int(arch["classes"])
    ]
    layers, off = [], 0
    for i in range(len(dims) - 1):
        spec = LayerSpec("dense", (dims[i], dims[i + 1]), (dims[i + 1],), off)
        layers.append(spec)
        off += spec.count
    return layers


def _conv_layers(arch: dict) -> list:
    img = arch["image"]
    h, w, c = int(img["h"]), int(img["w"]), int(img["c"])
    layers, off = [], 0
    for conv in arch["conv"]:
        k, cout = int(conv["k"]), int(conv["out"])
        spec = LayerSpec("conv", (k, k, c, cout), (cout,), off)
        layers.append(spec)
        off += spec.count
        c = cout
        h, w = h // 2, w // 2  # each conv is followed by 2x2 maxpool
    dims = [h * w * c] + [int(d) for d in arch["hidden"]] + [int(arch["classes"])]
    for i in range(len(dims) - 1):
        spec = LayerSpec("dense", (dims[i], dims[i + 1]), (dims[i + 1],), off)
        layers.append(spec)
        off += spec.count
    return layers


def load_config(path: str) -> ModelConfig:
    with open(path) as f:
        raw = json.load(f)
    arch = raw["arch"]
    layers = _mlp_layers(arch) if arch["type"] == "mlp" else _conv_layers(arch)
    cfg = ModelConfig(
        name=raw["name"],
        arch=arch,
        image=raw["image"],
        layer_slots=tuple(int(x) for x in raw["layer_slots"]),
        B=int(raw["blocks"]["B"]),
        S=int(raw["blocks"]["S"]),
        k_chunk=int(raw["k_chunk"]),
        batch=int(raw["batch"]),
        eval_batch=int(raw["eval_batch"]),
        layers=tuple(layers),
    )
    validate(cfg)
    return cfg


def validate(cfg: ModelConfig) -> None:
    if len(cfg.layer_slots) != cfg.n_layers:
        raise ValueError(
            f"{cfg.name}: layer_slots has {len(cfg.layer_slots)} entries, "
            f"arch has {cfg.n_layers} layers"
        )
    for spec, m in zip(cfg.layers, cfg.layer_slots):
        if not (0 < m <= spec.count):
            raise ValueError(
                f"{cfg.name}: layer slots {m} outside (0, {spec.count}]"
            )
    if cfg.B * cfg.S < cfg.n_slots:
        raise ValueError(
            f"{cfg.name}: B*S={cfg.B * cfg.S} < total slots {cfg.n_slots}"
        )
    if cfg.k_chunk <= 0 or cfg.k_chunk & (cfg.k_chunk - 1):
        raise ValueError(f"{cfg.name}: k_chunk must be a power of two")
