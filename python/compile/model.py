"""L2: MIRACLE's variational model and training/encoding graphs in JAX.

Everything here is lowered ONCE by ``aot.py`` to HLO text and then driven from
the rust coordinator — python is never on the hot path. The graphs are generic
over the runtime maps (hashing trick, random block permutation), which rust
generates and feeds as ordinary int32/float32 inputs:

* ``assemble_map`` [N_total] — flat-parameter position -> index into the
  block-layout slot vector ``blocks_flat`` [B*S]. It composes the hashing
  trick (position -> slot) with the random block permutation (slot ->
  position in block layout), so weight assembly is a single gather.
* ``layer_map``  [B, S] — layer id of each block element (p's stddev is
  shared per layer; blocks mix layers because the split is random).
* ``slot_mask``  [B, S] — 1.0 for real slots, 0.0 for the padding tail.

Variational family (§3.3): fully factorized Gaussian q with free mean and
stddev per slot; encoding distribution p is a zero-mean Gaussian with one
learned stddev per layer. Both are trained jointly by in-graph Adam on the
beta-annealed objective (Eq. 3) with per-block penalties (Algorithm 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import block_kl, sample_linear

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

def _assemble_full(blocks_flat, assemble_map):
    """Gather the flat parameter vector from block-layout slots."""
    return jnp.take(blocks_flat, assemble_map, axis=0)


def _layer_params(cfg: ModelConfig, w_full):
    """Slice the flat parameter vector into per-layer (W, b) tensors."""
    out = []
    for spec in cfg.layers:
        w = w_full[spec.offset:spec.offset + spec.w_count].reshape(spec.w_shape)
        b = w_full[spec.offset + spec.w_count:spec.offset + spec.count].reshape(
            spec.b_shape
        )
        out.append((w, b))
    return out


def _maxpool2(x):
    """2x2 max pooling, NHWC."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(cfg: ModelConfig, w_full, x):
    """Deterministic forward pass with explicit weights. Returns logits."""
    params = _layer_params(cfg, w_full)
    h = x
    li = 0
    if cfg.arch["type"] == "conv":
        for _ in cfg.arch["conv"]:
            w, b = params[li]
            li += 1
            h = jax.lax.conv_general_dilated(
                h, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            ) + b
            h = jax.nn.relu(h)
            h = _maxpool2(h)
        h = h.reshape(h.shape[0], -1)
    for i in range(li, cfg.n_layers):
        w, b = params[i]
        h = h @ w + b
        if i != cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h


def forward_sampled(cfg: ModelConfig, w_full, x):
    """Forward pass where dense layers run through the fused Pallas kernel.

    ``w_full`` here is a *tuple* (mu_full, lsq_full, eps_full) so that the
    sample+matmul fusion sees the raw variational parameters. Conv layers (and
    biases) use the pre-sampled values in ``eps`` form as well, composed with
    plain jnp since conv is not a Pallas target on this substrate.
    """
    mu_full, lsq_full, eps_full = w_full
    w_sampled = mu_full + jnp.exp(lsq_full) * eps_full
    params_mu = _layer_params(cfg, mu_full)
    params_lsq = _layer_params(cfg, lsq_full)
    params_eps = _layer_params(cfg, eps_full)
    params_w = _layer_params(cfg, w_sampled)
    h = x
    li = 0
    if cfg.arch["type"] == "conv":
        for _ in cfg.arch["conv"]:
            w, b = params_w[li]
            li += 1
            h = jax.lax.conv_general_dilated(
                h, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            ) + b
            h = jax.nn.relu(h)
            h = _maxpool2(h)
        h = h.reshape(h.shape[0], -1)
    for i in range(li, cfg.n_layers):
        mu_w, b_mu = params_mu[i]
        lsq_w, b_lsq = params_lsq[i]
        eps_w, b_eps = params_eps[i]
        b = b_mu + jnp.exp(b_lsq) * b_eps
        h = sample_linear(h, mu_w, lsq_w, eps_w, b)
        if i != cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# objective + train step
# ---------------------------------------------------------------------------

def _ce_and_acc(logits, y):
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return ce, acc


def _effective_blocks(mu, rho, eps, frozen_mask, frozen_w):
    """Per-block weights: encoded blocks are pinned to their decoded values."""
    sampled = mu + jnp.exp(rho) * eps
    fm = frozen_mask[:, None]
    return fm * frozen_w + (1.0 - fm) * sampled


def make_train_step(cfg: ModelConfig):
    """Build the jittable train-step function (one Adam update of Eq. 3)."""

    def loss_fn(tr, eps, x, y, beta, frozen_mask, frozen_w,
                assemble_map, layer_map, slot_mask, data_scale):
        mu, rho, lsp = tr  # trainable leaves
        lsp_elems = jnp.take(lsp, layer_map.reshape(-1), axis=0).reshape(
            layer_map.shape
        )
        kl_b = block_kl(mu, rho, lsp_elems, slot_mask)
        # frozen blocks: no KL penalty (their weights are already coded)
        kl_pen = jnp.sum(beta * (1.0 - frozen_mask) * kl_b)

        fm = frozen_mask[:, None]
        # variational parameters in block layout, with frozen blocks pinned:
        # mean <- frozen value, stddev <- 0 (via eps masking)
        mu_eff = fm * frozen_w + (1.0 - fm) * mu
        eps_eff = (1.0 - fm) * eps * slot_mask
        mu_full = _assemble_full(mu_eff.reshape(-1), assemble_map)
        lsq_full = _assemble_full(rho.reshape(-1), assemble_map)
        eps_full = _assemble_full(eps_eff.reshape(-1), assemble_map)
        logits = forward_sampled(cfg, (mu_full, lsq_full, eps_full), x)
        ce, acc = _ce_and_acc(logits, y)
        loss = data_scale * ce + kl_pen
        return loss, (ce, acc, kl_b)

    def train_step(mu, rho, lsp,
                   m_mu, v_mu, m_rho, v_rho, m_lsp, v_lsp, step,
                   x, y, beta, frozen_mask, frozen_w, seed,
                   assemble_map, layer_map, slot_mask,
                   data_scale, lsp_train, lr):
        key = jax.random.PRNGKey(seed)
        eps = jax.random.normal(key, (cfg.B, cfg.S), dtype=jnp.float32)

        grad_fn = jax.grad(loss_fn, argnums=0, has_aux=True)
        grads, (ce, acc, kl_b) = grad_fn(
            (mu, rho, lsp), eps, x, y, beta, frozen_mask,
            frozen_w, assemble_map, layer_map, slot_mask, data_scale
        )
        g_mu, g_rho, g_lsp = grads

        # mask: frozen blocks must not move; padding slots must not move
        live = (1.0 - frozen_mask)[:, None] * slot_mask
        g_mu = g_mu * live
        g_rho = g_rho * live
        g_lsp = g_lsp * lsp_train

        t = step.astype(jnp.float32)
        bc1 = 1.0 - ADAM_B1 ** t
        bc2 = 1.0 - ADAM_B2 ** t

        def adam(p, g, m, v, mask=None):
            m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
            v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
            upd = lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
            if mask is not None:
                upd = upd * mask
            return p - upd, m2, v2

        mu2, m_mu2, v_mu2 = adam(mu, g_mu, m_mu, v_mu, live)
        rho2, m_rho2, v_rho2 = adam(rho, g_rho, m_rho, v_rho, live)
        lsp2, m_lsp2, v_lsp2 = adam(lsp, g_lsp, m_lsp, v_lsp, lsp_train)

        loss = data_scale * ce + jnp.sum(beta * (1.0 - frozen_mask) * kl_b)
        return (mu2, rho2, lsp2, m_mu2, v_mu2, m_rho2, v_rho2, m_lsp2,
                v_lsp2, loss, ce, acc, kl_b)

    return train_step


# ---------------------------------------------------------------------------
# coding-path graphs (Algorithm 1): shared-randomness candidate generation
# ---------------------------------------------------------------------------

def _chunk_candidates(cfg: ModelConfig, seed, block_id, chunk_id):
    """The shared random generator: z ~ N(0, I), [K_chunk, S].

    The derivation key = fold_in(fold_in(PRNGKey(seed), block_id), chunk_id)
    is THE protocol constant shared by encoder and decoder: both sides replay
    this exact graph, so candidates are bit-identical by construction.
    """
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, block_id)
    key = jax.random.fold_in(key, chunk_id)
    return jax.random.normal(key, (cfg.k_chunk, cfg.S), dtype=jnp.float32)


def make_score_chunk(cfg: ModelConfig):
    """logits[K_chunk] of candidates for one block (Pallas hot-spot)."""
    from .kernels import importance_logits

    def score_chunk(seed, block_id, chunk_id, mu_b, rho_b, lsp_b, mask_b):
        z = _chunk_candidates(cfg, seed, block_id, chunk_id)
        return (importance_logits(z, mu_b, rho_b, lsp_b, mask_b),)

    return score_chunk


def make_decode_chunk(cfg: ModelConfig):
    """candidates[K_chunk, S] = sigma_p * z for one block chunk."""

    def decode_chunk(seed, block_id, chunk_id, lsp_b):
        z = _chunk_candidates(cfg, seed, block_id, chunk_id)
        return (jnp.exp(lsp_b)[None, :] * z,)

    return decode_chunk


# ---------------------------------------------------------------------------
# evaluation graphs
# ---------------------------------------------------------------------------

def make_eval_batch(cfg: ModelConfig):
    """logits[eval_batch, classes] from explicit block-layout weights."""

    def eval_batch(w_blocks, assemble_map, x):
        w_full = _assemble_full(w_blocks.reshape(-1), assemble_map)
        return (forward(cfg, w_full, x),)

    return eval_batch


def make_eval_full(cfg: ModelConfig):
    """logits from a raw flat weight vector (baselines bypass the hashed
    block layout entirely — pruned/quantized weight-sets are positionally
    free)."""

    def eval_full(w_full, x):
        return (forward(cfg, w_full, x),)

    return eval_full


def make_sample_weights(cfg: ModelConfig):
    """Draw one block-layout weight-set from q (frozen blocks pinned)."""

    def sample_weights(mu, rho, frozen_mask, frozen_w, seed):
        key = jax.random.PRNGKey(seed)
        eps = jax.random.normal(key, (cfg.B, cfg.S), dtype=jnp.float32)
        return (_effective_blocks(mu, rho, eps, frozen_mask, frozen_w),)

    return sample_weights


# ---------------------------------------------------------------------------
# example-input builders (shapes/dtypes for AOT lowering + the manifest)
# ---------------------------------------------------------------------------

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entry_points(cfg: ModelConfig):
    """name -> (fn, example_args) for every AOT artifact of this config."""
    bs = (cfg.B, cfg.S)
    x_shape = (cfg.batch,) + cfg.input_shape
    xe_shape = (cfg.eval_batch,) + cfg.input_shape
    return {
        "train_step": (
            make_train_step(cfg),
            (
                _f32(*bs), _f32(*bs), _f32(cfg.n_layers),          # mu rho lsp
                _f32(*bs), _f32(*bs), _f32(*bs), _f32(*bs),        # adam mu/rho
                _f32(cfg.n_layers), _f32(cfg.n_layers), _i32(),    # adam lsp, t
                _f32(*x_shape), _i32(cfg.batch),                   # batch
                _f32(cfg.B), _f32(cfg.B), _f32(*bs), _i32(),       # beta fm fw seed
                _i32(cfg.n_total), _i32(*bs), _f32(*bs),           # maps
                _f32(), _f32(), _f32(),                            # scale lsp_tr lr
            ),
        ),
        "score_chunk": (
            make_score_chunk(cfg),
            (_i32(), _i32(), _i32(), _f32(cfg.S), _f32(cfg.S), _f32(cfg.S),
             _f32(cfg.S)),
        ),
        "decode_chunk": (
            make_decode_chunk(cfg),
            (_i32(), _i32(), _i32(), _f32(cfg.S)),
        ),
        "eval_batch": (
            make_eval_batch(cfg),
            (_f32(*bs), _i32(cfg.n_total), _f32(*xe_shape)),
        ),
        "eval_full": (
            make_eval_full(cfg),
            (_f32(cfg.n_total), _f32(*xe_shape)),
        ),
        "sample_weights": (
            make_sample_weights(cfg),
            (_f32(*bs), _f32(*bs), _f32(cfg.B), _f32(*bs), _i32()),
        ),
    }
