"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
re-assigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --config ../configs/tiny_mlp.json \
        [--config ...] --out ../artifacts

Artifacts land in ``<out>/<config-name>/<entry>.hlo.txt`` plus a single
``<out>/<config-name>/manifest.json`` describing every entry point's input
and output shapes/dtypes (the rust runtime validates against it).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .configs import load_config
from .model import entry_points

_DTYPE_NAMES = {"float32": "f32", "int32": "i32", "uint32": "u32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": _DTYPE_NAMES[str(s.dtype)]}


def lower_config(cfg_path: str, out_root: str) -> dict:
    cfg = load_config(cfg_path)
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    entries = {}
    for name, (fn, example_args) in entry_points(cfg).items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *example_args)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        entries[name] = {
            "file": fname,
            "inputs": [_spec_json(a) for a in example_args],
            "outputs": [_spec_json(o) for o in out_shapes],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  {cfg.name}/{name}: {len(text)} chars")
    manifest = {
        "config": cfg.name,
        "config_sha256": _file_sha(cfg_path),
        "n_total": cfg.n_total,
        "n_slots": cfg.n_slots,
        "n_layers": cfg.n_layers,
        "B": cfg.B,
        "S": cfg.S,
        "k_chunk": cfg.k_chunk,
        "batch": cfg.batch,
        "eval_batch": cfg.eval_batch,
        "classes": cfg.classes,
        "layer_slots": list(cfg.layer_slots),
        "layer_counts": [l.count for l in cfg.layers],
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def _file_sha(path: str) -> str:
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", action="append", required=True,
                    help="config json path (repeatable)")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    for cfg_path in args.config:
        print(f"lowering {cfg_path} ...")
        lower_config(cfg_path, args.out)
    print("AOT done.")


if __name__ == "__main__":
    main()
