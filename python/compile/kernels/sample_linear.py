"""Pallas kernel: fused reparameterized dense layer with custom VJP.

The training forward pass samples one weight-set per step, ``w = mu +
sigma*eps`` (§3.3 uses the reparameterization trick), and immediately consumes
it in a matmul. Fusing the sample into the matmul keeps the sampled weight
panel in VMEM instead of round-tripping an ``[in, out]`` tensor through HBM —
the TPU analogue of the fused sampling epilogue a CUDA implementation would
put in the matmul prologue. Tiles target the MXU: ``[batch, in] @ [in,
out_tile]`` per grid step.

Backward uses the straightforward closed form (w is recomputed, i.e.
rematerialized, rather than stored).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, mu_ref, lsq_ref, eps_ref, b_ref, out_ref):
    x = x_ref[...]  # [batch, in]
    w = mu_ref[...] + jnp.exp(lsq_ref[...]) * eps_ref[...]  # [in, out_tile]
    out_ref[...] = jnp.dot(x, w) + b_ref[...]


def _pick_tile(n: int, cap: int = 128) -> int:
    tile = min(n, cap)
    while n % tile:
        tile -= 1
    return max(tile, 1)


def _sample_linear_pallas(x, mu, log_sigma, eps, b):
    batch, d_in = x.shape
    d_out = mu.shape[1]
    o_tile = _pick_tile(d_out)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(d_out // o_tile,),
        in_specs=[
            pl.BlockSpec((batch, d_in), lambda j: (0, 0)),
            pl.BlockSpec((d_in, o_tile), lambda j: (0, j)),
            pl.BlockSpec((d_in, o_tile), lambda j: (0, j)),
            pl.BlockSpec((d_in, o_tile), lambda j: (0, j)),
            pl.BlockSpec((1, o_tile), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((batch, o_tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), x.dtype),
        interpret=True,
    )(x, mu, log_sigma, eps, b.reshape(1, d_out))


@jax.custom_vjp
def sample_linear(x, mu, log_sigma, eps, b):
    """y = x @ (mu + exp(log_sigma) * eps) + b, Pallas-fused."""
    return _sample_linear_pallas(x, mu, log_sigma, eps, b)


def _fwd(x, mu, log_sigma, eps, b):
    return _sample_linear_pallas(x, mu, log_sigma, eps, b), (x, mu, log_sigma, eps)


def _bwd(res, g):
    x, mu, log_sigma, eps = res
    sigma = jnp.exp(log_sigma)
    w = mu + sigma * eps  # rematerialized
    d_x = g @ w.T
    d_w = x.T @ g
    d_mu = d_w
    d_lsq = d_w * eps * sigma  # d/d log_sigma = d_w * eps * sigma
    d_b = jnp.sum(g, axis=0)
    return d_x, d_mu, d_lsq, None, d_b


sample_linear.defvjp(_fwd, _bwd)
