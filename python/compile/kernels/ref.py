"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts ``assert_allclose(kernel(...), ref(...))`` across hypothesis-generated
shapes and values. The references are also what the kernels' custom VJPs are
derived from.
"""

from __future__ import annotations

import jax.numpy as jnp

_HALF_LOG_2PI = 0.9189385332046727  # 0.5 * log(2*pi)


def gauss_logpdf(x, mu, log_sigma):
    """Elementwise log N(x; mu, exp(log_sigma)^2)."""
    z = (x - mu) * jnp.exp(-log_sigma)
    return -0.5 * z * z - log_sigma - _HALF_LOG_2PI


def importance_logits_ref(z, mu_q, log_sigma_q, log_sigma_p, mask):
    """Log importance weights of ``K`` candidates drawn from p.

    Args:
      z:           [K, S] standard-normal draws (shared-randomness source).
      mu_q:        [S] variational means for this block.
      log_sigma_q: [S] variational log-stddevs.
      log_sigma_p: [S] encoding-distribution log-stddevs (per element, since a
                   block mixes layers and p's stddev is shared per layer).
      mask:        [S] 1.0 for real slots, 0.0 for padding.

    Returns:
      [K] log a_k = sum_j mask_j * (log q(w_kj) - log p(w_kj)) where
      w_k = exp(log_sigma_p) * z_k  (p has zero mean).
    """
    w = jnp.exp(log_sigma_p)[None, :] * z  # [K, S]
    log_q = gauss_logpdf(w, mu_q[None, :], log_sigma_q[None, :])
    log_p = -0.5 * z * z - log_sigma_p[None, :] - _HALF_LOG_2PI
    return jnp.sum(mask[None, :] * (log_q - log_p), axis=1)


def block_kl_ref(mu_q, log_sigma_q, log_sigma_p, mask):
    """Per-block KL(q||p) for diagonal Gaussians (p zero-mean).

    Args:
      mu_q, log_sigma_q, log_sigma_p, mask: all [B, S].

    Returns:
      [B] KL in nats: sum_s mask * (lsp - lsq + (sq^2 + mu^2)/(2 sp^2) - 1/2).
    """
    var_ratio = jnp.exp(2.0 * (log_sigma_q - log_sigma_p))
    mu_term = (mu_q * jnp.exp(-log_sigma_p)) ** 2
    elem = log_sigma_p - log_sigma_q + 0.5 * (var_ratio + mu_term) - 0.5
    return jnp.sum(mask * elem, axis=1)


def sample_linear_ref(x, mu, log_sigma, eps, b):
    """Fused reparameterized dense layer: y = x @ (mu + sigma*eps) + b.

    Args:
      x:   [batch, in]
      mu:  [in, out] weight means.
      log_sigma: [in, out] weight log-stddevs.
      eps: [in, out] standard-normal sample (one weight-set per step).
      b:   [out] bias (already sampled).

    Returns:
      [batch, out]
    """
    w = mu + jnp.exp(log_sigma) * eps
    return x @ w + b
