"""Pallas kernel: per-block Gaussian KL(q||p), with an analytic custom VJP.

Used inside ``train_step`` — the KL vector both feeds the annealed penalty
term of the objective (Eq. 3 / Algorithm 2) and is returned to the rust
coordinator, whose beta controller compares it against the local coding goal
``C_loc``. The forward pass runs as a Pallas panel reduction; the backward
pass uses the closed-form gradients so ``jax.grad`` works through it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kl_kernel(mu_ref, lsq_ref, lsp_ref, mask_ref, out_ref):
    mu = mu_ref[...]  # [B_TILE, S]
    lsq = lsq_ref[...]
    lsp = lsp_ref[...]
    mask = mask_ref[...]
    var_ratio = jnp.exp(2.0 * (lsq - lsp))
    mu_term = (mu * jnp.exp(-lsp)) ** 2
    elem = lsp - lsq + 0.5 * (var_ratio + mu_term) - 0.5
    out_ref[...] = jnp.sum(mask * elem, axis=1)


def _pick_tile(b: int, cap: int = 128) -> int:
    tile = min(b, cap)
    while b % tile:
        tile -= 1
    return max(tile, 1)


def _kl_pallas(mu_q, log_sigma_q, log_sigma_p, mask):
    b, s = mu_q.shape
    b_tile = _pick_tile(b)
    spec = pl.BlockSpec((b_tile, s), lambda i: (i, 0))
    return pl.pallas_call(
        _kl_kernel,
        grid=(b // b_tile,),
        in_specs=[spec, spec, spec, spec],
        out_specs=pl.BlockSpec((b_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), mu_q.dtype),
        interpret=True,
    )(mu_q, log_sigma_q, log_sigma_p, mask)


@jax.custom_vjp
def block_kl(mu_q, log_sigma_q, log_sigma_p, mask):
    """[B] KL(q_b || p_b); Pallas forward, analytic backward."""
    return _kl_pallas(mu_q, log_sigma_q, log_sigma_p, mask)


def _fwd(mu_q, log_sigma_q, log_sigma_p, mask):
    return _kl_pallas(mu_q, log_sigma_q, log_sigma_p, mask), (
        mu_q,
        log_sigma_q,
        log_sigma_p,
        mask,
    )


def _bwd(res, g):
    mu_q, lsq, lsp, mask = res
    gb = g[:, None]  # [B, 1] cotangent per block
    inv_vp = jnp.exp(-2.0 * lsp)
    var_ratio = jnp.exp(2.0 * (lsq - lsp))
    d_mu = mask * mu_q * inv_vp * gb
    d_lsq = mask * (var_ratio - 1.0) * gb
    d_lsp = mask * (1.0 - var_ratio - mu_q * mu_q * inv_vp) * gb
    return d_mu, d_lsq, d_lsp, None


block_kl.defvjp(_fwd, _bwd)
