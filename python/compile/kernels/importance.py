"""Pallas kernel: importance-weight scoring of candidate weight blocks.

This is the compute hot-spot of MIRACLE's encoder (Algorithm 1, line 4): for a
block of ``S`` weights, score ``K`` candidates ``w_k = sigma_p * z_k`` drawn
from the encoding distribution ``p`` with the *shared* random generator, where
the score is the log importance weight ``log a_k = log q(w_k) - log p(w_k)``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks K in tiles of
``K_TILE`` rows; each step holds a ``[K_TILE, S]`` candidate panel plus the
``[1, S]`` parameter rows in VMEM and performs an elementwise log-density
evaluation followed by a lane reduction over S — a VPU-shaped panel sweep (the
original GPU implementation's threadblock loop over samples). There is no data
reuse across K tiles, so double-buffering the z panel is the only HBM schedule
that matters; ``BlockSpec`` expresses exactly that.

The kernel is encode-path only (no autodiff needed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_HALF_LOG_2PI = 0.9189385332046727


def _score_kernel(z_ref, mu_ref, lsq_ref, lsp_ref, mask_ref, out_ref):
    z = z_ref[...]  # [K_TILE, S]
    mu = mu_ref[...]  # [1, S]
    lsq = lsq_ref[...]
    lsp = lsp_ref[...]
    mask = mask_ref[...]
    w = jnp.exp(lsp) * z
    # log q - log p; the 0.5*log(2*pi) terms cancel.
    zq = (w - mu) * jnp.exp(-lsq)
    term = (-0.5 * zq * zq - lsq) - (-0.5 * z * z - lsp)
    out_ref[...] = jnp.sum(mask * term, axis=1)


def _pick_tile(k: int, cap: int = 256) -> int:
    tile = min(k, cap)
    while k % tile:
        tile //= 2
    return max(tile, 1)


@functools.partial(jax.jit, static_argnames=())
def importance_logits(z, mu_q, log_sigma_q, log_sigma_p, mask):
    """Pallas-tiled version of :func:`ref.importance_logits_ref`.

    Shapes: z [K, S]; mu_q/log_sigma_q/log_sigma_p/mask [S]. Returns [K].
    """
    k, s = z.shape
    k_tile = _pick_tile(k)
    row = lambda a: a.reshape(1, s)
    grid = (k // k_tile,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k_tile, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((k_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k,), z.dtype),
        interpret=True,
    )(z, row(mu_q), row(log_sigma_q), row(log_sigma_p), row(mask))
