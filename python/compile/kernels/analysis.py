"""Structural performance analysis of the Pallas kernels.

interpret=True gives CPU-numpy timings only, so TPU efficiency is *estimated*
from the BlockSpec structure (DESIGN.md §3/§7): VMEM footprint per grid step,
arithmetic intensity against the HBM stream, and the implied roofline bound
on a reference TPU core (v4-like numbers: 275 TFLOP/s bf16 MXU, ~1.2 TB/s
HBM, 16 MiB VMEM). Usage::

    python -m compile.kernels.analysis          # print report
"""

from __future__ import annotations

from dataclasses import dataclass

# reference TPU core (v4-ish, per-core)
HBM_BW = 1.2e12  # B/s
VMEM_BYTES = 16 * 2**20
VPU_FLOPS = 4.4e12  # f32 vector unit
MXU_FLOPS = 137e12  # bf16 matmul per core


@dataclass
class KernelReport:
    name: str
    vmem_bytes: int
    flops_per_step: float
    hbm_bytes_per_step: float

    @property
    def intensity(self) -> float:
        return self.flops_per_step / self.hbm_bytes_per_step

    @property
    def vmem_ok(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES

    def roofline_flops(self, peak: float) -> float:
        """Attainable FLOP/s = min(peak, intensity * HBM bandwidth)."""
        return min(peak, self.intensity * HBM_BW)

    def efficiency(self, peak: float) -> float:
        return self.roofline_flops(peak) / peak


def importance_report(k_tile: int, s: int, dtype_bytes: int = 4) -> KernelReport:
    """score kernel: [K_tile, S] candidate panel, elementwise + row reduce.

    ~9 flops per element (exp x2, mul/add chain, masked sum). The z panel
    streams from HBM once; parameter rows stay resident; logits stream out.
    """
    vmem = (k_tile * s + 4 * s + k_tile) * dtype_bytes
    flops = 9.0 * k_tile * s
    hbm = (k_tile * s + k_tile) * dtype_bytes
    return KernelReport("importance_logits", vmem, flops, hbm)


def kl_report(b_tile: int, s: int, dtype_bytes: int = 4) -> KernelReport:
    """block-KL kernel: 4 [B_tile, S] panels in, [B_tile] out, ~8 flops/elem."""
    vmem = (4 * b_tile * s + b_tile) * dtype_bytes
    flops = 8.0 * b_tile * s
    hbm = (4 * b_tile * s + b_tile) * dtype_bytes
    return KernelReport("block_kl", vmem, flops, hbm)


def sample_linear_report(
    batch: int, d_in: int, o_tile: int, dtype_bytes: int = 4
) -> KernelReport:
    """fused reparameterized matmul: 3 [d_in, o_tile] panels (mu, ls, eps)
    + x [batch, d_in]; 2*batch*d_in*o_tile matmul flops on the MXU plus
    2 flops/weight for the fused sample."""
    vmem = (batch * d_in + 3 * d_in * o_tile + batch * o_tile + o_tile) * dtype_bytes
    flops = 2.0 * batch * d_in * o_tile + 2.0 * d_in * o_tile
    hbm = (3 * d_in * o_tile + batch * o_tile) * dtype_bytes  # x resident
    return KernelReport("sample_linear", vmem, flops, hbm)


def report() -> list:
    return [
        importance_report(k_tile=256, s=16),
        kl_report(b_tile=128, s=16),
        sample_linear_report(batch=128, d_in=784, o_tile=128),
    ]


def main() -> None:
    print(f"{'kernel':<20} {'VMEM':>10} {'AI f/B':>8} {'roofline':>12} {'eff':>6}")
    for r in report():
        peak = MXU_FLOPS if r.name == "sample_linear" else VPU_FLOPS
        print(
            f"{r.name:<20} {r.vmem_bytes / 1024:>8.1f}Ki "
            f"{r.intensity:>8.2f} {r.roofline_flops(peak) / 1e12:>10.2f}T "
            f"{r.efficiency(peak) * 100:>5.1f}%"
            + ("" if r.vmem_ok else "  !! exceeds VMEM")
        )


if __name__ == "__main__":
    main()
