"""L1: Pallas kernels for MIRACLE's compute hot-spots + pure-jnp oracles."""

from .importance import importance_logits
from .kl import block_kl
from .sample_linear import sample_linear

__all__ = ["importance_logits", "block_kl", "sample_linear"]
