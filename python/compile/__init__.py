"""Build-time python package: JAX model + Pallas kernels + AOT lowering."""
