//! Serve-loop resilience tests: overload shedding (both policies), circuit
//! breaker trip + HalfOpen recovery, deadline storms, hot reload under
//! traffic with last-known-good fallback, and completion under a
//! deterministic chaos schedule. The common thread: the loop never exits
//! early, every request gets exactly one answer, and
//! [`ServeStats::check_invariant`] holds on every path.

use miracle::codec::MrcFile;
use miracle::data;
use miracle::runtime::{self, Runtime};
use miracle::server::{
    ReloadRequest, Request, Response, Server, ServerCfg, ServerFaults,
    ServeError, ShedPolicy,
};
use miracle::util::breaker::BreakerCfg;
use miracle::util::faultline::ChaosSchedule;
use miracle::util::retry::RetryPolicy;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

fn test_mrc(arts: &runtime::ModelArtifacts) -> MrcFile {
    MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0xABCD,
        protocol_seed: 7,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: 10,
        lsp: vec![-2.0f32; arts.meta.n_layers],
        indices: (0..arts.meta.b as u64).map(|i| i % 1024).collect(),
    }
}

fn example() -> Vec<f32> {
    let test = data::synth_protos(4, 16, 4, 11);
    test.x[..16].to_vec()
}

fn send_and_wait(
    tx: &std::sync::mpsc::Sender<Request>,
    x: Vec<f32>,
) -> Response {
    let (rtx, rrx) = channel();
    tx.send(Request { x, submitted: Instant::now(), reply: rtx })
        .expect("server gone");
    rrx.recv_timeout(Duration::from_secs(30)).expect("no answer")
}

#[test]
fn overload_reject_sheds_excess_and_answers_everyone() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        max_batch: 2,
        queue_depth: 2,
        shed: ShedPolicy::Reject,
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    // the whole burst is queued before the loop starts, so admission is
    // deterministic: 1 blocking recv + 1 gathered fill the depth-2 queue,
    // the eager drain sheds the other 10
    let (tx, rx) = channel::<Request>();
    let mut replies: Vec<Receiver<Response>> = Vec::new();
    for _ in 0..12 {
        let (rtx, rrx) = channel();
        tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx })
            .unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let stats = server.run(rx).unwrap();

    let responses: Vec<Response> = replies
        .iter()
        .map(|r| r.recv_timeout(Duration::from_secs(5)).expect("unanswered"))
        .collect();
    let ok = responses.iter().filter(|r| r.is_ok()).count();
    let shed = responses
        .iter()
        .filter(|r| {
            matches!(r.error(), Some(ServeError::Overloaded { depth: 2 }))
        })
        .count();
    assert_eq!(ok, 2, "exactly the bounded queue is served");
    assert_eq!(shed, 10, "every overflow answered with Overloaded");
    assert_eq!(stats.accepted, 12);
    assert_eq!(stats.served, 2);
    assert_eq!(stats.sheds.overloaded, 10);
    assert_eq!(stats.queue_high_water, 2);
    stats.check_invariant().unwrap();
}

#[test]
fn overload_oldest_evicts_stale_keeps_freshest() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        max_batch: 2,
        queue_depth: 2,
        shed: ShedPolicy::Oldest,
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    let (tx, rx) = channel::<Request>();
    let mut replies: Vec<Receiver<Response>> = Vec::new();
    for _ in 0..6 {
        let (rtx, rrx) = channel();
        tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx })
            .unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let stats = server.run(rx).unwrap();

    // freshest-wins: the last two arrivals survive, the four oldest are
    // evicted (in order) with Overloaded answers
    for (i, rrx) in replies.iter().enumerate() {
        let resp = rrx.recv_timeout(Duration::from_secs(5)).expect("unanswered");
        if i < 4 {
            assert!(
                matches!(resp.error(), Some(ServeError::Overloaded { .. })),
                "old request {i} should be evicted, got {resp:?}"
            );
        } else {
            assert!(resp.is_ok(), "fresh request {i} failed: {resp:?}");
        }
    }
    assert_eq!(stats.served, 2);
    assert_eq!(stats.sheds.overloaded, 4);
    stats.check_invariant().unwrap();
}

#[test]
fn breaker_trips_after_repeated_exec_failures_and_fails_fast() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        retry: RetryPolicy::none(),
        breaker: BreakerCfg {
            window: 4,
            min_samples: 2,
            trip_ratio: 0.5,
            cooldown: Duration::from_secs(10), // never elapses in-test
            probes: 1,
        },
        faults: ServerFaults { fail_execs: 100, ..Default::default() },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    let (tx, rx) = channel::<Request>();
    let client = std::thread::spawn(move || {
        let a = send_and_wait(&tx, example());
        let b = send_and_wait(&tx, example());
        let c = send_and_wait(&tx, example());
        (a, b, c)
    });
    let stats = server.run(rx).unwrap();
    let (a, b, c) = client.join().unwrap();
    assert!(matches!(a.error(), Some(ServeError::ExecFailed(_))), "{a:?}");
    assert!(matches!(b.error(), Some(ServeError::ExecFailed(_))), "{b:?}");
    match c.error() {
        Some(ServeError::BreakerOpen { retry_after }) => {
            assert!(*retry_after > Duration::ZERO);
            assert!(*retry_after <= Duration::from_secs(10));
        }
        other => panic!("expected fast BreakerOpen, got {other:?}"),
    }
    assert_eq!(stats.breaker_trips, 1);
    assert_eq!(stats.errors.exec, 2);
    assert_eq!(stats.errors.breaker, 1);
    assert_eq!(stats.served, 0);
    assert_eq!(stats.accepted, 3);
    stats.check_invariant().unwrap();
}

#[test]
fn breaker_recovers_through_halfopen_probe() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        retry: RetryPolicy::none(),
        breaker: BreakerCfg {
            window: 4,
            min_samples: 2,
            trip_ratio: 0.5,
            cooldown: Duration::from_millis(30),
            probes: 1,
        },
        // exactly the two trip-inducing failures; the probe then succeeds
        faults: ServerFaults { fail_execs: 2, ..Default::default() },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    let (tx, rx) = channel::<Request>();
    let client = std::thread::spawn(move || {
        for _ in 0..2 {
            let r = send_and_wait(&tx, example());
            assert!(
                matches!(r.error(), Some(ServeError::ExecFailed(_))),
                "{r:?}"
            );
        }
        // hammer until the probe closes the breaker again, honoring the
        // retry_after hint instead of spinning
        let mut fast_fails = 0usize;
        for _ in 0..50 {
            match send_and_wait(&tx, example()) {
                Response::Ok(_) => return (fast_fails, true),
                Response::Err(ServeError::BreakerOpen { retry_after }) => {
                    fast_fails += 1;
                    std::thread::sleep(retry_after + Duration::from_millis(1));
                }
                other => panic!("unexpected response: {other:?}"),
            }
        }
        (fast_fails, false)
    });
    let stats = server.run(rx).unwrap();
    let (fast_fails, recovered) = client.join().unwrap();
    assert!(recovered, "breaker never recovered");
    assert!(fast_fails >= 1, "expected at least one fast-fail while Open");
    assert_eq!(stats.breaker_trips, 1);
    assert_eq!(stats.errors.exec, 2);
    assert_eq!(stats.errors.breaker, fast_fails);
    assert!(stats.served >= 1);
    stats.check_invariant().unwrap();
}

#[test]
fn deadline_storm_is_shed_without_killing_the_loop() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        deadline: Duration::from_millis(50),
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    let (tx, rx) = channel::<Request>();
    let mut stale: Vec<Receiver<Response>> = Vec::new();
    for _ in 0..10 {
        let (rtx, rrx) = channel();
        tx.send(Request {
            x: example(),
            submitted: Instant::now() - Duration::from_secs(1),
            reply: rtx,
        })
        .unwrap();
        stale.push(rrx);
    }
    let (fresh_tx, fresh_rx) = channel();
    tx.send(Request { x: example(), submitted: Instant::now(), reply: fresh_tx })
        .unwrap();
    drop(tx);
    let stats = server.run(rx).unwrap();
    for rrx in stale {
        let resp = rrx.recv_timeout(Duration::from_secs(5)).expect("unanswered");
        assert!(
            matches!(resp.error(), Some(ServeError::DeadlineExceeded { .. })),
            "stale request must be shed, got {resp:?}"
        );
    }
    assert!(fresh_rx.recv().unwrap().is_ok());
    assert_eq!(stats.served, 1);
    assert_eq!(stats.sheds.deadline, 10);
    assert_eq!(stats.accepted, 11);
    stats.check_invariant().unwrap();
}

#[test]
fn hot_reload_swaps_model_and_corrupt_push_keeps_last_known_good() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        reload_poll: Duration::from_millis(5),
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();
    let (reload_tx, reload_rx) = channel::<ReloadRequest>();
    server.set_reload(reload_rx);

    let good_bytes = mrc.to_bytes();
    // a truncated container cannot survive the CRC-protected parse
    let corrupt = good_bytes[..good_bytes.len() / 2].to_vec();
    let swapped = {
        let mut next = mrc.clone();
        let k = 1u64 << next.c_loc_bits;
        next.indices[0] = (next.indices[0] + 1) % k;
        next.to_bytes()
    };

    let (tx, rx) = channel::<Request>();
    let client = std::thread::spawn(move || {
        let logits = |r: &Response| -> Vec<f32> {
            r.prediction().expect("request failed").logits.clone()
        };
        let before = logits(&send_and_wait(&tx, example()));
        // corrupt push: must be rejected, serving must be unaffected
        reload_tx
            .send(ReloadRequest { bytes: corrupt, origin: "test:corrupt".into() })
            .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let after_corrupt = logits(&send_and_wait(&tx, example()));
        // valid push with different indices: must swap in atomically
        reload_tx
            .send(ReloadRequest { bytes: swapped, origin: "test:swap".into() })
            .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let after_swap = logits(&send_and_wait(&tx, example()));
        (before, after_corrupt, after_swap)
    });
    let stats = server.run(rx).unwrap();
    let (before, after_corrupt, after_swap) = client.join().unwrap();
    assert_eq!(
        before, after_corrupt,
        "a rejected push must leave the serving model bit-identical"
    );
    assert_ne!(
        before, after_swap,
        "an applied push must actually change the decoded model"
    );
    assert_eq!(stats.reloads, 1);
    assert_eq!(stats.reloads_rejected, 1);
    assert_eq!(stats.served, 3);
    assert_eq!(stats.errored, 0, "no swap-attributable failures");
    stats.check_invariant().unwrap();
}

#[test]
fn chaos_schedule_runs_to_completion_with_exact_accounting() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    const N: usize = 40;
    let cfg = ServerCfg {
        faults: ServerFaults {
            schedule: ChaosSchedule {
                seed: 0xC4A0_5EED,
                exec_fail_p: 0.10,
                // ticks 5 and 6 fail ALL attempts: retries are defeated and
                // two ExecFailed answers are guaranteed
                outage: Some((5, 7)),
                spike_p: 0.10,
                spike: Duration::from_millis(1),
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    let (tx, rx) = channel::<Request>();
    let client = std::thread::spawn(move || {
        // sequential: one request == one batch == one chaos tick
        (0..N).map(|_| send_and_wait(&tx, example())).collect::<Vec<_>>()
    });
    let stats = server.run(rx).unwrap();
    let responses = client.join().unwrap();
    assert_eq!(responses.len(), N, "every request answered exactly once");
    assert_eq!(stats.accepted, N);
    assert!(
        stats.errors.exec >= 2,
        "the outage window must defeat the retry budget"
    );
    assert!(
        stats.retries >= 4,
        "each outage tick burns the full retry budget (got {})",
        stats.retries
    );
    assert_eq!(stats.served + stats.errored, N);
    assert_eq!(stats.rejected, 0);
    stats.check_invariant().unwrap();
}

/// PR 10: the event log tells the truth under chaos. Run the full
/// `chaos-serve` drive as a subprocess with `--events-out`; the binary's
/// internal reconcile (exact counter<->event match) gates its exit code,
/// and we independently re-count the shed / breaker / reload events here
/// against the chaos geometry (DEPTH=4, BURST=20 => 16 overload sheds).
#[test]
fn chaos_serve_event_log_reconciles() {
    let events = std::env::temp_dir().join(format!(
        "miracle_chaos_events_{}.jsonl",
        std::process::id()
    ));
    let output = std::process::Command::new(env!("CARGO_BIN_EXE_miracle"))
        .args(["chaos-serve", "--seed", "7", "--iters", "40", "--events-out"])
        .arg(&events)
        .output()
        .expect("spawn miracle chaos-serve");
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(
        output.status.success(),
        "chaos-serve failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&output.stderr)
    );
    assert!(
        stdout.contains("event log reconciled"),
        "internal reconcile did not run:\n{stdout}"
    );

    use miracle::util::json::Json;
    let text = std::fs::read_to_string(&events).expect("read event log");
    let mut counts = std::collections::BTreeMap::<String, usize>::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let j = Json::parse(line).expect("every event line parses");
        *counts
            .entry(j.get("ev").unwrap().as_str().unwrap().to_string())
            .or_insert(0) += 1;
    }
    // geometry: the pre-queued burst of 20 against a depth-4 queue sheds
    // exactly 16; phase 3 trips the breaker at least once; phase 4 pushes
    // exactly one rejected and one applied reload
    assert!(
        counts.get("shed").copied().unwrap_or(0) >= 16,
        "burst sheds missing from the log: {counts:?}"
    );
    assert!(
        counts.get("breaker_open").copied().unwrap_or(0) >= 1,
        "breaker trip not logged: {counts:?}"
    );
    assert_eq!(counts.get("reload_applied"), Some(&1), "{counts:?}");
    assert_eq!(counts.get("reload_rejected"), Some(&1), "{counts:?}");
    let _ = std::fs::remove_file(&events);
}
