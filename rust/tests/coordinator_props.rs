//! Property-based tests on coordinator invariants (routing, batching,
//! state management) — no PJRT required; pure control-plane logic.

use miracle::codec::{BackendFamily, MrcFile};
use miracle::coordinator::BetaController;
use miracle::model::Layout;
use miracle::prng::{categorical_from_logits, Pcg64, StreamingCategorical};
use miracle::runtime::ModelMeta;
use miracle::util::quickprop::{check, Gen};

fn random_meta(g: &mut Gen) -> ModelMeta {
    let n_layers = g.usize_in(1, 4);
    let layer_counts: Vec<usize> = (0..n_layers).map(|_| g.usize_in(4, 200)).collect();
    let layer_slots: Vec<usize> = layer_counts
        .iter()
        .map(|&c| g.usize_in(1, c))
        .collect();
    let n_slots: usize = layer_slots.iter().sum();
    let s = g.usize_in(1, 16);
    let b = n_slots / s + 1;
    ModelMeta {
        name: "prop".into(),
        b,
        s,
        k_chunk: 1 << g.usize_in(0, 8),
        n_total: layer_counts.iter().sum(),
        n_slots,
        n_layers,
        layer_slots,
        layer_counts,
        batch: 4,
        eval_batch: 4,
        classes: 2,
        input_shape: vec![3],
    }
}

#[test]
fn layout_assembles_every_position_to_a_real_slot() {
    check("layout real slots", 60, |g| {
        let meta = random_meta(g);
        let layout = Layout::generate(&meta, g.rng.next_u64());
        assert_eq!(layout.assemble_map.len(), meta.n_total);
        for &t in &layout.assemble_map {
            let t = t as usize;
            assert!(t < meta.b * meta.s);
            assert!(layout.slot_mask[t] > 0.0, "position mapped to padding");
        }
        let real: usize = layout.slot_mask.iter().filter(|&&m| m > 0.0).count();
        assert_eq!(real, meta.n_slots);
    });
}

#[test]
fn layout_layer_map_consistent_with_slots() {
    check("layout layer map", 40, |g| {
        let meta = random_meta(g);
        let layout = Layout::generate(&meta, g.rng.next_u64());
        // positions of layer l must land on slots labeled l
        let mut pos = 0usize;
        for (l, &count) in meta.layer_counts.iter().enumerate() {
            for _ in 0..count {
                let bpos = layout.assemble_map[pos] as usize;
                assert_eq!(layout.layer_map[bpos], l as i32);
                pos += 1;
            }
        }
    });
}

#[test]
fn beta_controller_is_monotone_in_kl() {
    check("beta monotone", 60, |g| {
        let b = g.usize_in(1, 50);
        let bits = g.usize_in(2, 20) as u8;
        let mut ctl = BetaController::new(b, 1e-6, 0.01, bits);
        let goal = ctl.c_loc_nats;
        let kl: Vec<f32> = (0..b)
            .map(|_| g.f32_in(0.0, 2.0 * goal as f32))
            .collect();
        let fm = vec![0.0f32; b];
        let before = ctl.beta.clone();
        ctl.update(&kl, &fm);
        for i in 0..b {
            if (kl[i] as f64) > goal {
                assert!(ctl.beta[i] > before[i]);
            } else {
                assert!(ctl.beta[i] < before[i]);
            }
        }
    });
}

#[test]
fn streaming_sampler_matches_batch_for_any_chunking() {
    check("streaming categorical", 60, |g| {
        let n = g.usize_in(1, 2000);
        let logits: Vec<f32> = (0..n).map(|_| g.f32_in(-5.0, 5.0)).collect();
        let seed = g.rng.next_u64();
        let batch = categorical_from_logits(&mut Pcg64::seed(seed), &logits);
        let mut stream = StreamingCategorical::new(Pcg64::seed(seed));
        let mut i = 0usize;
        while i < n {
            let step = g.usize_in(1, 128).min(n - i);
            stream.push(&logits[i..i + step]);
            i += step;
        }
        let (idx, _) = stream.finish();
        assert_eq!(idx, batch);
    });
}

#[test]
fn mrc_round_trips_for_any_geometry() {
    check("mrc geometry", 60, |g| {
        let b = g.usize_in(1, 500);
        let bits = g.usize_in(1, 30) as u8;
        let mrc = MrcFile {
            model: format!("m{}", g.usize_in(0, 9)),
            layout_seed: g.rng.next_u64(),
            protocol_seed: g.rng.next_u32() as i32,
            backend: BackendFamily::Native,
            b,
            s: g.usize_in(1, 64),
            k_chunk: 1 << g.usize_in(0, 12),
            c_loc_bits: bits,
            lsp: (0..g.usize_in(1, 8)).map(|_| g.f32_in(-6.0, 2.0)).collect(),
            indices: (0..b)
                .map(|_| g.rng.next_u64() & ((1u64 << bits) - 1))
                .collect(),
        };
        let rt = MrcFile::from_bytes(&mrc.to_bytes()).unwrap();
        assert_eq!(rt, mrc);
        // size accounting: payload + bounded header
        assert!(rt.total_bits() >= rt.payload_bits());
        assert!(rt.total_bits() <= rt.payload_bits() + 8 * (64 + rt.lsp.len() * 4) + 256);
    });
}

#[test]
fn block_lsp_respects_layer_table_for_random_layouts() {
    check("block lsp", 40, |g| {
        let meta = random_meta(g);
        let layout = Layout::generate(&meta, g.rng.next_u64());
        let lsp: Vec<f32> = (0..meta.n_layers).map(|_| g.f32_in(-4.0, 0.0)).collect();
        for b in 0..meta.b.min(10) {
            let v = layout.block_lsp(b, &lsp);
            for (j, &x) in v.iter().enumerate() {
                assert_eq!(x, lsp[layout.layer_map[b * meta.s + j] as usize]);
            }
        }
    });
}
