//! Integration tests over the runtime backend + coordinator.
//!
//! These run on the default pure-Rust native backend: no Python, no XLA and
//! no pre-generated artifacts required. Setting `MIRACLE_BACKEND=xla` (with
//! a `--features xla` build and `make artifacts`) exercises the same suite
//! through the PJRT path.

use miracle::codec::MrcFile;
use miracle::coordinator::{self, encoder, MiracleCfg, Session};
use miracle::data;
use miracle::model::Layout;
use miracle::runtime::{self, Runtime};
use miracle::server::{spawn_clients, Server, ServerCfg};
use miracle::tensor::{Arg, TensorF32};

fn tiny_cfg() -> MiracleCfg {
    MiracleCfg {
        c_loc_bits: 10,
        i0: 1200,
        i_intermediate: 2,
        lr: 5e-3,
        beta0: 1e-3,
        eps_beta: 0.02,
        data_scale: 512.0,
        layout_seed: 0xABCD,
        protocol_seed: 7,
        train_seed: 42,
        threads: 0,
    }
}

fn datasets() -> (data::Dataset, data::Dataset) {
    (
        data::synth_protos(512, 16, 4, 1234),
        data::synth_protos(512, 16, 4, 1234 ^ 0x7E57),
    )
}

#[test]
fn end_to_end_compress_decode_eval() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let cfg = tiny_cfg();
    let result = coordinator::compress(&arts, &train, &test, &cfg).unwrap();

    // learned something far better than chance (4 classes)
    assert!(
        result.test_error < 0.20,
        "test error {:.3}",
        result.test_error
    );
    // KL controller pinned blocks near the goal
    assert!(
        result.mean_block_kl_bits < cfg.c_loc_bits as f64 * 1.5,
        "mean block KL {:.1} bits",
        result.mean_block_kl_bits
    );
    // container size accounting: payload dominates
    assert_eq!(result.mrc.payload_bits(), 22 * 10);
    assert!(result.total_bits < result.mrc.payload_bits() + 400);

    // round-trip via disk and re-decode deterministically
    let path = std::env::temp_dir().join("miracle_it.mrc");
    result.mrc.save(path.to_str().unwrap()).unwrap();
    let loaded = MrcFile::load(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded, result.mrc);
    let w1 = coordinator::decode_model(&arts, &loaded).unwrap();
    let w2 = coordinator::decode_model(&arts, &loaded).unwrap();
    assert_eq!(w1, w2, "decode must be deterministic");

    // decoded model evaluates to the same error the compressor reported
    let layout = Layout::generate(&arts.meta, loaded.layout_seed);
    let err = coordinator::eval_error(&arts, &layout.assemble_map, &w1, &test).unwrap();
    assert!((err - result.test_error).abs() < 1e-9);
}

#[test]
fn encoder_freeze_matches_decode() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, _) = datasets();
    let cfg = tiny_cfg();
    let mut session = Session::new(&arts, &train, &cfg).unwrap();
    for _ in 0..30 {
        session.train_step(true).unwrap();
    }
    let b = 5;
    let lsp_b = session.layout.block_lsp(b, &session.state.lsp);
    let outcome = encoder::encode_block(&mut session, b).unwrap();
    // decoding the transmitted index reproduces the frozen weights exactly
    let decoded =
        encoder::decode_block_row(&arts, cfg.protocol_seed, b, outcome.index, &lsp_b)
            .unwrap();
    assert_eq!(decoded, outcome.weights);
    let s = arts.meta.s;
    assert_eq!(&session.frozen_w[b * s..(b + 1) * s], &decoded[..]);
    assert_eq!(session.frozen_mask[b], 1.0);
    assert!(outcome.index < 1 << cfg.c_loc_bits);
}

#[test]
fn frozen_blocks_survive_training() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, _) = datasets();
    let cfg = tiny_cfg();
    let mut session = Session::new(&arts, &train, &cfg).unwrap();
    for _ in 0..10 {
        session.train_step(true).unwrap();
    }
    let b = 3;
    encoder::encode_block(&mut session, b).unwrap();
    let s = arts.meta.s;
    let frozen_before = session.frozen_w[b * s..(b + 1) * s].to_vec();
    let mu_before = session.state.mu[b * s..(b + 1) * s].to_vec();
    for _ in 0..10 {
        session.train_step(false).unwrap();
    }
    assert_eq!(&session.frozen_w[b * s..(b + 1) * s], &frozen_before[..]);
    // frozen block's variational parameters must not drift either
    assert_eq!(&session.state.mu[b * s..(b + 1) * s], &mu_before[..]);
}

#[test]
fn different_protocol_seeds_give_different_codebooks() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let lsp = vec![0.0f32; arts.meta.s];
    let a = encoder::decode_block_row(&arts, 1, 0, 5, &lsp).unwrap();
    let b = encoder::decode_block_row(&arts, 2, 0, 5, &lsp).unwrap();
    assert_ne!(a, b);
    let a2 = encoder::decode_block_row(&arts, 1, 0, 5, &lsp).unwrap();
    assert_eq!(a, a2);
}

#[test]
fn runtime_rejects_bad_shapes() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let bad = TensorF32::zeros(vec![3, 3]);
    let err = arts.invoke(
        "eval_batch",
        &[Arg::F32(bad.clone()), Arg::F32(bad.clone()), Arg::F32(bad)],
    );
    let msg = match err {
        Ok(_) => panic!("bad shapes accepted"),
        Err(e) => format!("{e}"),
    };
    assert!(msg.contains("expected"), "{msg}");
}

#[test]
fn server_predictions_match_direct_eval() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let mut cfg = tiny_cfg();
    cfg.i0 = 400;
    cfg.i_intermediate = 1;
    let result = coordinator::compress(&arts, &train, &test, &cfg).unwrap();

    // direct decode + eval predictions
    let w = coordinator::decode_model(&arts, &result.mrc).unwrap();
    let layout = Layout::generate(&arts.meta, result.mrc.layout_seed);
    let direct_err =
        coordinator::eval_error(&arts, &layout.assemble_map, &w, &test).unwrap();

    // serve the same test set
    let feat = test.feature_dim();
    let examples: Vec<Vec<f32>> = (0..64)
        .map(|i| test.x[i * feat..(i + 1) * feat].to_vec())
        .collect();
    let mut server = Server::new(&arts, &result.mrc, ServerCfg::default()).unwrap();
    let (rx, clients) = spawn_clients(examples, 2, 32, std::time::Duration::ZERO);
    let stats = server.run(rx).unwrap();
    let responses = clients.join().unwrap();
    assert_eq!(stats.served, 64);
    assert_eq!(responses.len(), 64);
    // server-side error over the first 64 examples should roughly match
    let wrong = responses
        .iter()
        .zip((0..64).map(|i| test.y[i % test.len()]))
        .filter(|(_, _)| false)
        .count();
    let _ = wrong; // prediction-vs-label matching is order-dependent with
                   // multiple clients; instead just sanity check outputs
    for r in &responses {
        let p = r.prediction().expect("default cfg must serve every request");
        assert_eq!(p.logits.len(), arts.meta.classes);
        assert!(p.pred < arts.meta.classes);
        assert!(p.logits.iter().all(|v| v.is_finite()));
    }
    assert!(direct_err < 0.5);
}

#[test]
fn eval_error_handles_partial_final_batch() {
    // test set not a multiple of eval_batch: every example counted once
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, _) = datasets();
    let cfg = tiny_cfg();
    let session = Session::new(&arts, &train, &cfg).unwrap();
    let odd_test = data::synth_protos(77, 16, 4, 5); // 77 % 64 != 0
    let w: Vec<f32> = (0..arts.meta.b * arts.meta.s)
        .map(|i| ((i % 13) as f32 - 6.0) * 0.02)
        .collect();
    let err =
        coordinator::eval_error(&arts, &session.layout.assemble_map, &w, &odd_test)
            .unwrap();
    // reference: evaluate each example as its own single-element dataset;
    // the batched partial-final-batch path must count each exactly once
    let mut wrong = 0usize;
    for i in 0..77 {
        let single = data::Dataset {
            x: odd_test.x[i * 16..(i + 1) * 16].to_vec(),
            y: vec![odd_test.y[i]],
            example_shape: vec![16],
            classes: 4,
        };
        let e =
            coordinator::eval_error(&arts, &session.layout.assemble_map, &w, &single)
                .unwrap();
        if e > 0.5 {
            wrong += 1;
        }
    }
    let expect = wrong as f64 / 77.0;
    assert!((err - expect).abs() < 1e-9, "err {err} expect {expect}");
}

#[test]
fn compress_without_intermediate_updates_works() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let mut cfg = tiny_cfg();
    cfg.i0 = 600;
    cfg.i_intermediate = 0; // pure encode after I0 (paper ablation I=0)
    let r = coordinator::compress(&arts, &train, &test, &cfg).unwrap();
    assert!(r.test_error < 0.5);
    assert_eq!(r.mrc.indices.len(), arts.meta.b);
}

#[test]
fn server_respects_max_batch() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0xABCD,
        protocol_seed: 7,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: 10,
        lsp: vec![-2.0f32; arts.meta.n_layers],
        indices: (0..arts.meta.b as u64).map(|i| i % 1024).collect(),
    };
    let test = data::synth_protos(64, 16, 4, 9);
    let feat = test.feature_dim();
    let examples: Vec<Vec<f32>> = (0..64)
        .map(|i| test.x[i * feat..(i + 1) * feat].to_vec())
        .collect();
    let cfg = ServerCfg { max_batch: 2, ..Default::default() };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();
    let (rx, clients) = spawn_clients(examples, 8, 8, std::time::Duration::ZERO);
    let stats = server.run(rx).unwrap();
    let _ = clients.join();
    assert_eq!(stats.served, 64);
    assert!(
        stats.batches >= 32,
        "max_batch=2 must force >=32 batches, got {}",
        stats.batches
    );
}

#[test]
fn posterior_samples_perform_like_the_mean() {
    // §3: "a weight-set drawn from q will perform comparable to a
    // deterministically trained network"
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let mut cfg = tiny_cfg();
    cfg.i0 = 0;
    let mut session = Session::new(&arts, &train, &cfg).unwrap();
    for _ in 0..800 {
        session.train_step(true).unwrap();
    }
    let mean_err = coordinator::eval_error(
        &arts,
        &session.layout.assemble_map,
        &session.state.mu,
        &test,
    )
    .unwrap();
    let mut sample_errs = Vec::new();
    for seed in 0..5 {
        let w = session.sample_weights(seed).unwrap();
        sample_errs.push(
            coordinator::eval_error(&arts, &session.layout.assemble_map, &w, &test)
                .unwrap(),
        );
    }
    let mean_sample = sample_errs.iter().sum::<f64>() / sample_errs.len() as f64;
    assert!(
        (mean_sample - mean_err).abs() < 0.10,
        "sample err {mean_sample:.3} vs mean err {mean_err:.3}"
    );
}

#[test]
fn checkpoint_round_trips_through_disk_and_restores() {
    use miracle::coordinator::checkpoint::Checkpoint;
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, _) = datasets();
    let cfg = tiny_cfg();
    let mut session = Session::new(&arts, &train, &cfg).unwrap();
    for _ in 0..20 {
        session.train_step(true).unwrap();
    }
    encoder::encode_block(&mut session, 4).unwrap();
    let mut indices = vec![u64::MAX; arts.meta.b];
    indices[4] = 77;
    let ck = Checkpoint::capture(&session, &indices, 12.5);
    let path = std::env::temp_dir().join("miracle_ck_it.bin");
    ck.save(path.to_str().unwrap(), 0xFEED_FACE).unwrap();
    let (loaded, fp) = Checkpoint::load(path.to_str().unwrap()).unwrap();
    assert_eq!(fp, 0xFEED_FACE);
    assert_eq!(loaded, ck);
    // the verified loader rejects a fingerprint from another config
    assert!(
        Checkpoint::load_verified(path.to_str().unwrap(), 0xBAD).is_err()
    );

    // restore into a fresh session: state + freeze set identical
    let mut fresh = Session::new(&arts, &train, &cfg).unwrap();
    let got_indices = loaded.restore(&mut fresh).unwrap();
    assert_eq!(got_indices, indices);
    assert_eq!(fresh.state.mu, session.state.mu);
    assert_eq!(fresh.state.step, session.state.step);
    assert_eq!(fresh.frozen_mask, session.frozen_mask);
    assert_eq!(fresh.betas.beta, session.betas.beta);
    // the restored session keeps training without error
    fresh.train_step(false).unwrap();
    // and the frozen block is still pinned
    let s = arts.meta.s;
    assert_eq!(
        &fresh.frozen_w[4 * s..5 * s],
        &session.frozen_w[4 * s..5 * s]
    );
}

#[test]
fn checkpoint_rejects_wrong_model_geometry() {
    use miracle::coordinator::checkpoint::Checkpoint;
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, _) = datasets();
    let cfg = tiny_cfg();
    let session = Session::new(&arts, &train, &cfg).unwrap();
    let mut ck = Checkpoint::capture(&session, &vec![u64::MAX; arts.meta.b], 0.0);
    ck.model = "lenet_synth".into();
    let mut fresh = Session::new(&arts, &train, &cfg).unwrap();
    assert!(ck.restore(&mut fresh).is_err());
}

#[test]
fn decode_is_deterministic_across_fresh_backends() {
    // Shared-randomness determinism: the same `.mrc` must decode to
    // bit-identical block weights on two *independently constructed*
    // runtimes/backends (nothing carried over but the container bytes).
    let mk_mrc = |arts: &miracle::runtime::ModelArtifacts| MrcFile {
        model: arts.meta.name.clone(),
        layout_seed: 0x5EED,
        protocol_seed: 11,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: 10,
        lsp: vec![-1.25f32; arts.meta.n_layers],
        indices: (0..arts.meta.b as u64).map(|i| (i * 131) % 1024).collect(),
    };
    let decode_fresh = || {
        let rt = Runtime::cpu().unwrap();
        let arts = runtime::load(&rt, "tiny_mlp").unwrap();
        let mrc = mk_mrc(&arts);
        coordinator::decode_model(&arts, &mrc).unwrap()
    };
    let w1 = decode_fresh();
    let w2 = decode_fresh();
    assert_eq!(w1, w2, "fresh backends must replay identical candidates");
    assert!(w1.iter().any(|&v| v != 0.0));

    // ...and the codebook is protocol-seed sensitive: a different seed in
    // the container yields different weights
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mut other = mk_mrc(&arts);
    other.protocol_seed = 12;
    let w3 = coordinator::decode_model(&arts, &other).unwrap();
    assert_ne!(w1, w3);
}

#[test]
fn lazy_server_decodes_on_demand() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0xABCD,
        protocol_seed: 7,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: 10,
        lsp: vec![-2.0f32; arts.meta.n_layers],
        indices: (0..arts.meta.b as u64).map(|i| i % 1024).collect(),
    };
    let cfg = ServerCfg { lazy_decode: true, ..Default::default() };
    let server = Server::new(&arts, &mrc, cfg).unwrap();
    assert_eq!(server.blocks_decoded(), 0, "lazy server must not pre-decode");
}
