//! Kill-resume equivalence: the crash-safety contract of `compress_with`.
//!
//! The whole point of a checkpoint is that dying is free: a run killed at
//! *any* durable point and resumed with `--resume` must emit a `.mrc` that
//! is **byte-for-byte identical** to an uninterrupted run — same selected
//! indices, same header, same decoded weights, same reported history. This
//! suite simulates kills with the test-only `stop_after_blocks` /
//! `stop_after_steps` kill switches (which checkpoint and then fail with a
//! structured [`Interrupted`] payload), resumes, and compares bytes:
//! at every Phase-2 block boundary, during Phase 1, for the batched I = 0
//! sweep and the sequential I > 0 schedule, and across worker thread counts
//! (the config fingerprint deliberately excludes `threads`).
//!
//! The `--on-nonfinite` policy rides the same machinery: an injected
//! non-finite loss either aborts with a structured [`NonFinite`] payload or
//! rewinds to the last checkpoint and still converges to the clean bytes.

use miracle::coordinator::{
    self, compress_with, Interrupted, MiracleCfg, NonFinite, NonFinitePolicy,
    RunOptions,
};
use miracle::data;
use miracle::runtime::{self, Runtime};

const B: usize = 22; // tiny_mlp block count

fn cfg(i_intermediate: usize, threads: usize) -> MiracleCfg {
    MiracleCfg {
        c_loc_bits: 9,
        i0: 30,
        i_intermediate,
        lr: 5e-3,
        beta0: 1e-3,
        eps_beta: 0.02,
        data_scale: 256.0,
        layout_seed: 0xABCD,
        protocol_seed: 7,
        train_seed: 42,
        threads,
    }
}

fn datasets() -> (data::Dataset, data::Dataset) {
    (
        data::synth_protos(256, 16, 4, 1234),
        data::synth_protos(128, 16, 4, 1234 ^ 0x7E57),
    )
}

fn ckpt_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("miracle_resume_{tag}.ckpt"))
        .to_str()
        .unwrap()
        .to_string()
}

/// Kill an identically-configured run after each of `stops` encoded blocks,
/// resume it, and require byte equality with the clean run. `kill_threads`
/// and `resume_threads` may differ: a checkpoint is portable across worker
/// counts.
fn kill_resume_sweep(
    i_intermediate: usize,
    kill_threads: usize,
    resume_threads: usize,
    stops: &[usize],
    tag: &str,
) {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let clean =
        coordinator::compress(&arts, &train, &test, &cfg(i_intermediate, 1))
            .unwrap();
    let clean_bytes = clean.mrc.to_bytes();
    let w_clean = coordinator::decode_model(&arts, &clean.mrc).unwrap();

    for &stop in stops {
        let path = ckpt_path(&format!("{tag}_{stop}"));
        let _ = std::fs::remove_file(&path);
        let kill = RunOptions {
            checkpoint: Some(path.clone()),
            every_blocks: 1,
            every_steps: 1,
            stop_after_blocks: Some(stop),
            ..Default::default()
        };
        let err = compress_with(
            &arts,
            &train,
            &test,
            &cfg(i_intermediate, kill_threads),
            &kill,
        )
        .expect_err("the kill switch must interrupt the run");
        let intr = err
            .payload::<Interrupted>()
            .expect("interruption must carry the Interrupted payload");
        assert_eq!(intr.encoded_blocks, stop);

        let resume = RunOptions {
            checkpoint: Some(path.clone()),
            every_blocks: 1,
            every_steps: 1,
            resume: true,
            ..Default::default()
        };
        let resumed = compress_with(
            &arts,
            &train,
            &test,
            &cfg(i_intermediate, resume_threads),
            &resume,
        )
        .unwrap_or_else(|e| panic!("resume from block {stop} failed: {e}"));
        assert_eq!(
            resumed.mrc.to_bytes(),
            clean_bytes,
            "resume from block {stop} ({tag}) did not reproduce the clean .mrc"
        );
        assert_eq!(
            coordinator::decode_model(&arts, &resumed.mrc).unwrap(),
            w_clean,
            "decoded weights diverged after resume from block {stop}"
        );
        // reporting is resume-invariant too: the checkpoint carries the
        // metric history and the realized-KL sum
        assert_eq!(resumed.history, clean.history, "history diverged at {stop}");
        assert!(
            (resumed.mean_block_kl_bits - clean.mean_block_kl_bits).abs() < 1e-9
        );
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn kill_resume_every_block_boundary_sequential() {
    // I > 0: encode + intermediate updates, killed at every boundary
    let stops: Vec<usize> = (1..B).collect();
    kill_resume_sweep(2, 1, 1, &stops, "seq1");
}

#[test]
fn kill_resume_every_block_boundary_batched() {
    // I = 0: the batched sweep, killed at every group boundary
    let stops: Vec<usize> = (1..B).collect();
    kill_resume_sweep(0, 1, 1, &stops, "bat1");
}

#[test]
fn kill_resume_with_eight_worker_threads() {
    kill_resume_sweep(2, 8, 8, &[1, 11, B - 1], "seq8");
    kill_resume_sweep(0, 8, 8, &[1, 11, B - 1], "bat8");
}

#[test]
fn checkpoint_is_portable_across_thread_counts() {
    // killed under 1 worker, resumed under 8 (and the clean reference ran
    // under 1): `threads` is excluded from the config fingerprint because
    // selected indices are thread-count invariant
    kill_resume_sweep(2, 1, 8, &[7], "mix18");
    kill_resume_sweep(2, 8, 1, &[15], "mix81");
}

#[test]
fn kill_resume_during_phase1_is_byte_identical() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let cfg1 = cfg(2, 1);
    let clean = coordinator::compress(&arts, &train, &test, &cfg1).unwrap();
    for stop in [1usize, 13, 29] {
        let path = ckpt_path(&format!("p1_{stop}"));
        let _ = std::fs::remove_file(&path);
        let kill = RunOptions {
            checkpoint: Some(path.clone()),
            // cadence coarser than the stop point: exercises the forced
            // save at the kill itself
            every_steps: 5,
            stop_after_steps: Some(stop),
            ..Default::default()
        };
        let err = compress_with(&arts, &train, &test, &cfg1, &kill)
            .expect_err("phase-1 kill switch must interrupt");
        let intr = err.payload::<Interrupted>().unwrap();
        assert_eq!((intr.step, intr.encoded_blocks), (stop as i32, 0));

        let resume = RunOptions {
            checkpoint: Some(path.clone()),
            resume: true,
            ..Default::default()
        };
        let resumed =
            compress_with(&arts, &train, &test, &cfg1, &resume).unwrap();
        assert_eq!(
            resumed.mrc.to_bytes(),
            clean.mrc.to_bytes(),
            "resume from I0 step {stop} diverged"
        );
        assert_eq!(resumed.history, clean.history);
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn resume_after_completion_reemits_identical_bytes() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let cfg1 = cfg(0, 1);
    let path = ckpt_path("complete");
    let _ = std::fs::remove_file(&path);
    let opts = RunOptions {
        checkpoint: Some(path.clone()),
        ..Default::default()
    };
    let first = compress_with(&arts, &train, &test, &cfg1, &opts).unwrap();
    // the final checkpoint marks the run complete; resuming it is a no-op
    // that re-emits the same container
    let again = RunOptions {
        checkpoint: Some(path.clone()),
        resume: true,
        ..Default::default()
    };
    let second = compress_with(&arts, &train, &test, &cfg1, &again).unwrap();
    assert_eq!(second.mrc.to_bytes(), first.mrc.to_bytes());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn nonfinite_abort_is_a_structured_error() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let opts = RunOptions {
        nonfinite_fault: Some(15),
        ..Default::default() // on_nonfinite: Abort
    };
    let err = compress_with(&arts, &train, &test, &cfg(2, 1), &opts)
        .expect_err("injected non-finite loss must abort the run");
    let nf = err
        .payload::<NonFinite>()
        .expect("abort must carry the NonFinite payload");
    assert_eq!(nf.step, 15);
    assert!(
        err.to_string().contains("step 15"),
        "diagnosis must name the step: {err}"
    );
}

#[test]
fn nonfinite_rewind_recovers_to_the_clean_bytes() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let cfg1 = cfg(2, 1);
    let clean = coordinator::compress(&arts, &train, &test, &cfg1).unwrap();
    // fault at step 15 = mid Phase 1; fault at step 40 = mid Phase 2
    // intermediate updates (i0=30 + 2 per encoded block)
    for fault_step in [15i32, 40] {
        let path = ckpt_path(&format!("rewind_{fault_step}"));
        let _ = std::fs::remove_file(&path);
        let opts = RunOptions {
            checkpoint: Some(path.clone()),
            every_blocks: 1,
            every_steps: 1,
            on_nonfinite: NonFinitePolicy::Rewind,
            nonfinite_fault: Some(fault_step),
            ..Default::default()
        };
        let r = compress_with(&arts, &train, &test, &cfg1, &opts)
            .unwrap_or_else(|e| panic!("rewind at step {fault_step} failed: {e}"));
        assert_eq!(
            r.mrc.to_bytes(),
            clean.mrc.to_bytes(),
            "rewind retry at step {fault_step} diverged from the clean run"
        );
        assert_eq!(r.history, clean.history);
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn nonfinite_rewind_without_checkpoint_restarts_from_scratch() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let cfg1 = cfg(2, 1);
    let clean = coordinator::compress(&arts, &train, &test, &cfg1).unwrap();
    let opts = RunOptions {
        on_nonfinite: NonFinitePolicy::Rewind,
        nonfinite_fault: Some(5),
        ..Default::default() // checkpoint: None — nothing durable to rewind to
    };
    let r = compress_with(&arts, &train, &test, &cfg1, &opts).unwrap();
    assert_eq!(r.mrc.to_bytes(), clean.mrc.to_bytes());
}

#[test]
fn resume_misuse_is_refused_up_front() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let cfg1 = cfg(2, 1);
    // --resume without --checkpoint
    let opts = RunOptions { resume: true, ..Default::default() };
    let err = compress_with(&arts, &train, &test, &cfg1, &opts).unwrap_err();
    assert!(err.to_string().contains("--resume requires"), "{err}");
    // --resume with a checkpoint that does not exist
    let opts = RunOptions {
        checkpoint: Some(ckpt_path("definitely_missing")),
        resume: true,
        ..Default::default()
    };
    let err = compress_with(&arts, &train, &test, &cfg1, &opts).unwrap_err();
    assert!(err.to_string().contains("does not exist"), "{err}");
}

#[test]
fn resume_rejects_a_checkpoint_from_a_different_config() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let path = ckpt_path("foreign_cfg");
    let _ = std::fs::remove_file(&path);
    let kill = RunOptions {
        checkpoint: Some(path.clone()),
        every_blocks: 1,
        stop_after_blocks: Some(3),
        ..Default::default()
    };
    compress_with(&arts, &train, &test, &cfg(2, 1), &kill).unwrap_err();
    // same model, different protocol-relevant config (c_loc_bits)
    let mut other = cfg(2, 1);
    other.c_loc_bits = 8;
    let resume = RunOptions {
        checkpoint: Some(path.clone()),
        resume: true,
        ..Default::default()
    };
    let err = compress_with(&arts, &train, &test, &other, &resume).unwrap_err();
    assert!(
        err.to_string().contains("fingerprint"),
        "expected a fingerprint refusal, got: {err}"
    );
    let _ = std::fs::remove_file(&path);
}
