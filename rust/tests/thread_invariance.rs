//! Thread-count invariance of the candidate hot path.
//!
//! The seed tree makes every chunk's randomness independently addressable,
//! so fanning chunk scoring across workers must not change a single bit of
//! the protocol: the selected candidate indices, the `.mrc` bytes, and the
//! decoded weights have to be identical with `MIRACLE_THREADS` = 1, 2 and 8
//! (plumbed here through `MiracleCfg::threads` / the pool's scoped
//! override, which take precedence over the env var).

use miracle::codec::MrcFile;
use miracle::coordinator::{self, encoder, MiracleCfg, Session};
use miracle::data;
use miracle::runtime::{self, Runtime};
use miracle::util::pool;
use miracle::util::quickprop;

fn cfg(threads: usize) -> MiracleCfg {
    MiracleCfg {
        c_loc_bits: 9,
        i0: 0,
        i_intermediate: 0,
        data_scale: 256.0,
        threads,
        ..Default::default()
    }
}

/// Train briefly, encode every block, decode the resulting container.
/// Returns (indices, frozen weights, mrc bytes, decoded model).
fn encode_everything(threads: usize) -> (Vec<u64>, Vec<Vec<f32>>, Vec<u8>, Vec<f32>) {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let train = data::synth_protos(256, 16, 4, 77);
    let cfg = cfg(threads);
    let mut session = Session::new(&arts, &train, &cfg).unwrap();
    for _ in 0..40 {
        session.train_step(true).unwrap();
    }
    let mut indices = Vec::new();
    let mut weights = Vec::new();
    for b in 0..arts.meta.b {
        let outcome = encoder::encode_block(&mut session, b).unwrap();
        indices.push(outcome.index);
        weights.push(outcome.weights);
    }
    let mrc = MrcFile {
        model: arts.meta.name.clone(),
        layout_seed: cfg.layout_seed,
        protocol_seed: cfg.protocol_seed,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: cfg.c_loc_bits,
        lsp: session.state.lsp.clone(),
        indices: indices.clone(),
    };
    let decoded = coordinator::decode_model(&arts, &mrc).unwrap();
    (indices, weights, mrc.to_bytes(), decoded)
}

#[test]
fn encode_and_decode_are_identical_at_every_thread_count() {
    let base = encode_everything(1);
    assert!(
        base.0.iter().any(|&i| i != 0),
        "degenerate run: every selected index is 0"
    );
    for threads in [2usize, 8] {
        let got = encode_everything(threads);
        assert_eq!(got.0, base.0, "indices differ at {threads} threads");
        assert_eq!(got.1, base.1, "frozen weights differ at {threads} threads");
        assert_eq!(got.2, base.2, ".mrc bytes differ at {threads} threads");
        assert_eq!(got.3, base.3, "decoded model differs at {threads} threads");
    }
}

#[test]
fn batched_encode_blocks_matches_sequential_encode() {
    // Same session state, same blocks: one score_blocks sweep must select
    // exactly what per-block encode_block calls select (and freeze the same
    // weights), at any thread count.
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let train = data::synth_protos(256, 16, 4, 123);
    let run = |batched: bool, threads: usize| {
        let mut session = Session::new(&arts, &train, &cfg(threads)).unwrap();
        for _ in 0..25 {
            session.train_step(true).unwrap();
        }
        let blocks: Vec<usize> = (0..arts.meta.b).collect();
        let outcomes = if batched {
            encoder::encode_blocks(&mut session, &blocks).unwrap()
        } else {
            blocks
                .iter()
                .map(|&b| encoder::encode_block(&mut session, b).unwrap())
                .collect()
        };
        let indices: Vec<u64> = outcomes.iter().map(|o| o.index).collect();
        let weights: Vec<Vec<f32>> =
            outcomes.iter().map(|o| o.weights.clone()).collect();
        (indices, weights, session.frozen_w.clone())
    };
    let sequential = run(false, 1);
    for threads in [1usize, 2, 8] {
        let batched = run(true, threads);
        assert_eq!(batched, sequential, "threads={threads}");
    }
}

#[test]
fn property_thread_invariance_across_seeds_and_budgets() {
    // Random protocol seeds and coding budgets: 1-thread and 4-thread
    // encodes of a single block must agree exactly.
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let train = data::synth_protos(128, 16, 4, 5);
    quickprop::check("thread invariance", 6, |g| {
        let c_loc_bits = g.usize_in(6, 10) as u8;
        let protocol_seed = g.i64_in(-1000, 1000) as i32;
        let train_seed = g.rng.next_u64();
        let block = g.usize_in(0, arts.meta.b - 1);
        let encode = |threads: usize| {
            let cfg = MiracleCfg {
                c_loc_bits,
                i0: 0,
                i_intermediate: 0,
                data_scale: 128.0,
                protocol_seed,
                train_seed,
                threads,
                ..Default::default()
            };
            let mut session = Session::new(&arts, &train, &cfg).unwrap();
            for _ in 0..10 {
                session.train_step(true).unwrap();
            }
            let o = encoder::encode_block(&mut session, block).unwrap();
            (o.index, o.weights)
        };
        let single = encode(1);
        let multi = encode(4);
        assert_eq!(single, multi, "c_loc={c_loc_bits} seed={protocol_seed}");
    });
}

#[test]
fn pool_override_beats_env_resolution() {
    // guard-scoped overrides are what the tests above rely on — make sure
    // they actually apply on this thread
    pool::with_threads(3, || assert_eq!(pool::current_threads(), 3));
}
