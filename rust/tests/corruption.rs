//! Corruption property suite for the `.mrc` container (tier-1).
//!
//! The contract under test: for ANY mutation of a well-formed container,
//! `MrcFile::from_bytes` either returns a structured `MrcError` or parses a
//! struct identical to the original — never a panic, never an unbounded
//! allocation, and (for the CRC-protected v2 revision) never a silently
//! different model. Legacy v1 containers carry no integrity section, so for
//! them the suite only asserts no-panic/no-OOM and bounded behavior.

use miracle::codec::{BackendFamily, MrcError, MrcFile};
use miracle::util::faultline::{self, Fault};

fn base_mrc() -> MrcFile {
    MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0x4D31_7261,
        protocol_seed: 7,
        backend: BackendFamily::Native,
        b: 22,
        s: 8,
        k_chunk: 64,
        c_loc_bits: 10,
        lsp: vec![-1.5, -2.25],
        indices: (0..22u64).map(|i| (i * 37 + 11) % 1024).collect(),
    }
}

#[test]
fn every_truncation_of_v2_is_rejected() {
    let bytes = base_mrc().to_bytes();
    for cut in 0..bytes.len() {
        let err = MrcFile::from_bytes(&bytes[..cut])
            .expect_err(&format!("truncation to {cut}/{} bytes accepted", bytes.len()));
        // the diagnosis must stay one line for the CLI
        assert!(!err.to_string().contains('\n'));
    }
}

#[test]
fn every_truncation_of_v1_is_rejected() {
    // v1 has no CRC, but its header + payload length is still exact: any
    // strictly shorter buffer must fail the pre-allocation bounds checks
    let bytes = base_mrc().to_bytes_v1();
    for cut in 0..bytes.len() {
        assert!(
            MrcFile::from_bytes(&bytes[..cut]).is_err(),
            "v1 truncation to {cut}/{} bytes accepted",
            bytes.len()
        );
    }
}

#[test]
fn every_single_bit_flip_in_v2_is_detected() {
    // full coverage: magic, every header byte, the CRCs themselves, every
    // payload byte including the final byte's padding bits
    let m = base_mrc();
    let bytes = m.to_bytes();
    for bit in 0..bytes.len() * 8 {
        let mutated = Fault::FlipBit { bit }.apply(&bytes);
        assert!(
            MrcFile::from_bytes(&mutated).is_err(),
            "flip of bit {bit} (byte {}) parsed without error",
            bit / 8
        );
    }
}

#[test]
fn v1_bit_flips_never_panic_and_stay_bounded() {
    // legacy files are unprotected: a payload flip CAN silently change an
    // index (that is why v2 exists). The hard requirement here is only that
    // nothing panics and any accepted parse keeps the declared geometry.
    let m = base_mrc();
    let bytes = m.to_bytes_v1();
    let mut silent = 0usize;
    for bit in 0..bytes.len() * 8 {
        let mutated = Fault::FlipBit { bit }.apply(&bytes);
        match MrcFile::from_bytes(&mutated) {
            Err(_) => {}
            Ok(parsed) => {
                assert!(parsed.indices.len() <= mutated.len() * 8);
                if parsed != m {
                    silent += 1;
                }
            }
        }
    }
    // sanity: the unprotected payload really is silently corruptible —
    // if this ever reaches zero the fixture stopped testing anything
    assert!(silent > 0, "expected v1 payload flips to corrupt silently");
}

#[test]
fn seeded_byte_mutations_of_v2_never_corrupt_silently() {
    let m = base_mrc();
    let bytes = m.to_bytes();
    for (i, fault) in faultline::plan(0xC0FFEE, 2000, bytes.len())
        .into_iter()
        .enumerate()
    {
        let mutated = fault.apply(&bytes);
        match MrcFile::from_bytes(&mutated) {
            Err(_) => {}
            Ok(parsed) => assert_eq!(
                parsed,
                m,
                "iter {i} ({}) parsed a DIFFERENT model without error",
                fault.describe()
            ),
        }
    }
}

#[test]
fn magic_downgrade_attack_is_rejected() {
    // a 2-bit mutation can rewrite "MRC2" into "MRC1"; the v1 parser must
    // not misread the CRC section as index payload
    let mut bytes = base_mrc().to_bytes();
    bytes[3] = b'1';
    assert!(matches!(
        MrcFile::from_bytes(&bytes),
        Err(MrcError::TrailingGarbage { .. })
    ));
}

#[test]
fn hostile_length_fields_fail_fast_without_allocating() {
    // drive the parser with headers declaring astronomically large counts;
    // each must be refused by a bounds check in well under a second (an
    // attempted allocation of 2^40 indices would OOM the test runner)
    let m = base_mrc();
    for (bytes, label) in [(m.to_bytes(), "v2"), (m.to_bytes_v1(), "v1")] {
        // name_len varint lives right after the magic: overwrite with a
        // multi-byte varint declaring ~2^28 name bytes
        let mut hostile = bytes.clone();
        hostile.splice(4..5, [0xFF, 0xFF, 0xFF, 0x7F]);
        let t = std::time::Instant::now();
        assert!(
            MrcFile::from_bytes(&hostile).is_err(),
            "{label}: hostile name_len accepted"
        );
        assert!(t.elapsed().as_secs_f64() < 1.0, "{label}: not fail-fast");
    }
}

#[test]
fn empty_and_tiny_inputs_are_structured_errors() {
    for bytes in [&b""[..], &b"M"[..], &b"MRC"[..], &b"MRC2"[..], &b"MRC1"[..]] {
        let err = MrcFile::from_bytes(bytes).expect_err("tiny input accepted");
        assert!(
            matches!(err, MrcError::Truncated | MrcError::NotMrc { .. }),
            "unexpected error kind for {} bytes: {err}",
            bytes.len()
        );
    }
}

#[test]
fn appended_garbage_is_rejected_for_both_revisions() {
    let m = base_mrc();
    for (mut bytes, label) in [(m.to_bytes(), "v2"), (m.to_bytes_v1(), "v1")] {
        bytes.extend_from_slice(b"extra");
        assert!(
            matches!(
                MrcFile::from_bytes(&bytes),
                Err(MrcError::TrailingGarbage { .. }) | Err(MrcError::Bounds { .. })
            ),
            "{label}: appended garbage accepted"
        );
    }
}

#[test]
fn multi_block_geometry_survives_the_same_sweep() {
    // a second geometry exercising the multi-byte-varint and odd-padding
    // paths: 173 blocks x 7 bits = 1211 bits => 152 payload bytes, 5 pad bits
    let m = MrcFile {
        model: "lenet_synth".into(),
        layout_seed: u64::MAX,
        protocol_seed: -1,
        backend: BackendFamily::Pjrt,
        b: 173,
        s: 48,
        k_chunk: 128,
        c_loc_bits: 7,
        lsp: vec![-0.5; 4],
        indices: (0..173u64).map(|i| (i * 31) % 128).collect(),
    };
    let bytes = m.to_bytes();
    assert_eq!(MrcFile::from_bytes(&bytes).unwrap(), m);
    for bit in 0..bytes.len() * 8 {
        let mutated = Fault::FlipBit { bit }.apply(&bytes);
        assert!(
            MrcFile::from_bytes(&mutated).is_err(),
            "flip of bit {bit} parsed without error"
        );
    }
    for cut in 0..bytes.len() {
        assert!(MrcFile::from_bytes(&bytes[..cut]).is_err());
    }
}
