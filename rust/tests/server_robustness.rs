//! Server graceful-degradation tests: a wedged client, a corrupt container
//! in lazy mode, a malformed request or a slow backend must never kill the
//! serve loop — affected requests get structured [`ServeError`]s and
//! everyone else keeps getting predictions.

use miracle::codec::MrcFile;
use miracle::data;
use miracle::runtime::{self, Runtime};
use miracle::server::{Request, Server, ServerCfg, ServerFaults, ServeError};
use miracle::util::retry::RetryPolicy;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

fn test_mrc(arts: &runtime::ModelArtifacts) -> MrcFile {
    MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0xABCD,
        protocol_seed: 7,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: 10,
        lsp: vec![-2.0f32; arts.meta.n_layers],
        indices: (0..arts.meta.b as u64).map(|i| i % 1024).collect(),
    }
}

fn example() -> Vec<f32> {
    let test = data::synth_protos(4, 16, 4, 11);
    test.x[..16].to_vec()
}

#[test]
fn dead_client_does_not_wedge_the_loop() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let mut server = Server::new(&arts, &mrc, ServerCfg::default()).unwrap();

    let (tx, rx) = channel::<Request>();
    // a client that sent a request and immediately went away
    let (dead_tx, dead_rx) = channel();
    drop(dead_rx);
    tx.send(Request { x: example(), submitted: Instant::now(), reply: dead_tx })
        .unwrap();
    // eight live clients behind it
    let mut live = Vec::new();
    for _ in 0..8 {
        let (rtx, rrx) = channel();
        tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx })
            .unwrap();
        live.push(rrx);
    }
    drop(tx);
    let stats = server.run(rx).unwrap();
    for rrx in live {
        let resp = rrx.recv().expect("live client must get a response");
        assert!(resp.is_ok(), "live request failed: {:?}", resp.error());
    }
    assert_eq!(stats.served, 9, "dead client's request is still executed");
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.accepted, 9);
    stats.check_invariant().unwrap();
}

#[test]
fn lazy_decode_failure_degrades_and_recovers() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        lazy_decode: true,
        // no retry budget: the single injected fault must surface as a
        // per-request DecodeFailed instead of being absorbed by backoff
        retry: RetryPolicy::none(),
        faults: ServerFaults { fail_decodes: 1, ..Default::default() },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();
    assert_eq!(server.blocks_decoded(), 0);

    let (tx, rx) = channel::<Request>();
    let client = std::thread::spawn(move || {
        // wave 1: hits the injected decode fault
        let (rtx, rrx) = channel();
        tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx })
            .unwrap();
        let first = rrx.recv().unwrap();
        // wave 2: decode retries and succeeds; the loop must still be alive
        let (rtx, rrx) = channel();
        tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx })
            .unwrap();
        let second = rrx.recv().unwrap();
        (first, second)
    });
    let stats = server.run(rx).unwrap();
    let (first, second) = client.join().unwrap();
    assert!(
        matches!(first.error(), Some(ServeError::DecodeFailed(m)) if m.contains("injected")),
        "expected injected DecodeFailed, got {first:?}"
    );
    assert!(second.is_ok(), "decode must recover: {second:?}");
    assert_eq!(stats.served, 1);
    // a decode failure is an execution-side error, not an admission shed
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.errored, 1);
    assert_eq!(stats.errors.decode, 1);
    assert_eq!(stats.accepted, 2);
    stats.check_invariant().unwrap();
    assert_eq!(server.blocks_decoded(), arts.meta.b);
}

#[test]
fn transient_decode_fault_is_absorbed_by_retry() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        lazy_decode: true,
        // default retry policy: 3 attempts — one injected per-attempt fault
        // is invisible to the client and shows up only in the retry counter
        faults: ServerFaults { fail_decodes: 1, ..Default::default() },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();
    let (tx, rx) = channel::<Request>();
    let (rtx, rrx) = channel();
    tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx })
        .unwrap();
    drop(tx);
    let stats = server.run(rx).unwrap();
    let resp = rrx.recv().unwrap();
    assert!(resp.is_ok(), "retry must absorb the fault: {resp:?}");
    assert_eq!(stats.served, 1);
    assert_eq!(stats.errored, 0);
    assert!(stats.retries >= 1, "the absorbed attempt must be counted");
    stats.check_invariant().unwrap();
}

#[test]
fn malformed_request_is_bounced_not_fatal() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let mut server = Server::new(&arts, &mrc, ServerCfg::default()).unwrap();

    let (tx, rx) = channel::<Request>();
    let (bad_tx, bad_rx) = channel();
    tx.send(Request {
        x: vec![1.0; 3], // wrong feature dimension
        submitted: Instant::now(),
        reply: bad_tx,
    })
    .unwrap();
    let (ok_tx, ok_rx) = channel();
    tx.send(Request { x: example(), submitted: Instant::now(), reply: ok_tx })
        .unwrap();
    drop(tx);
    let stats = server.run(rx).unwrap();
    assert!(matches!(
        bad_rx.recv().unwrap().error(),
        Some(ServeError::BadRequest(_))
    ));
    assert!(ok_rx.recv().unwrap().is_ok());
    assert_eq!(stats.served, 1);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.sheds.bad_request, 1);
    assert_eq!(stats.accepted, 2);
    stats.check_invariant().unwrap();
}

#[test]
fn stale_requests_are_shed_with_deadline_exceeded() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        deadline: Duration::from_millis(20),
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    let (tx, rx) = channel::<Request>();
    let (stale_tx, stale_rx) = channel();
    tx.send(Request {
        x: example(),
        // submitted long before its deadline budget
        submitted: Instant::now() - Duration::from_millis(500),
        reply: stale_tx,
    })
    .unwrap();
    let (fresh_tx, fresh_rx) = channel();
    tx.send(Request { x: example(), submitted: Instant::now(), reply: fresh_tx })
        .unwrap();
    drop(tx);
    let stats = server.run(rx).unwrap();
    match stale_rx.recv().unwrap().error() {
        Some(ServeError::DeadlineExceeded { waited, deadline }) => {
            assert!(*waited >= Duration::from_millis(500));
            assert_eq!(*deadline, Duration::from_millis(20));
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(fresh_rx.recv().unwrap().is_ok());
    assert_eq!(stats.served, 1);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.sheds.deadline, 1);
    assert_eq!(stats.accepted, 2);
    stats.check_invariant().unwrap();
}

#[test]
fn slow_backend_requests_queued_past_deadline_are_shed() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        deadline: Duration::from_millis(100),
        faults: ServerFaults {
            exec_delay: Duration::from_millis(400),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();

    let (tx, rx) = channel::<Request>();
    let client = std::thread::spawn(move || {
        // request A is admitted and served (slowly)
        let (rtx_a, rrx_a) = channel();
        tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx_a })
            .unwrap();
        // request B arrives while the backend sleeps on A's batch; by the
        // time the loop gets back to triage, B is far past its deadline
        std::thread::sleep(Duration::from_millis(100));
        let (rtx_b, rrx_b) = channel();
        tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx_b })
            .unwrap();
        (rrx_a.recv().unwrap(), rrx_b.recv().unwrap())
    });
    let stats = server.run(rx).unwrap();
    let (a, b) = client.join().unwrap();
    assert!(a.is_ok(), "admitted request must complete: {a:?}");
    assert!(
        matches!(b.error(), Some(ServeError::DeadlineExceeded { .. })),
        "queued-past-deadline request must be shed, got {b:?}"
    );
    assert_eq!(stats.served, 1);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.sheds.deadline, 1);
    stats.check_invariant().unwrap();
}

#[test]
fn exec_delay_fault_is_observable_in_wall_time() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = test_mrc(&arts);
    let cfg = ServerCfg {
        faults: ServerFaults {
            exec_delay: Duration::from_millis(30),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg).unwrap();
    let (tx, rx) = channel::<Request>();
    let (rtx, rrx) = channel();
    tx.send(Request { x: example(), submitted: Instant::now(), reply: rtx })
        .unwrap();
    drop(tx);
    let stats = server.run(rx).unwrap();
    assert!(rrx.recv().unwrap().is_ok());
    assert_eq!(stats.served, 1);
    assert!(
        stats.wall_secs >= 0.03,
        "injected 30ms exec delay not observed (wall {}s)",
        stats.wall_secs
    );
    stats.check_invariant().unwrap();
}
