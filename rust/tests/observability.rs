//! End-to-end telemetry contract (PR 10, `docs/observability.md`):
//!
//! 1. **Determinism** — telemetry is write-only: the `.mrc` produced with
//!    every sink enabled is byte-identical to one produced with none.
//! 2. **Event log** — every line is valid JSON with the reserved keys
//!    (`ts_us`, `seq`, `lvl`, `ev`), `seq` strictly increasing, and the
//!    lifecycle events (`run_start`, `encode_block`, `checkpoint_write`,
//!    `i0_done`, `simd_dispatch`) all present for a checkpointed compress.
//! 3. **Metrics snapshot** — parses via `util/json.rs`, carries the
//!    `counters`/`gauges` registries with sane values.
//! 4. **Chrome trace** — a well-formed JSON array of complete (`ph: "X"`)
//!    and metadata events.
//!
//! Everything drives the real binary as a subprocess, like
//! `simd_parity.rs`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::Command;

use miracle::util::json::Json;

fn miracle_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_miracle"))
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("miracle_obs_{}_{tag}", std::process::id()))
}

/// Tiny deterministic compress (fixed seeds via defaults); `extra` carries
/// the telemetry flags for the instrumented run.
fn run_compress(out: &Path, extra: &[&str]) -> String {
    let output = miracle_bin()
        .args([
            "compress",
            "--model",
            "tiny_mlp",
            "--i0",
            "2",
            "--i",
            "0",
            "--c-loc-bits",
            "6",
            "--train-size",
            "64",
            "--test-size",
            "64",
            "--out",
        ])
        .arg(out)
        .args(extra)
        .output()
        .expect("spawn miracle compress");
    assert!(
        output.status.success(),
        "compress {extra:?} failed: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    String::from_utf8_lossy(&output.stdout).into_owned()
}

/// Parse a JSON-lines event log: validate reserved keys + seq order and
/// return `ev` name -> count.
fn event_counts(path: &Path) -> BTreeMap<String, usize> {
    let text = std::fs::read_to_string(path).expect("read event log");
    let mut counts = BTreeMap::new();
    let mut last_seq = -1i64;
    for (i, line) in text.lines().filter(|l| !l.trim().is_empty()).enumerate()
    {
        let j = Json::parse(line)
            .unwrap_or_else(|e| panic!("event line {}: {e}\n{line}", i + 1));
        assert!(j.get("ts_us").unwrap().as_f64().unwrap() >= 0.0);
        let seq = j.get("seq").unwrap().as_i64().unwrap();
        assert!(seq > last_seq, "seq not increasing at line {}", i + 1);
        last_seq = seq;
        let lvl = j.get("lvl").unwrap().as_str().unwrap().to_string();
        assert!(
            ["debug", "info", "warn"].contains(&lvl.as_str()),
            "bad lvl '{lvl}'"
        );
        let ev = j.get("ev").unwrap().as_str().unwrap().to_string();
        *counts.entry(ev).or_insert(0) += 1;
    }
    counts
}

#[test]
fn mrc_bytes_identical_with_and_without_telemetry() {
    let plain = tmp_path("plain.mrc");
    let instr = tmp_path("instr.mrc");
    let events = tmp_path("events.jsonl");
    let metrics = tmp_path("metrics.json");
    let trace = tmp_path("trace.json");
    let ckpt = tmp_path("instr.ckpt");

    run_compress(&plain, &[]);
    run_compress(
        &instr,
        &[
            "--events-out",
            events.to_str().unwrap(),
            "--events-level",
            "debug",
            "--metrics-out",
            metrics.to_str().unwrap(),
            "--metrics-every",
            "1",
            "--trace-out",
            trace.to_str().unwrap(),
            "--checkpoint",
            ckpt.to_str().unwrap(),
            "--checkpoint-every",
            "4",
        ],
    );

    // 1. determinism: instrumentation must not perturb the artifact
    let a = std::fs::read(&plain).expect("read plain mrc");
    let b = std::fs::read(&instr).expect("read instrumented mrc");
    assert_eq!(a, b, "telemetry changed the .mrc bytes");

    // 2. event log: reserved keys, ordering, lifecycle coverage
    let counts = event_counts(&events);
    assert_eq!(counts.get("run_start"), Some(&1), "{counts:?}");
    assert_eq!(counts.get("i0_done"), Some(&1), "{counts:?}");
    assert!(counts.get("simd_dispatch").copied().unwrap_or(0) >= 1);
    assert!(
        counts.get("train_step").copied().unwrap_or(0) >= 2,
        "debug level must include per-step training events: {counts:?}"
    );
    let blocks = counts.get("encode_block").copied().unwrap_or(0);
    assert!(blocks >= 1, "no encode_block events: {counts:?}");
    assert!(
        counts.get("checkpoint_write").copied().unwrap_or(0) >= 1,
        "checkpointed run logged no checkpoint_write: {counts:?}"
    );

    // 3. metrics snapshot: registries present, values reconcile
    let m = Json::parse(
        &std::fs::read_to_string(&metrics).expect("read metrics"),
    )
    .expect("metrics snapshot must parse");
    assert!(m.get("ts_us").unwrap().as_f64().unwrap() >= 0.0);
    let counters = m.get("counters").unwrap().as_obj().unwrap();
    assert_eq!(
        counters.get("blocks_encoded").unwrap().as_usize().unwrap(),
        blocks,
        "counter and event log disagree on blocks encoded"
    );
    assert!(counters.get("train_steps").unwrap().as_usize().unwrap() >= 2);
    assert!(m.get("gauges").unwrap().as_obj().is_ok());

    // 4. Chrome trace: a JSON array of named events, at least one complete
    let t = Json::parse(&std::fs::read_to_string(&trace).expect("read trace"))
        .expect("trace must be valid JSON");
    let arr = t.as_arr().expect("trace must be a JSON array");
    assert!(!arr.is_empty());
    let mut complete = 0usize;
    for e in arr {
        assert!(e.get("ph").unwrap().as_str().is_ok());
        assert!(e.get("name").unwrap().as_str().is_ok());
        if e.get("ph").unwrap().as_str().unwrap() == "X" {
            complete += 1;
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
    assert!(complete >= 1, "no complete spans in the trace");

    for p in [plain, instr, events, metrics, trace, ckpt] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn serve_runs_with_all_sinks() {
    let mrc = tmp_path("serve.mrc");
    let events = tmp_path("serve_events.jsonl");
    let metrics = tmp_path("serve_metrics.json");
    let trace = tmp_path("serve_trace.json");
    run_compress(&mrc, &[]);

    let output = miracle_bin()
        .args(["serve", "--mrc"])
        .arg(&mrc)
        .args([
            "--clients",
            "2",
            "--requests",
            "8",
            "--heartbeat-ms",
            "1",
            "--events-out",
            events.to_str().unwrap(),
            "--metrics-out",
            metrics.to_str().unwrap(),
            "--metrics-every",
            "1",
            "--trace-out",
            trace.to_str().unwrap(),
        ])
        .output()
        .expect("spawn miracle serve");
    assert!(
        output.status.success(),
        "serve failed: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(stdout.contains("latency:"), "no ledger printed:\n{stdout}");
    assert!(
        stdout.contains("[serve]"),
        "--heartbeat-ms 1 printed no heartbeat:\n{stdout}"
    );

    let counts = event_counts(&events);
    assert_eq!(counts.get("run_start"), Some(&1), "{counts:?}");

    let m = Json::parse(
        &std::fs::read_to_string(&metrics).expect("read metrics"),
    )
    .expect("metrics snapshot must parse");
    let counters = m.get("counters").unwrap().as_obj().unwrap();
    assert_eq!(
        counters.get("serve_served").unwrap().as_usize().unwrap(),
        16,
        "2 clients x 8 requests should all be served"
    );
    // the final snapshot (written by obs::finish) has empty `live` extras,
    // but the registries must still reconcile
    assert!(m.get("live").unwrap().as_obj().is_ok());

    let t = Json::parse(&std::fs::read_to_string(&trace).expect("read trace"))
        .expect("trace must be valid JSON");
    assert!(!t.as_arr().unwrap().is_empty());

    for p in [mrc, events, metrics, trace] {
        let _ = std::fs::remove_file(p);
    }
}
