//! Golden-format regression tests: canonical `.mrc` fixtures committed
//! under `tests/data/` pin the byte-level container layout. If either
//! serializer drifts — even by one bit — these fail, which is the point:
//! every `.mrc` ever written must stay readable, and v1 files must keep
//! decoding unchanged.
//!
//! The decoded-weight hash is bless-on-absent: the expected-hash file is
//! written on the first run (weights depend on the platform's libm-exact
//! float behavior, so the hash cannot be authored by hand) and compared on
//! every run after.

use miracle::codec::{BackendFamily, MrcFile};
use miracle::coordinator;
use miracle::runtime::{self, Runtime};

const TINY_V1: &[u8] = include_bytes!("data/tiny_v1.mrc");
const TINY_V2: &[u8] = include_bytes!("data/tiny_v2.mrc");

fn expected() -> MrcFile {
    MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0x4D31_7261,
        protocol_seed: 7,
        backend: BackendFamily::Native,
        b: 22,
        s: 8,
        k_chunk: 64,
        c_loc_bits: 10,
        lsp: vec![-1.5, -2.25],
        indices: (0..22u64).map(|i| (i * 37 + 11) % 1024).collect(),
    }
}

#[test]
fn v1_fixture_parses_to_the_expected_struct() {
    assert_eq!(MrcFile::version_of(TINY_V1).unwrap(), 1);
    let m = MrcFile::from_bytes(TINY_V1).unwrap();
    assert_eq!(m, expected());
}

#[test]
fn v2_fixture_parses_to_the_expected_struct() {
    assert_eq!(MrcFile::version_of(TINY_V2).unwrap(), 2);
    let m = MrcFile::from_bytes(TINY_V2).unwrap();
    assert_eq!(m, expected());
}

#[test]
fn serializers_reproduce_the_fixtures_byte_for_byte() {
    let m = expected();
    assert_eq!(m.to_bytes_v1(), TINY_V1, "v1 writer drifted from the fixture");
    assert_eq!(m.to_bytes(), TINY_V2, "v2 writer drifted from the fixture");
}

#[test]
fn both_revisions_decode_to_identical_weights() {
    // upgrading the container revision must not change a single weight
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let w1 = coordinator::decode_model(&arts, &MrcFile::from_bytes(TINY_V1).unwrap())
        .unwrap();
    let w2 = coordinator::decode_model(&arts, &MrcFile::from_bytes(TINY_V2).unwrap())
        .unwrap();
    assert_eq!(w1, w2);
    assert!(w1.iter().any(|&v| v != 0.0));
    assert!(w1.iter().all(|v| v.is_finite()));
}

/// FNV-1a over the exact bit patterns of the decoded weights.
fn weight_hash(w: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in w {
        for byte in v.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[test]
fn decoded_weight_hash_matches_blessed_value() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let mrc = MrcFile::from_bytes(TINY_V2).unwrap();
    let w = coordinator::decode_model(&arts, &mrc).unwrap();
    let got = format!("{:016x}", weight_hash(&w));

    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/data/tiny_weights.fnv1a"
    );
    match std::fs::read_to_string(path) {
        Ok(blessed) => assert_eq!(
            got,
            blessed.trim(),
            "decoded weights changed: the shared-randomness replay no longer \
             reproduces the blessed model (delete {path} only if the change \
             is intentional)"
        ),
        Err(_) => {
            std::fs::write(path, format!("{got}\n")).unwrap();
            eprintln!("blessed decoded-weight hash {got} -> {path}");
        }
    }
}
