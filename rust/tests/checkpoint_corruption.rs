//! Adversarial robustness of the MCK2 checkpoint loader.
//!
//! A checkpoint that loads wrong is worse than one that refuses to load: a
//! silently-altered snapshot resumes into a *different* encode stream and
//! emits a valid-looking `.mrc` for the wrong model. This suite drives the
//! loader with every truncation, every single-bit flip, a seeded mutation
//! sweep and the exhaustive mid-write crash plan (torn tails), asserting
//! the contract: **every mutated container either fails with a structured
//! one-line [`CkptError`] or parses byte-identically** — never a panic,
//! never an unbounded allocation, never a silently different resume.
//!
//! The seed matches CI's fuzz-decode runs (20260807) so a failure here
//! reproduces from the fault description alone.

use miracle::coordinator::{Checkpoint, CkptError};
use miracle::util::faultline::{self, Fault};

const SEED: u64 = 20260807;
const FP: u64 = 0xD15C_B10C_5EED_0001;

/// A mid-run snapshot with tiny_mlp geometry (22 blocks of 8 slots, 7
/// encoded) — no runtime or training needed to exercise the container.
fn sample_ckpt() -> Checkpoint {
    let n = 22 * 8;
    Checkpoint {
        model: "tiny_mlp".into(),
        b: 22,
        s: 8,
        n_layers: 2,
        step: 120,
        mu: (0..n).map(|i| i as f32 * 0.01 - 0.5).collect(),
        rho: vec![-3.0; n],
        lsp: vec![-1.5, -2.25],
        m_mu: vec![0.01; n],
        v_mu: vec![0.02; n],
        m_rho: vec![0.03; n],
        v_rho: vec![0.04; n],
        m_lsp: vec![0.05; 2],
        v_lsp: vec![0.06; 2],
        beta: vec![1e-6; 22],
        frozen_mask: (0..n).map(|i| if i < 7 * 8 { 1.0 } else { 0.0 }).collect(),
        frozen_w: vec![0.125; n],
        indices: (0..22u64)
            .map(|i| if i < 7 { (i * 37 + 11) % 1024 } else { u64::MAX })
            .collect(),
        last_kl: vec![4.25; 22],
        kl_bits_sum: 70.5,
        history: vec![],
    }
}

fn container() -> Vec<u8> {
    sample_ckpt().to_container_bytes(FP)
}

/// The corruption contract for one mutated buffer: a structured one-line
/// error, or a parse identical to the reference. Returns whether it parsed.
fn assert_contract(mutated: &[u8], reference: &Checkpoint, what: &str) -> bool {
    match Checkpoint::from_container_bytes(mutated) {
        Err(e) => {
            let msg = e.to_string();
            assert!(
                !msg.is_empty() && !msg.contains('\n'),
                "{what}: error must be one line, got {msg:?}"
            );
            false
        }
        Ok((parsed, fp)) => {
            assert!(
                parsed == *reference && fp == FP,
                "{what}: SILENT CORRUPTION — parse succeeded but differs"
            );
            true
        }
    }
}

#[test]
fn every_truncation_is_rejected() {
    let bytes = container();
    let reference = sample_ckpt();
    for len in 0..bytes.len() {
        let parsed = assert_contract(&bytes[..len], &reference, &format!("truncate to {len}"));
        assert!(!parsed, "a strict prefix ({len} bytes) must never parse");
    }
}

#[test]
fn every_single_bit_flip_is_caught_by_a_crc() {
    let bytes = container();
    let reference = sample_ckpt();
    for bit in 0..bytes.len() * 8 {
        let f = Fault::FlipBit { bit };
        let parsed = assert_contract(&f.apply(&bytes), &reference, &f.describe());
        assert!(!parsed, "flipped bit {bit} must not parse (CRC-protected)");
    }
}

#[test]
fn seeded_mutation_sweep_never_panics_or_silently_alters() {
    let bytes = container();
    let reference = sample_ckpt();
    let mut rejected = 0usize;
    for (i, f) in faultline::plan(SEED, 2000, bytes.len()).into_iter().enumerate() {
        let what = format!("seed {SEED} iter {i}: {}", f.describe());
        if !assert_contract(&f.apply(&bytes), &reference, &what) {
            rejected += 1;
        }
    }
    // single-byte/bit mutations and truncations of a CRC-protected
    // container are essentially always caught
    assert!(rejected >= 1990, "only {rejected}/2000 mutations rejected");
}

#[test]
fn exhaustive_crash_plan_has_no_usable_partial_state() {
    // every cut point a dying writer could leave behind, as both a short
    // file and a torn full-length file
    let bytes = container();
    let reference = sample_ckpt();
    for f in faultline::crash_plan(SEED, bytes.len()) {
        let mutated = f.apply(&bytes);
        let parsed = assert_contract(&mutated, &reference, &f.describe());
        // a torn tail can coincidentally reproduce the original bytes
        // (fill == original); identity is the only parse allowed
        if parsed {
            assert_eq!(mutated, bytes, "{}: non-identity parse", f.describe());
        }
    }
}

#[test]
fn garbage_and_foreign_magic_are_structured_errors() {
    assert!(matches!(
        Checkpoint::from_container_bytes(b"MRC2 definitely not a checkpoint"),
        Err(CkptError::NotCheckpoint { .. })
    ));
    assert!(matches!(
        Checkpoint::from_container_bytes(&[]),
        Err(CkptError::Truncated)
    ));
    let zeros = vec![0u8; 64];
    assert!(Checkpoint::from_container_bytes(&zeros).is_err());
}

#[test]
fn trailing_garbage_is_refused() {
    let mut bytes = container();
    bytes.extend_from_slice(b"xyz");
    assert_eq!(
        Checkpoint::from_container_bytes(&bytes),
        Err(CkptError::TrailingGarbage { extra_bytes: 3 })
    );
}

#[test]
fn fingerprint_mismatch_is_refused_with_both_values() {
    let dir = std::env::temp_dir();
    let path = dir.join("miracle_ckpt_fp_test.ckpt");
    let path = path.to_str().unwrap();
    let ck = sample_ckpt();
    ck.save(path, FP).unwrap();
    match Checkpoint::load_verified(path, FP ^ 1) {
        Err(CkptError::Fingerprint { stored, expected }) => {
            assert_eq!(stored, FP);
            assert_eq!(expected, FP ^ 1);
        }
        other => panic!("expected Fingerprint error, got {other:?}"),
    }
    // the right fingerprint still loads
    let loaded = Checkpoint::load_verified(path, FP).unwrap();
    assert_eq!(loaded, ck);
    let _ = std::fs::remove_file(path);
}

#[test]
fn durable_save_overwrites_atomically_and_cleans_its_tmp() {
    let dir = std::env::temp_dir();
    let path = dir.join("miracle_ckpt_atomic_test.ckpt");
    let path = path.to_str().unwrap();
    let ck = sample_ckpt();
    ck.save(path, FP).unwrap();
    // a second save over an existing checkpoint must succeed (rename
    // replaces) and leave no .tmp staging file behind
    let mut newer = sample_ckpt();
    newer.step = 121;
    newer.save(path, FP).unwrap();
    assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
    let (loaded, _) = Checkpoint::load(path).unwrap();
    assert_eq!(loaded.step, 121);
    let _ = std::fs::remove_file(path);
}

#[test]
fn missing_file_is_an_io_error_naming_the_path() {
    match Checkpoint::load("/nonexistent/dir/nope.ckpt") {
        Err(CkptError::Io { path, .. }) => {
            assert_eq!(path, "/nonexistent/dir/nope.ckpt")
        }
        other => panic!("expected Io error, got {other:?}"),
    }
}
