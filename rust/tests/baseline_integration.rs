//! Baselines against the dense tiny artifacts: train deterministically,
//! compress with each baseline, verify the quality/size trade-off is sane
//! and the eval path (eval_full) agrees with the block path.

use miracle::baselines::deepcomp::DeepCompCfg;
use miracle::baselines::bayescomp::BayesCompCfg;
use miracle::baselines::runner;
use miracle::coordinator::eval_error_full;
use miracle::data;
use miracle::runtime::{self, Runtime};

fn datasets() -> (data::Dataset, data::Dataset) {
    (
        data::synth_protos(512, 16, 4, 1234),
        data::synth_protos(512, 16, 4, 1234 ^ 0x7E57),
    )
}

#[test]
fn dense_training_learns_and_baselines_trade_off() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let post = runner::train_dense(&arts, &train, 600, 5e-3, 512.0, 7).unwrap();

    // the deterministic means classify well
    let err = eval_error_full(&arts, &post.mu_full, &test).unwrap();
    assert!(err < 0.15, "dense test error {err}");

    let points = runner::baseline_suite(
        &arts,
        &post,
        &test,
        &DeepCompCfg { sparsity: 0.5, clusters: 16, ..Default::default() },
        &BayesCompCfg::default(),
    )
    .unwrap();
    assert_eq!(points.len(), 4); // uncompressed, deep, weightless, bayes
    let uncompressed = &points[0];
    let deep = &points[1];
    assert_eq!(uncompressed.bits, arts.meta.n_total * 32);
    // compression achieved
    assert!(deep.bits < uncompressed.bits / 3, "deep bits {}", deep.bits);
    // bounded quality loss on this easy task
    assert!(
        deep.test_error <= uncompressed.test_error + 0.25,
        "deep err {} vs {}",
        deep.test_error,
        uncompressed.test_error
    );
}

#[test]
fn deepcomp_sweep_is_monotone_in_size() {
    let rt = Runtime::cpu().unwrap();
    let arts = runtime::load(&rt, "tiny_mlp").unwrap();
    let (train, test) = datasets();
    let post = runner::train_dense(&arts, &train, 400, 5e-3, 512.0, 8).unwrap();
    let pts = runner::deepcomp_sweep(
        &arts,
        &post,
        &test,
        &[(0.3, 32), (0.7, 16), (0.9, 8)],
    )
    .unwrap();
    assert!(pts[0].bits > pts[1].bits && pts[1].bits > pts[2].bits);
}
