//! SIMD dispatch parity suite (contract in `docs/perf.md`):
//!
//! * the bulk Pcg64 kernel is **bit-identical** to sequential `next_u64`
//!   on every available dispatch path — the property that makes `.mrc`
//!   decode bytes path-invariant;
//! * dispatched candidate scoring agrees with the scalar reference within
//!   the documented ulp tolerance and picks identical argmax candidates on
//!   seeded blocks;
//! * a full compress→`.mrc` run (subprocess, so the `MIRACLE_SIMD` env var
//!   is honored end to end) produces byte-identical containers under
//!   `scalar` and `auto`, and the committed golden fixture decodes
//!   identically under both;
//! * an invalid `MIRACLE_SIMD` is a hard error, not a silent fallback;
//! * `log_sum_exp` / `softmax_in_place` edge cases (empty, single-element,
//!   all `-inf`, NaN propagation) are pinned.

use std::process::Command;

use miracle::prng::{bulk, log_sum_exp, softmax_in_place, Pcg64};
use miracle::runtime::kernels;
use miracle::util::simd::{self, SimdPath};

/// Paths exercised on this machine: the reference plus whatever `auto`
/// resolves to (deduplicated when detection lands on scalar).
fn available_paths() -> Vec<SimdPath> {
    let mut v = vec![SimdPath::Scalar];
    if simd::detect() != SimdPath::Scalar {
        v.push(simd::detect());
    }
    v
}

// ---- (b) bulk Pcg64 bit-identity --------------------------------------

#[test]
fn bulk_u64s_bit_identical_to_sequential_next_u64() {
    for seed in [0u64, 7, 0xDEAD_BEEF, u64::MAX] {
        for n in [1usize, 3, 4, 5, 8, 13, 64, 257, 1024] {
            let mut seq_rng = Pcg64::seed(seed);
            let want: Vec<u64> = (0..n).map(|_| seq_rng.next_u64()).collect();
            // Pcg64::fill_u64s runs on the process-wide (auto) path
            let mut bulk_rng = Pcg64::seed(seed);
            let mut got = vec![0u64; n];
            bulk_rng.fill_u64s(&mut got);
            assert_eq!(got, want, "seed={seed} n={n}");
            // and the generators stay aligned afterwards
            assert_eq!(bulk_rng.next_u64(), seq_rng.next_u64());
        }
    }
}

#[test]
fn bulk_kernel_paths_agree_bit_for_bit() {
    for (state, inc) in [
        (0u64, 1u64),
        (0x853C_49E6_748F_EA9B, 0xDA3E_39CB_94B9_5BDB),
        (u64::MAX, u64::MAX),
    ] {
        for n in [1usize, 4, 7, 16, 33, 256, 4096] {
            let mut want = vec![0u64; n];
            let end =
                bulk::fill_u64s_with(SimdPath::Scalar, state, inc, &mut want);
            for p in available_paths() {
                let mut got = vec![0u64; n];
                let e = bulk::fill_u64s_with(p, state, inc, &mut got);
                assert_eq!(got, want, "path={p} state={state:#x} n={n}");
                assert_eq!(e, end, "end state diverged on path={p} n={n}");
            }
        }
    }
}

#[test]
fn normals_are_bit_identical_across_paths_via_auto_process() {
    // fill_normals_f32 consumes the bulk u64 stream; since that stream is
    // bit-identical on every path and Box–Muller itself stays scalar, the
    // normals this process (auto path) produces must equal sequential
    // next_normal draws exactly
    let mut a = Pcg64::seed(0xBEEF);
    let mut b = Pcg64::seed(0xBEEF);
    let mut bulk = vec![0f32; 1023];
    a.fill_normals_f32(&mut bulk);
    for (i, &x) in bulk.iter().enumerate() {
        let y = b.next_normal() as f32;
        assert_eq!(x.to_bits(), y.to_bits(), "normal {i}");
    }
}

// ---- (a) scoring parity + argmax --------------------------------------

fn seeded_block(s: usize, k: usize, seed: u64) -> (kernels::ScoreConsts, Vec<f32>) {
    let mut rng = Pcg64::seed(seed);
    let mk = |rng: &mut Pcg64, lo: f32, hi: f32, n: usize| -> Vec<f32> {
        (0..n).map(|_| lo + (hi - lo) * rng.next_f32()).collect()
    };
    let mu = mk(&mut rng, -0.5, 0.5, s);
    let rho = mk(&mut rng, -2.5, -0.5, s);
    let lsp = mk(&mut rng, -1.5, -0.5, s);
    let mask: Vec<f32> =
        (0..s).map(|j| if j % 11 == 5 { 0.0 } else { 1.0 }).collect();
    let zs = miracle::prng::normals_f32(&mut rng, k * s);
    (kernels::score_consts(&mu, &rho, &lsp, &mask), zs)
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[test]
fn dispatched_scoring_within_tolerance_and_same_argmax() {
    // S sweeps across vector-width boundaries (8-lane AVX2, 4-lane NEON)
    for (case, s) in [1usize, 3, 7, 8, 9, 16, 63, 128, 257].iter().enumerate()
    {
        let k = 128;
        let (c, zs) = seeded_block(*s, k, 0x51D0 + case as u64);
        let mut want = vec![0f32; k];
        kernels::score_rows_scalar(&c, &zs, &mut want);
        for p in available_paths() {
            let mut got = vec![0f32; k];
            kernels::score_rows_with(p, &c, &zs, &mut got);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                // documented tolerance (docs/perf.md): relative 1e-5
                let tol = 1e-5 * (1.0 + a.abs());
                assert!(
                    (a - b).abs() <= tol,
                    "path={p} S={s} row {i}: scalar {a} vs {b}"
                );
            }
            assert_eq!(
                argmax(&want),
                argmax(&got),
                "argmax flipped on path={p} S={s}"
            );
        }
    }
}

// ---- (c)+(d) end-to-end under MIRACLE_SIMD ----------------------------

fn miracle_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_miracle"))
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("miracle_simd_parity_{}_{tag}.mrc", std::process::id()))
}

/// Training-free compress (i0=0, i=0): pure candidate scoring + encode, the
/// paths whose SIMD parity this file is about.
fn compress_with_simd(simd_val: &str, out: &std::path::Path) -> String {
    let output = miracle_bin()
        .env("MIRACLE_SIMD", simd_val)
        .args([
            "compress",
            "--model",
            "tiny_mlp",
            "--i0",
            "0",
            "--i",
            "0",
            "--c-loc-bits",
            "8",
            "--train-size",
            "64",
            "--test-size",
            "64",
            "--protocol-seed",
            "7",
            "--out",
        ])
        .arg(out)
        .output()
        .expect("spawn miracle compress");
    assert!(
        output.status.success(),
        "compress failed under MIRACLE_SIMD={simd_val}: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    String::from_utf8_lossy(&output.stdout).into_owned()
}

#[test]
fn compress_is_byte_identical_under_scalar_and_auto() {
    let out_scalar = tmp_path("scalar");
    let out_auto = tmp_path("auto");
    let stdout_scalar = compress_with_simd("scalar", &out_scalar);
    let stdout_auto = compress_with_simd("auto", &out_auto);
    assert!(
        stdout_scalar.contains("simd/threads:    scalar"),
        "compress did not report the scalar path:\n{stdout_scalar}"
    );
    assert!(
        stdout_auto
            .contains(&format!("simd/threads:    {}", simd::detect())),
        "compress did not report the auto-detected path:\n{stdout_auto}"
    );
    let bytes_scalar = std::fs::read(&out_scalar).unwrap();
    let bytes_auto = std::fs::read(&out_auto).unwrap();
    assert_eq!(
        bytes_scalar, bytes_auto,
        "`.mrc` bytes depend on the SIMD path — the shared-randomness or \
         selection contract is broken"
    );
    let _ = std::fs::remove_file(&out_scalar);
    let _ = std::fs::remove_file(&out_auto);
}

#[test]
fn golden_fixture_decodes_identically_under_scalar_and_auto() {
    let fixture =
        concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/tiny_v2.mrc");
    let run = |simd_val: &str| {
        let output = miracle_bin()
            .env("MIRACLE_SIMD", simd_val)
            .args(["eval", "--mrc", fixture, "--test-size", "256"])
            .output()
            .expect("spawn miracle eval");
        assert!(
            output.status.success(),
            "eval failed under MIRACLE_SIMD={simd_val}: {}",
            String::from_utf8_lossy(&output.stderr)
        );
        String::from_utf8_lossy(&output.stdout).into_owned()
    };
    let scalar = run("scalar");
    let auto = run("auto");
    assert_eq!(
        scalar, auto,
        "decoding the golden fixture differs between SIMD paths"
    );
    assert!(scalar.contains("test error"), "unexpected output: {scalar}");
}

#[test]
fn invalid_miracle_simd_is_a_hard_error() {
    let out = tmp_path("invalid");
    let output = miracle_bin()
        .env("MIRACLE_SIMD", "turbo")
        .args([
            "compress", "--model", "tiny_mlp", "--i0", "0", "--i", "0",
            "--c-loc-bits", "3", "--train-size", "8", "--test-size", "8",
            "--out",
        ])
        .arg(&out)
        .output()
        .expect("spawn miracle compress");
    assert!(
        !output.status.success(),
        "MIRACLE_SIMD=turbo must fail loudly, not fall back"
    );
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        stderr.contains("MIRACLE_SIMD") && stderr.contains("turbo"),
        "error does not name the bad value: {stderr}"
    );
    assert!(!out.exists(), "no output may be written on a config error");
}

// ---- sampling edge cases ----------------------------------------------

#[test]
fn log_sum_exp_edge_cases() {
    // empty: no elements, the max fold is -inf and that is the answer
    assert_eq!(log_sum_exp(&[]), f64::NEG_INFINITY);
    // single element: lse == the element
    assert!((log_sum_exp(&[1.25]) - 1.25).abs() < 1e-12);
    // all -inf: still -inf (no NaN from inf - inf)
    assert_eq!(
        log_sum_exp(&[f32::NEG_INFINITY, f32::NEG_INFINITY]),
        f64::NEG_INFINITY
    );
    // NaN alongside finite values must propagate, not be silently dropped
    assert!(log_sum_exp(&[1.0, f32::NAN]).is_nan());
    // +inf dominates
    assert_eq!(log_sum_exp(&[0.0, f32::INFINITY]), f64::INFINITY);
}

#[test]
fn softmax_in_place_edge_cases() {
    // empty: no-op, normalizer -inf
    let mut xs: Vec<f32> = vec![];
    assert_eq!(softmax_in_place(&mut xs), f64::NEG_INFINITY);
    // single element: probability exactly 1
    let mut xs = vec![-3.5f32];
    let lse = softmax_in_place(&mut xs);
    assert_eq!(xs, vec![1.0]);
    assert!((lse + 3.5).abs() < 1e-6);
    // NaN input propagates into the normalizer and the outputs
    let mut xs = vec![0.0f32, f32::NAN];
    assert!(softmax_in_place(&mut xs).is_nan());
    assert!(xs.iter().all(|v| v.is_nan()));
    // uniform logits stay uniform and sum to 1
    let mut xs = vec![2.0f32; 8];
    softmax_in_place(&mut xs);
    for &v in &xs {
        assert!((v - 0.125).abs() < 1e-6);
    }
}
