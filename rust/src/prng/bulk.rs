//! Bulk PCG-XSH-RR 64/32 generation — the vectorizable integer core of the
//! candidate hot path.
//!
//! [`fill_u64s`] produces exactly the sequence `n` repeated
//! [`super::Pcg64::next_u64`] calls would (each output is two 32-bit PCG
//! draws, high word first) and returns the advanced LCG state, so the
//! generator object stays bit-aligned with sequential use. The LCG advance
//! `s' = a·s + c (mod 2^64)` is closed under composition
//! (`k` steps = `a^k·s + (a^{k-1}+…+1)·c`), which is what makes the AVX2
//! variant possible: four u64 lanes each hold a state offset by one draw
//! and jump eight draws per iteration. Integer arithmetic only — the
//! vector path is **bit-identical** to the scalar one, not merely close,
//! so `.mrc` decode bytes can never depend on the dispatch path
//! (`rust/tests/simd_parity.rs` proves it draw-for-draw).
//!
//! aarch64 note: NEON has no 64-bit vector multiply, so the `neon` path
//! uses the scalar loop (the compiler schedules it well); the dispatch
//! exists so the selection stays uniform across kernels.
//!
//! Safety policy: intrinsic blocks live behind
//! `#[deny(unsafe_op_in_unsafe_fn)]` with a SAFETY comment per `unsafe`
//! block; the only unsafe operations are the 32-byte stores into a local
//! scratch array and the feature-gated call itself (CPU support is
//! verified by [`crate::util::simd::detect`] before this path is ever
//! selected).

#![deny(unsafe_op_in_unsafe_fn)]

use crate::util::simd::{self, SimdPath};

/// The PCG64 LCG multiplier (Knuth's MMIX constant) — shared with
/// [`super::Pcg64::next_u32`] so the scalar generator and the bulk kernels
/// cannot drift apart.
pub(crate) const PCG_MUL: u64 = 6364136223846793005;

/// One 32-bit PCG-XSH-RR output from a pre-advance state.
#[inline]
fn pcg_out32(old: u64) -> u32 {
    let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
    xorshifted.rotate_right((old >> 59) as u32)
}

/// Scalar reference: fill `out` with u64 draws from `(state, inc)` exactly
/// as sequential `next_u64` calls would; returns the advanced state.
pub fn fill_u64s_scalar(mut state: u64, inc: u64, out: &mut [u64]) -> u64 {
    for o in out.iter_mut() {
        let hi = pcg_out32(state) as u64;
        state = state.wrapping_mul(PCG_MUL).wrapping_add(inc);
        let lo = pcg_out32(state) as u64;
        state = state.wrapping_mul(PCG_MUL).wrapping_add(inc);
        *o = (hi << 32) | lo;
    }
    state
}

/// Dispatched bulk generation (see module docs for the bit-exactness
/// contract). `path` is normally [`simd::active`]; parity tests pass
/// explicit paths.
pub fn fill_u64s_with(
    path: SimdPath,
    state: u64,
    inc: u64,
    out: &mut [u64],
) -> u64 {
    match path {
        SimdPath::Scalar | SimdPath::Neon => {
            fill_u64s_scalar(state, inc, out)
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `SimdPath::Avx2` is only ever selected after
        // `is_x86_feature_detected!("avx2")` succeeded (util/simd.rs), so
        // the target-feature call contract holds.
        SimdPath::Avx2 => unsafe { x86::fill_u64s_avx2(state, inc, out) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdPath::Avx2 => fill_u64s_scalar(state, inc, out),
    }
}

/// [`fill_u64s_with`] on the process-wide dispatch path.
pub fn fill_u64s(state: u64, inc: u64, out: &mut [u64]) -> u64 {
    fill_u64s_with(simd::active(), state, inc, out)
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 lane plan: two vectors of four u64 states, offset 0..=7 draws
    //! from the entry state; each iteration emits their eight 32-bit
    //! outputs (packed as four u64 results, high word first) and jumps
    //! every lane eight draws via the composed LCG `(a^8, Σa^i·c)`.

    use super::PCG_MUL;
    use core::arch::x86_64::*;

    /// `(a^j, Σ_{t<j} a^t)` for `j = 0..=8`: state after `j` draws is
    /// `a^j·s + Σ·inc` (all mod 2^64).
    fn lcg_powers() -> ([u64; 9], [u64; 9]) {
        let mut a = [0u64; 9];
        let mut csum = [0u64; 9];
        a[0] = 1;
        for j in 1..=8 {
            a[j] = a[j - 1].wrapping_mul(PCG_MUL);
            csum[j] = csum[j - 1].wrapping_mul(PCG_MUL).wrapping_add(1);
        }
        (a, csum)
    }

    /// Lane-wise low-64 product (AVX2 has no 64-bit multiply; compose it
    /// from the 32×32→64 `mul_epu32` partial products).
    #[target_feature(enable = "avx2")]
    #[inline]
    fn mullo_epi64(a: __m256i, b: __m256i) -> __m256i {
        let lo = _mm256_mul_epu32(a, b);
        let t1 = _mm256_mul_epu32(_mm256_srli_epi64::<32>(a), b);
        let t2 = _mm256_mul_epu32(a, _mm256_srli_epi64::<32>(b));
        let hi = _mm256_slli_epi64::<32>(_mm256_add_epi64(t1, t2));
        _mm256_add_epi64(lo, hi)
    }

    /// The XSH-RR output of four pre-advance states, one u32 per u64 lane
    /// (low 32 bits). The variable rotate is `(x >> r) | (x << (32 - r))`
    /// masked back to 32 bits; at `r = 0` the left term shifts into the
    /// cleared upper half, so no special case is needed.
    #[target_feature(enable = "avx2")]
    #[inline]
    fn pcg_out32x4(s: __m256i) -> __m256i {
        let mask32 = _mm256_set1_epi64x(0xffff_ffff);
        let t = _mm256_xor_si256(_mm256_srli_epi64::<18>(s), s);
        let xs = _mm256_and_si256(_mm256_srli_epi64::<27>(t), mask32);
        let rot = _mm256_srli_epi64::<59>(s);
        let right = _mm256_srlv_epi64(xs, rot);
        let left =
            _mm256_sllv_epi64(xs, _mm256_sub_epi64(_mm256_set1_epi64x(32), rot));
        _mm256_and_si256(_mm256_or_si256(right, left), mask32)
    }

    /// AVX2 bulk generation — bit-identical to
    /// [`super::fill_u64s_scalar`]; the tail (< 4 u64s) runs scalar.
    #[target_feature(enable = "avx2")]
    pub fn fill_u64s_avx2(state: u64, inc: u64, out: &mut [u64]) -> u64 {
        let n = out.len();
        if n < 4 {
            return super::fill_u64s_scalar(state, inc, out);
        }
        let (a, csum) = lcg_powers();
        let lane = |j: usize| {
            a[j].wrapping_mul(state)
                .wrapping_add(csum[j].wrapping_mul(inc)) as i64
        };
        let mut v0 = _mm256_setr_epi64x(lane(0), lane(1), lane(2), lane(3));
        let mut v1 = _mm256_setr_epi64x(lane(4), lane(5), lane(6), lane(7));
        let a8 = _mm256_set1_epi64x(a[8] as i64);
        let c8 = _mm256_set1_epi64x(csum[8].wrapping_mul(inc) as i64);
        let mut s = state;
        let mut tmp = [0u64; 8];
        let mut i = 0usize;
        while i + 4 <= n {
            let o0 = pcg_out32x4(v0);
            let o1 = pcg_out32x4(v1);
            // SAFETY: `tmp` is 8 u64s (64 bytes); the two unaligned
            // 32-byte stores cover exactly its first and second halves.
            unsafe {
                _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, o0);
                _mm256_storeu_si256(
                    tmp.as_mut_ptr().add(4) as *mut __m256i,
                    o1,
                );
            }
            // pack pairs of 32-bit draws, high word first (next_u64 order)
            out[i] = (tmp[0] << 32) | tmp[1];
            out[i + 1] = (tmp[2] << 32) | tmp[3];
            out[i + 2] = (tmp[4] << 32) | tmp[5];
            out[i + 3] = (tmp[6] << 32) | tmp[7];
            v0 = _mm256_add_epi64(mullo_epi64(v0, a8), c8);
            v1 = _mm256_add_epi64(mullo_epi64(v1, a8), c8);
            s = a[8].wrapping_mul(s).wrapping_add(csum[8].wrapping_mul(inc));
            i += 4;
        }
        super::fill_u64s_scalar(s, inc, &mut out[i..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drawn via `Pcg64` so the reference covers the real consumption.
    fn reference(seed: u64, n: usize) -> (Vec<u64>, crate::prng::Pcg64) {
        let mut rng = crate::prng::Pcg64::seed(seed);
        let v = (0..n).map(|_| rng.next_u64()).collect();
        (v, rng)
    }

    #[test]
    fn scalar_kernel_matches_sequential_next_u64() {
        for seed in [0u64, 1, 42, 0xDEAD_BEEF] {
            for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 64, 129] {
                let (want, mut rng_after) = reference(seed, n);
                let mut rng = crate::prng::Pcg64::seed(seed);
                let mut got = vec![0u64; n];
                rng.fill_u64s(&mut got);
                assert_eq!(got, want, "seed={seed} n={n}");
                // the state advanced exactly as far as sequential draws
                assert_eq!(
                    rng.next_u64(),
                    rng_after.next_u64(),
                    "state desync: seed={seed} n={n}"
                );
            }
        }
    }

    #[test]
    fn every_available_path_is_bit_identical_to_scalar() {
        let paths = [SimdPath::Scalar, simd::detect()];
        for seed in [7u64, 0x5EED, u64::MAX] {
            for n in [1usize, 3, 4, 6, 8, 11, 16, 33, 256, 1000] {
                let mut rng = crate::prng::Pcg64::seed(seed);
                let (state, inc) = rng.raw_state();
                let mut want = vec![0u64; n];
                let end =
                    fill_u64s_scalar(state, inc, &mut want);
                for p in paths {
                    let mut got = vec![0u64; n];
                    let e = fill_u64s_with(p, state, inc, &mut got);
                    assert_eq!(got, want, "path={p} seed={seed} n={n}");
                    assert_eq!(e, end, "end state: path={p} seed={seed} n={n}");
                }
            }
        }
    }
}
