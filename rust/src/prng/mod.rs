//! Deterministic PRNGs and sampling for the coordinator and the native
//! backend.
//!
//! On the PJRT backend the *protocol* randomness (candidate weight
//! generation) lives inside the AOT-compiled jax graphs (threefry, replayed
//! identically by encoder and decoder — see
//! `python/compile/model.py::_chunk_candidates`). On the native backend the
//! same role is played by [`candidate_stream`], which mirrors jax's
//! `fold_in` seed-tree derivation over [`Pcg64`]: encoder and decoder both
//! derive the (seed, block, chunk) stream from here, so shared randomness
//! holds by construction. [`eps_stream`] is the `PRNGKey(seed)` analogue
//! for reparameterization noise. The remaining PRNGs serve dataset
//! synthesis, parameter init, block permutations, the encoder's categorical
//! draw, and the mini property-test framework.
//!
//! Determinism scope: the integer streams are bit-stable everywhere; the
//! *normal* draws go through platform libm (`ln`, `sin_cos`), so replay is
//! guaranteed per platform/toolchain but not CI-verified across platforms —
//! decode a `.mrc` on the platform family that encoded it (see
//! `docs/adr/001-backend-abstraction.md`).

pub mod bulk;
pub mod sampling;

pub use sampling::{
    categorical_from_logits, log_sum_exp, softmax_in_place, StreamingCategorical,
};

/// SplitMix64 — used for seeding and cheap hashing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn seed(s: u64) -> SplitMix64 {
        SplitMix64 { state: s }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Stateless 64-bit mix — deterministic hashing for the hashing trick.
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 with 128-bit-free state (two u64 words), good enough
/// statistical quality for experiment workloads.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

impl Pcg64 {
    pub fn seed(s: u64) -> Pcg64 {
        let mut sm = SplitMix64::seed(s);
        let mut p = Pcg64 {
            state: sm.next_u64(),
            inc: sm.next_u64() | 1,
            spare_normal: None,
        };
        p.next_u32();
        p
    }

    /// Derive an independent stream (seed tree).
    pub fn fold_in(&self, tag: u64) -> Pcg64 {
        Pcg64::seed(mix64(self.state ^ mix64(tag ^ self.inc)))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(bulk::PCG_MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Fill `out` with the exact sequence repeated [`Pcg64::next_u64`]
    /// calls would produce, through the dispatched bulk kernel
    /// ([`bulk::fill_u64s`] — bit-identical on every SIMD path).
    pub fn fill_u64s(&mut self, out: &mut [u64]) {
        self.state = bulk::fill_u64s(self.state, self.inc, out);
    }

    /// The raw `(state, inc)` pair, for the bulk-kernel parity tests.
    pub(crate) fn raw_state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection to remove modulo bias
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// One Box-Muller pair. Factored out so the bulk/skip paths consume the
    /// uniform stream identically to repeated [`Pcg64::next_normal`] calls.
    fn box_muller_pair(&mut self) -> (f64, f64) {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            return (r * c, r * s);
        }
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (a, b) = self.box_muller_pair();
        self.spare_normal = Some(b);
        a
    }

    /// Fill `out` with standard normals as f32 — the exact sequence repeated
    /// [`Pcg64::next_normal`] calls would produce, minus the per-draw spare
    /// bookkeeping (the candidate hot path's bulk generator).
    ///
    /// The uniforms come from [`Pcg64::fill_u64s`] in buffered batches, so
    /// the integer half of the work runs on the dispatched SIMD kernel
    /// while the Box–Muller transform (libm `ln`/`sin_cos`) stays scalar —
    /// the outputs are therefore bit-identical across SIMD paths. The
    /// batch size is capped at the *minimum* draws the remaining outputs
    /// can consume (a rejected `u1` just triggers another batch), so the
    /// generator never advances past what sequential draws would use.
    pub fn fill_normals_f32(&mut self, out: &mut [f32]) {
        #[inline]
        fn to_unit(u: u64) -> f64 {
            // same mapping as next_f64
            (u >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
        }
        let n = out.len();
        let mut i = 0usize;
        if i < n {
            if let Some(z) = self.spare_normal.take() {
                out[i] = z as f32;
                i += 1;
            }
        }
        const BUF: usize = 256;
        let mut buf = [0u64; BUF];
        // a u1 that passed rejection but whose u2 missed the last batch
        let mut pending_u1: Option<f64> = None;
        while i < n {
            // ceil((n - i) / 2) full Box–Muller pairs still to compute
            // (the final odd output also burns a full pair, like
            // box_muller_pair does)
            let pairs_left = (n - i + 1) / 2;
            let want = 2 * pairs_left - usize::from(pending_u1.is_some());
            let take = want.min(BUF);
            let batch = &mut buf[..take];
            self.fill_u64s(batch);
            let mut k = 0usize;
            while k < take {
                let u1 = match pending_u1.take() {
                    Some(u) => u,
                    None => {
                        let u = to_unit(batch[k]);
                        k += 1;
                        u
                    }
                };
                if u1 <= f64::MIN_POSITIVE {
                    // rejected — redraw u1 (identical to box_muller_pair)
                    continue;
                }
                if k == take {
                    pending_u1 = Some(u1);
                    break;
                }
                let u2 = to_unit(batch[k]);
                k += 1;
                let r = (-2.0 * u1.ln()).sqrt();
                let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
                out[i] = (r * c) as f32;
                i += 1;
                if i < n {
                    out[i] = (r * s) as f32;
                    i += 1;
                } else {
                    self.spare_normal = Some(r * s);
                    return;
                }
            }
        }
    }

    /// Advance the stream past `n` normal draws without materializing them.
    /// Bit-exact with drawing and discarding — the uniform consumption
    /// (including the Box-Muller rejection branch) is replayed precisely —
    /// but full pairs skip the `ln`/`sin_cos` calls entirely, which is what
    /// makes single-candidate decode cheap (see `decode_block` in
    /// `runtime/native.rs`).
    ///
    /// The uniforms are consumed in batches through [`Pcg64::fill_u64s`]
    /// (the dispatched SIMD bulk kernel), so a skip is one LCG sweep rather
    /// than per-draw `next_u64` calls. The Box–Muller rejection test on the
    /// 53-bit uniform `to_unit(u) <= f64::MIN_POSITIVE` is equivalent to
    /// the pure-integer `(u >> 11) == 0` (a non-zero 53-bit mantissa yields
    /// at least 2⁻⁵³ ≫ `MIN_POSITIVE`), so skipping never touches float
    /// math at all for full pairs. Each batch is sized at the *minimum*
    /// draws the remaining pairs must consume — rejections simply trigger
    /// another batch — so the generator can never advance past what
    /// sequential draws would use.
    pub fn skip_normals(&mut self, mut n: usize) {
        if n > 0 && self.spare_normal.take().is_some() {
            n -= 1;
        }
        const BUF: usize = 256;
        let mut buf = [0u64; BUF];
        // an accepted u1 whose u2 missed the last batch
        let mut have_u1 = false;
        while n >= 2 {
            // 2 draws per remaining full pair, minus the carried u1
            let need = 2 * (n / 2) - usize::from(have_u1);
            let take = need.min(BUF);
            let batch = &mut buf[..take];
            self.fill_u64s(batch);
            for &u in batch.iter() {
                if !have_u1 {
                    // rejection iff the 53-bit uniform is exactly zero
                    have_u1 = (u >> 11) != 0;
                } else {
                    have_u1 = false;
                    n -= 2;
                }
            }
        }
        if n == 1 {
            let _ = self.next_normal();
        }
    }

    /// Sequential reference for [`Pcg64::skip_normals`] — one uniform at a
    /// time, exactly as the pre-bulk implementation drew them. Kept only to
    /// pin the bulk path bit-for-bit.
    #[cfg(test)]
    fn skip_normals_seq(&mut self, mut n: usize) {
        if n > 0 && self.spare_normal.take().is_some() {
            n -= 1;
        }
        while n >= 2 {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let _u2 = self.next_f64();
            n -= 2;
        }
        if n == 1 {
            let _ = self.next_normal();
        }
    }

    /// Fisher-Yates permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }

    /// Sample Gumbel(0,1).
    pub fn next_gumbel(&mut self) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -(-u.ln()).ln()
    }
}

/// Domain-separation tags for the native backend's named random streams.
const TAG_PROTOCOL: u64 = 0x4D52_4331_5052_4F54; // "MRC1PROT"
const TAG_EPS: u64 = 0x4D52_4331_4550_5331; // "MRC1EPS1"

/// Protocol randomness for the native backend: the candidate generator
/// stream of `(protocol_seed, block, chunk)` — the jax
/// `fold_in(fold_in(PRNGKey(seed), block), chunk)` analogue. This derivation
/// is THE protocol constant shared by native encode and decode; changing it
/// invalidates every natively-encoded `.mrc`. The normals drawn from the
/// stream go through platform libm (see the module docs), so the replay
/// guarantee is per platform/toolchain.
pub fn candidate_stream(protocol_seed: i32, block: i32, chunk: i32) -> Pcg64 {
    Pcg64::seed(mix64(protocol_seed as u32 as u64 ^ TAG_PROTOCOL))
        .fold_in(block as u32 as u64)
        .fold_in(chunk as u32 as u64)
}

/// Reparameterization-noise stream for the native backend (the
/// `jax.random.PRNGKey(seed)` analogue, shared by `train_step` and
/// `sample_weights`).
pub fn eps_stream(seed: i32) -> Pcg64 {
    Pcg64::seed(mix64(seed as u32 as u64 ^ TAG_EPS))
}

/// Draw `n` standard normals as f32 from a stream.
pub fn normals_f32(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; n];
    rng.fill_normals_f32(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seed(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fold_in_independent() {
        let base = Pcg64::seed(7);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
        // and reproducible
        let mut a2 = base.fold_in(0);
        assert_eq!(a2.next_u64(), xs[0]);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg64::seed(1);
        let n = 20000;
        let m: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_unbiased_range() {
        let mut r = Pcg64::seed(3);
        let mut counts = [0usize; 7];
        for _ in 0..7000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!(c > 700, "{counts:?}");
        }
    }

    #[test]
    fn candidate_stream_is_deterministic_per_coordinate() {
        let a = normals_f32(&mut candidate_stream(7, 3, 1), 16);
        let b = normals_f32(&mut candidate_stream(7, 3, 1), 16);
        assert_eq!(a, b);
        // any coordinate change moves the stream
        for (s, blk, ch) in [(8, 3, 1), (7, 4, 1), (7, 3, 2)] {
            let c = normals_f32(&mut candidate_stream(s, blk, ch), 16);
            assert_ne!(a, c, "stream collision at ({s},{blk},{ch})");
        }
    }

    #[test]
    fn eps_stream_differs_from_candidate_stream() {
        let a = normals_f32(&mut eps_stream(7), 16);
        let b = normals_f32(&mut candidate_stream(7, 0, 0), 16);
        assert_ne!(a, b);
        assert_eq!(a, normals_f32(&mut eps_stream(7), 16));
    }

    #[test]
    fn fill_normals_matches_sequential_draws() {
        // every (pre-fill offset, length) parity combination, including a
        // live spare from an odd number of prior draws
        for pre in 0..3usize {
            for len in [0usize, 1, 2, 5, 8, 33] {
                let mut a = Pcg64::seed(0xF17);
                let mut b = Pcg64::seed(0xF17);
                for _ in 0..pre {
                    let x = a.next_normal();
                    let y = b.next_normal();
                    assert_eq!(x.to_bits(), y.to_bits());
                }
                let mut bulk = vec![0f32; len];
                a.fill_normals_f32(&mut bulk);
                let seq: Vec<f32> =
                    (0..len).map(|_| b.next_normal() as f32).collect();
                assert_eq!(bulk, seq, "pre={pre} len={len}");
                // streams stay aligned afterwards
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
    }

    #[test]
    fn skip_normals_matches_draw_and_discard() {
        for pre in 0..3usize {
            for skip in [0usize, 1, 2, 3, 7, 64, 129] {
                let mut a = Pcg64::seed(0x5C1D);
                let mut b = Pcg64::seed(0x5C1D);
                for _ in 0..pre {
                    a.next_normal();
                    b.next_normal();
                }
                a.skip_normals(skip);
                for _ in 0..skip {
                    b.next_normal();
                }
                // the next draws must agree bit for bit
                for _ in 0..4 {
                    assert_eq!(
                        a.next_normal().to_bits(),
                        b.next_normal().to_bits(),
                        "pre={pre} skip={skip}"
                    );
                }
            }
        }
    }

    #[test]
    fn bulk_skip_is_bit_identical_to_sequential_skip() {
        // exercise batch boundaries (BUF=256 draws), odd tails, live spares,
        // and multi-batch skips
        for pre in 0..3usize {
            for skip in [0usize, 1, 2, 3, 7, 64, 129, 255, 256, 257, 513, 1000] {
                let mut a = Pcg64::seed(0xB01D ^ skip as u64);
                let mut b = a.clone();
                for _ in 0..pre {
                    a.next_normal();
                    b.next_normal();
                }
                a.skip_normals(skip);
                b.skip_normals_seq(skip);
                assert_eq!(
                    a.raw_state(),
                    b.raw_state(),
                    "generator state diverged: pre={pre} skip={skip}"
                );
                assert_eq!(
                    a.spare_normal.map(f64::to_bits),
                    b.spare_normal.map(f64::to_bits),
                    "spare diverged: pre={pre} skip={skip}"
                );
                for _ in 0..4 {
                    assert_eq!(
                        a.next_normal().to_bits(),
                        b.next_normal().to_bits(),
                        "pre={pre} skip={skip}"
                    );
                }
            }
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg64::seed(4);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
