//! Deterministic PRNGs and sampling for the coordinator.
//!
//! The *protocol* randomness (candidate weight generation) lives inside the
//! AOT-compiled jax graphs (threefry, replayed identically by encoder and
//! decoder — see `python/compile/model.py::_chunk_candidates`). The PRNGs
//! here serve everything else: dataset synthesis, parameter init, block
//! permutations, the encoder's categorical draw, and the mini property-test
//! framework. All are seed-stable across runs and platforms.

pub mod sampling;

pub use sampling::{
    categorical_from_logits, log_sum_exp, softmax_in_place, StreamingCategorical,
};

/// SplitMix64 — used for seeding and cheap hashing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn seed(s: u64) -> SplitMix64 {
        SplitMix64 { state: s }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Stateless 64-bit mix — deterministic hashing for the hashing trick.
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 with 128-bit-free state (two u64 words), good enough
/// statistical quality for experiment workloads.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

impl Pcg64 {
    pub fn seed(s: u64) -> Pcg64 {
        let mut sm = SplitMix64::seed(s);
        let mut p = Pcg64 {
            state: sm.next_u64(),
            inc: sm.next_u64() | 1,
            spare_normal: None,
        };
        p.next_u32();
        p
    }

    /// Derive an independent stream (seed tree).
    pub fn fold_in(&self, tag: u64) -> Pcg64 {
        Pcg64::seed(mix64(self.state ^ mix64(tag ^ self.inc)))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection to remove modulo bias
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Fisher-Yates permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }

    /// Sample Gumbel(0,1).
    pub fn next_gumbel(&mut self) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -(-u.ln()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seed(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fold_in_independent() {
        let base = Pcg64::seed(7);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
        // and reproducible
        let mut a2 = base.fold_in(0);
        assert_eq!(a2.next_u64(), xs[0]);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg64::seed(1);
        let n = 20000;
        let m: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_unbiased_range() {
        let mut r = Pcg64::seed(3);
        let mut counts = [0usize; 7];
        for _ in 0..7000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!(c > 700, "{counts:?}");
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg64::seed(4);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
