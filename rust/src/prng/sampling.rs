//! Categorical sampling from (streamed) logits — Algorithm 1 line 6.
//!
//! The encoder accumulates candidate logits chunk by chunk; sampling from the
//! normalized proxy distribution q̃ uses the Gumbel-max trick so the draw can
//! be made in one streaming pass without materializing the softmax:
//! `argmax_k (logit_k + G_k)` with iid Gumbel noise is an exact categorical
//! sample from softmax(logits).

use crate::prng::Pcg64;

/// Numerically stable log(sum(exp(xs))).
pub fn log_sum_exp(xs: &[f32]) -> f64 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    if !m.is_finite() {
        return m;
    }
    let s: f64 = xs.iter().map(|&x| ((x as f64) - m).exp()).sum();
    m + s.ln()
}

/// In-place softmax (stable). Returns the normalizer log-sum-exp.
pub fn softmax_in_place(xs: &mut [f32]) -> f64 {
    let lse = log_sum_exp(xs);
    for x in xs.iter_mut() {
        *x = ((*x as f64) - lse).exp() as f32;
    }
    lse
}

/// Exact categorical draw from softmax(logits) via Gumbel-max.
pub fn categorical_from_logits(rng: &mut Pcg64, logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        let v = l as f64 + rng.next_gumbel();
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Streaming Gumbel-max sampler: feed chunks of logits, read the argmax at
/// the end. Equivalent to `categorical_from_logits` over the concatenation.
pub struct StreamingCategorical {
    rng: Pcg64,
    offset: usize,
    best: usize,
    best_v: f64,
    /// running log-sum-exp of everything seen (for KL/overhead accounting)
    lse_max: f64,
    lse_sum: f64,
}

impl StreamingCategorical {
    pub fn new(rng: Pcg64) -> StreamingCategorical {
        StreamingCategorical {
            rng,
            offset: 0,
            best: 0,
            best_v: f64::NEG_INFINITY,
            lse_max: f64::NEG_INFINITY,
            lse_sum: 0.0,
        }
    }

    pub fn push(&mut self, logits: &[f32]) {
        for (i, &l) in logits.iter().enumerate() {
            let v = l as f64 + self.rng.next_gumbel();
            if v > self.best_v {
                self.best_v = v;
                self.best = self.offset + i;
            }
            let lf = l as f64;
            if lf > self.lse_max {
                // rescale running sum
                self.lse_sum = self.lse_sum * (self.lse_max - lf).exp();
                self.lse_max = lf;
            }
            self.lse_sum += (lf - self.lse_max).exp();
        }
        self.offset += logits.len();
    }

    pub fn total(&self) -> usize {
        self.offset
    }

    /// (sampled index, log-sum-exp of all logits)
    pub fn finish(self) -> (usize, f64) {
        (self.best, self.lse_max + self.lse_sum.ln())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lse_matches_naive() {
        let xs = [0.0f32, 1.0, 2.0, -3.0];
        let naive: f64 = xs.iter().map(|&x| (x as f64).exp()).sum::<f64>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-9);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![0.5f32, -1.0, 3.0, 3.0];
        softmax_in_place(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn categorical_frequencies() {
        // logits -> probs [0.0671, 0.1824, 0.4958, 0.2547] approx
        let logits = [0.0f32, 1.0, 2.0, 1.333];
        let mut probs = logits.to_vec();
        softmax_in_place(&mut probs);
        let mut rng = Pcg64::seed(11);
        let mut counts = [0usize; 4];
        let n = 40000;
        for _ in 0..n {
            counts[categorical_from_logits(&mut rng, &logits)] += 1;
        }
        for i in 0..4 {
            let freq = counts[i] as f64 / n as f64;
            assert!(
                (freq - probs[i] as f64).abs() < 0.01,
                "i={i} freq={freq} p={}",
                probs[i]
            );
        }
    }

    #[test]
    fn streaming_equals_batch() {
        let logits: Vec<f32> = (0..1000).map(|i| ((i * 37) % 17) as f32 / 5.0).collect();
        let mut s = StreamingCategorical::new(Pcg64::seed(5));
        for chunk in logits.chunks(64) {
            s.push(chunk);
        }
        let (idx_stream, lse_stream) = s.finish();
        let idx_batch = categorical_from_logits(&mut Pcg64::seed(5), &logits);
        assert_eq!(idx_stream, idx_batch);
        assert!((lse_stream - log_sum_exp(&logits)).abs() < 1e-9);
    }
}
