//! Greedy Rejection Sampling (Harsha et al., 2010) — paper Appendix A,
//! Algorithm 3 — over discretized distributions, plus coding-length
//! accounting with the Vitányi–Li prefix-free code (Eq. 15).
//!
//! This is the *exact but intractable* protocol that MIRACLE's Algorithm 1
//! approximates; the theory bench (`bench_coding_theory`) uses it to verify
//! the paper's bounds: unbiasedness, `E[log i*] <= KL(q||p) + O(1)` and
//! `E|l(i*)| <= KL + 2 log(KL + 1) + O(1)`.

use crate::bitstream::vitanyi_li_len;
use crate::prng::Pcg64;

/// A discrete distribution over `0..n` (probabilities sum to 1).
#[derive(Debug, Clone)]
pub struct Discrete {
    pub p: Vec<f64>,
}

impl Discrete {
    pub fn new(mut p: Vec<f64>) -> Discrete {
        let s: f64 = p.iter().sum();
        assert!(s > 0.0, "degenerate distribution");
        for v in p.iter_mut() {
            *v /= s;
        }
        Discrete { p }
    }

    /// Discretize a Gaussian N(mu, sigma^2) onto a symmetric grid of `n`
    /// points covering ±span (used to build q/p pairs with known KL).
    pub fn gauss(n: usize, mu: f64, sigma: f64, span: f64) -> Discrete {
        let p: Vec<f64> = (0..n)
            .map(|i| {
                let x = -span + 2.0 * span * (i as f64 + 0.5) / n as f64;
                let z = (x - mu) / sigma;
                (-0.5 * z * z).exp() / sigma
            })
            .collect();
        Discrete::new(p)
    }

    pub fn kl(&self, other: &Discrete) -> f64 {
        self.p
            .iter()
            .zip(&other.p)
            .filter(|(&q, _)| q > 0.0)
            .map(|(&q, &p)| q * (q / p).ln())
            .sum()
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let mut u = rng.next_f64();
        for (i, &p) in self.p.iter().enumerate() {
            u -= p;
            if u <= 0.0 {
                return i;
            }
        }
        self.p.len() - 1
    }
}

/// Result of one greedy-rejection encode.
#[derive(Debug, Clone, Copy)]
pub struct GrcSample {
    /// accepted sample value (index into the distribution's support)
    pub value: usize,
    /// accepted iteration index i* (the transmitted message)
    pub index: u64,
    /// prefix-free code length of i* in bits (Vitányi–Li)
    pub code_bits: usize,
}

/// Algorithm 3: greedy rejection sampling of one draw from `q` using shared
/// randomness that both sides derive from `rng` (candidates i.i.d. ~ p).
///
/// Tracks the residual mass vector over the whole support — this is exactly
/// why the paper calls it intractable for continuous, high-dimensional w; it
/// is perfectly fine for the discrete analysis here.
pub fn greedy_rejection_sample(q: &Discrete, p: &Discrete, rng: &mut Pcg64) -> GrcSample {
    let n = q.p.len();
    assert_eq!(n, p.p.len());
    // p_i(w) accumulated acceptance mass per value; p_star = sum
    let mut acc = vec![0f64; n];
    let mut p_star = 0f64;
    for i in 0u64.. {
        // alpha_i(w) = min(q(w) - p_{i-1}(w), (1 - p*_{i-1}) p(w))
        let wi = p.sample(rng);
        let alpha = (q.p[wi] - acc[wi]).min((1.0 - p_star) * p.p[wi]).max(0.0);
        let beta = if (1.0 - p_star) * p.p[wi] > 0.0 {
            alpha / ((1.0 - p_star) * p.p[wi])
        } else {
            0.0
        };
        let accept = rng.next_f64() <= beta;
        // update the bookkeeping for ALL values (the intractable part)
        let mut new_pstar = p_star;
        for w in 0..n {
            let a = (q.p[w] - acc[w]).min((1.0 - p_star) * p.p[w]).max(0.0);
            acc[w] += a;
            new_pstar += a;
        }
        p_star = new_pstar.min(1.0);
        if accept {
            return GrcSample {
                value: wi,
                index: i,
                code_bits: vitanyi_li_len(i),
            };
        }
        if i > 1_000_000 {
            // numerically stuck (q==p to machine precision); accept current
            return GrcSample { value: wi, index: i, code_bits: vitanyi_li_len(i) };
        }
    }
    unreachable!()
}

/// Instrumented variant of Algorithm 3: runs `iters` bookkeeping rounds
/// (without sampling) and returns the residual mass `q(w) - p_i(w)` per
/// value after each round — used to verify the Appendix A.1 convergence
/// invariant `q(w) - p_i(w) <= q(w) (1 - p(w))^i`.
pub fn greedy_rejection_residuals(
    q: &Discrete,
    p: &Discrete,
    iters: usize,
) -> Vec<Vec<f64>> {
    let n = q.p.len();
    let mut acc = vec![0f64; n];
    let mut p_star = 0f64;
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let mut new_pstar = p_star;
        for w in 0..n {
            let a = (q.p[w] - acc[w]).min((1.0 - p_star) * p.p[w]).max(0.0);
            acc[w] += a;
            new_pstar += a;
        }
        p_star = new_pstar.min(1.0);
        out.push(q.p.iter().zip(&acc).map(|(&qq, &a)| qq - a).collect());
    }
    out
}

/// MIRACLE's Algorithm 1 on the same discrete pair: draw K candidates from
/// p, reweight by q/p, sample the proxy  q̃. Returns (value, index, exact
/// proxy distribution over candidate slots for bias analysis).
pub fn minimal_random_code_sample(
    q: &Discrete,
    p: &Discrete,
    k: usize,
    rng: &mut Pcg64,
) -> (usize, usize, Vec<f64>, Vec<usize>) {
    let candidates: Vec<usize> = (0..k).map(|_| p.sample(rng)).collect();
    let mut weights: Vec<f64> = candidates
        .iter()
        .map(|&w| if p.p[w] > 0.0 { q.p[w] / p.p[w] } else { 0.0 })
        .collect();
    let s: f64 = weights.iter().sum();
    if s <= 0.0 {
        let idx = 0;
        return (candidates[idx], idx, vec![1.0 / k as f64; k], candidates);
    }
    for w in weights.iter_mut() {
        *w /= s;
    }
    let mut u = rng.next_f64();
    let mut idx = k - 1;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            idx = i;
            break;
        }
    }
    (candidates[idx], idx, weights, candidates)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qp(n: usize) -> (Discrete, Discrete) {
        let q = Discrete::gauss(n, 0.8, 0.5, 4.0);
        let p = Discrete::gauss(n, 0.0, 1.0, 4.0);
        (q, p)
    }

    #[test]
    fn kl_properties() {
        let (q, p) = qp(128);
        assert!(q.kl(&p) > 0.0);
        assert!(q.kl(&q).abs() < 1e-12);
    }

    #[test]
    fn grc_is_approximately_unbiased() {
        // empirical distribution of accepted values ~ q
        let (q, p) = qp(32);
        let mut rng = Pcg64::seed(1);
        let n = 20000;
        let mut counts = vec![0f64; 32];
        for _ in 0..n {
            let s = greedy_rejection_sample(&q, &p, &mut rng);
            counts[s.value] += 1.0;
        }
        let tv: f64 = counts
            .iter()
            .zip(&q.p)
            .map(|(&c, &qq)| (c / n as f64 - qq).abs())
            .sum::<f64>()
            / 2.0;
        assert!(tv < 0.03, "total variation {tv}");
    }

    #[test]
    fn grc_code_length_bound() {
        // E[|l(i*)|] <= KL + 2 log(KL+1) + O(1)  (Eq. 15); O(1) ~ few bits
        let (q, p) = qp(64);
        let kl_bits = q.kl(&p) / std::f64::consts::LN_2;
        let mut rng = Pcg64::seed(2);
        let n = 4000;
        let mean_bits: f64 = (0..n)
            .map(|_| greedy_rejection_sample(&q, &p, &mut rng).code_bits as f64)
            .sum::<f64>()
            / n as f64;
        let bound = kl_bits + 2.0 * (kl_bits + 1.0).log2() + 8.0;
        assert!(
            mean_bits <= bound,
            "mean {mean_bits} bits, KL {kl_bits} bits, bound {bound}"
        );
    }

    #[test]
    fn mrc_proxy_converges_with_k() {
        // E_q̃[f] -> E_q[f] as K grows (Theorem 3.2 flavor)
        let (q, p) = qp(64);
        let f = |w: usize| w as f64;
        let e_q: f64 = q.p.iter().enumerate().map(|(w, &qq)| f(w) * qq).sum();
        let mut err_small = 0f64;
        let mut err_large = 0f64;
        let trials = 300;
        for t in 0..trials {
            let mut rng = Pcg64::seed(100 + t);
            let (_, _, wts, cands) = minimal_random_code_sample(&q, &p, 4, &mut rng);
            let e4: f64 = wts
                .iter()
                .zip(&cands)
                .map(|(&w, &c)| w * f(c))
                .sum();
            err_small += (e4 - e_q).abs();
            let mut rng = Pcg64::seed(100 + t);
            let (_, _, wts, cands) = minimal_random_code_sample(&q, &p, 512, &mut rng);
            let e512: f64 = wts
                .iter()
                .zip(&cands)
                .map(|(&w, &c)| w * f(c))
                .sum();
            err_large += (e512 - e_q).abs();
        }
        assert!(
            err_large < err_small * 0.5,
            "err K=512 {err_large} vs K=4 {err_small}"
        );
    }

    #[test]
    fn residual_mass_bound_appendix_a1() {
        // q(w) - p_i(w) <= q(w) * (1 - p(w))^i   (Appendix A.1)
        let (q, p) = qp(48);
        let residuals = greedy_rejection_residuals(&q, &p, 200);
        for (i, res) in residuals.iter().enumerate() {
            for w in 0..q.p.len() {
                let bound = q.p[w] * (1.0 - p.p[w]).powi(i as i32 + 1);
                assert!(
                    res[w] <= bound + 1e-12,
                    "i={i} w={w}: residual {} > bound {bound}",
                    res[w]
                );
                assert!(res[w] >= -1e-12, "negative residual");
            }
        }
        // residual mass vanishes (unbiasedness in the limit)
        let total: f64 = residuals.last().unwrap().iter().sum();
        assert!(total < 1e-3, "residual mass {total}");
        // and it decreases monotonically round over round
        let sums: Vec<f64> = residuals.iter().map(|r| r.iter().sum()).collect();
        for w in sums.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn mrc_index_fits_log_k_bits() {
        let (q, p) = qp(64);
        let mut rng = Pcg64::seed(7);
        for _ in 0..100 {
            let (_, idx, _, _) = minimal_random_code_sample(&q, &p, 256, &mut rng);
            assert!(idx < 256);
        }
    }
}
