//! Baseline experiment driver: train a deterministic / variational net on a
//! *dense* (no-hashing) config, then push it through each baseline
//! compressor and measure (size, test error) — the rows Table 1 and the
//! curves Figure 1 compare MIRACLE against.

use crate::coordinator::{eval_error_full, MiracleCfg, Session};
use crate::data::Dataset;
use crate::runtime::ModelArtifacts;
use crate::util::Result;

use super::bayescomp::{bayes_compress, BayesCompCfg};
use super::deepcomp::{deep_compress, DeepCompCfg};
use super::weightless::{weightless_compress, WeightlessCfg};
use super::{uncompressed, CompressedWeights};

/// A (label, size bits, test error) measurement.
#[derive(Debug, Clone)]
pub struct BaselinePoint {
    pub label: String,
    pub bits: usize,
    pub test_error: f64,
}

/// Trained posterior on the dense config: per-position mean and stddev.
pub struct DensePosterior {
    pub mu_full: Vec<f32>,
    pub sigma_full: Vec<f32>,
}

/// Train the dense config variationally (mild KL pressure so sigmas are
/// informative for Bayes-Compression; the means alone are the deterministic
/// net for Deep Compression).
pub fn train_dense(
    arts: &ModelArtifacts,
    train: &Dataset,
    steps: usize,
    lr: f32,
    data_scale: f32,
    seed: u64,
) -> Result<DensePosterior> {
    let cfg = MiracleCfg {
        c_loc_bits: 16,         // generous budget: mild KL pressure
        i0: steps,
        i_intermediate: 0,
        lr,
        beta0: 1e-6,
        eps_beta: 2e-3,
        data_scale,
        layout_seed: seed ^ 0xDE,
        protocol_seed: 5,
        train_seed: seed,
        threads: 0,
    };
    let mut session = Session::new(arts, train, &cfg)?;
    for _ in 0..steps {
        session.train_step(true)?;
    }
    // assemble flat mean / sigma through the (bijective, dense) layout
    let mu_full = session.layout.assemble(&session.state.mu);
    let sigma_blocks: Vec<f32> = session.state.rho.iter().map(|r| r.exp()).collect();
    let sigma_full = session.layout.assemble(&sigma_blocks);
    Ok(DensePosterior { mu_full, sigma_full })
}

/// Evaluate one compressed weight-set.
pub fn measure(
    arts: &ModelArtifacts,
    c: &CompressedWeights,
    test: &Dataset,
) -> Result<BaselinePoint> {
    let err = eval_error_full(arts, &c.weights, test)?;
    Ok(BaselinePoint { label: c.descr.clone(), bits: c.bits, test_error: err })
}

/// The standard baseline suite at one operating point each.
pub fn baseline_suite(
    arts: &ModelArtifacts,
    post: &DensePosterior,
    test: &Dataset,
    deep_cfg: &DeepCompCfg,
    bayes_cfg: &BayesCompCfg,
) -> Result<Vec<BaselinePoint>> {
    let mut out = Vec::new();
    let un = uncompressed(&post.mu_full, false);
    out.push(BaselinePoint {
        label: "Uncompressed (fp32)".into(),
        bits: un.bits,
        test_error: eval_error_full(arts, &un.weights, test)?,
    });
    let dc = deep_compress(&post.mu_full, deep_cfg)?;
    out.push(measure(arts, &dc, test)?);
    let wl = weightless_compress(
        &post.mu_full,
        &WeightlessCfg {
            sparsity: deep_cfg.sparsity,
            clusters: deep_cfg.clusters,
            ..Default::default()
        },
    )?;
    out.push(measure(arts, &wl, test)?);
    let bc = bayes_compress(&post.mu_full, &post.sigma_full, bayes_cfg)?;
    out.push(measure(arts, &bc, test)?);
    Ok(out)
}

/// Sweep Weightless operating points (Figure 1 series).
pub fn weightless_sweep(
    arts: &ModelArtifacts,
    post: &DensePosterior,
    test: &Dataset,
    points: &[(f64, usize, u32)], // (sparsity, clusters, tag_bits)
) -> Result<Vec<BaselinePoint>> {
    points
        .iter()
        .map(|&(sparsity, clusters, tag_bits)| {
            let c = weightless_compress(
                &post.mu_full,
                &WeightlessCfg { sparsity, clusters, tag_bits, ..Default::default() },
            )?;
            measure(arts, &c, test)
        })
        .collect()
}

/// Sweep Deep Compression across operating points (Figure 1 series).
pub fn deepcomp_sweep(
    arts: &ModelArtifacts,
    post: &DensePosterior,
    test: &Dataset,
    points: &[(f64, usize)], // (sparsity, clusters)
) -> Result<Vec<BaselinePoint>> {
    points
        .iter()
        .map(|&(sparsity, clusters)| {
            let c = deep_compress(
                &post.mu_full,
                &DeepCompCfg { sparsity, clusters, ..Default::default() },
            )?;
            measure(arts, &c, test)
        })
        .collect()
}

/// Sweep Bayes-Compression thresholds (Figure 1 series).
pub fn bayescomp_sweep(
    arts: &ModelArtifacts,
    post: &DensePosterior,
    test: &Dataset,
    thresholds: &[f32],
) -> Result<Vec<BaselinePoint>> {
    thresholds
        .iter()
        .map(|&snr| {
            let c = bayes_compress(
                &post.mu_full,
                &post.sigma_full,
                &BayesCompCfg { snr_threshold: snr, step_scale: 1.0 },
            )?;
            measure(arts, &c, test)
        })
        .collect()
}
