//! Deep Compression pipeline (Han et al., 2016): magnitude pruning →
//! k-means quantization → Huffman coding of cluster indices and sparse
//! run lengths. Operates on a trained deterministic weight vector.

use super::kmeans::{kmeans_1d, reconstruct};
use super::prune::magnitude_prune;
use super::sparse::encode_sparse;
use super::CompressedWeights;
use crate::util::Result;

/// Operating point of the pipeline.
#[derive(Debug, Clone, Copy)]
pub struct DeepCompCfg {
    /// fraction of weights zeroed by magnitude pruning
    pub sparsity: f64,
    /// number of k-means clusters for the survivors
    pub clusters: usize,
    /// Lloyd iterations
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for DeepCompCfg {
    fn default() -> DeepCompCfg {
        DeepCompCfg { sparsity: 0.9, clusters: 32, kmeans_iters: 25, seed: 0 }
    }
}

/// Run the full pipeline; size accounting covers Huffman payload, both code
/// books, the centroid table (fp32 each) and the header.
pub fn deep_compress(weights: &[f32], cfg: &DeepCompCfg) -> Result<CompressedWeights> {
    let (pruned, _survivors) = magnitude_prune(weights, cfg.sparsity);
    let (centroids, assign) = kmeans_1d(&pruned, cfg.clusters, cfg.kmeans_iters, cfg.seed);
    let occupancy: Vec<bool> = pruned.iter().map(|&w| w != 0.0).collect();
    let symbols: Vec<u32> = assign
        .iter()
        .cloned()
        .filter(|&a| a != u32::MAX)
        .collect();

    let (bits, decoded) = if symbols.is_empty() {
        (64, vec![0f32; weights.len()])
    } else {
        let coded = encode_sparse(&occupancy, &symbols)?;
        // verify decodability and reconstruct from the *decoded* stream
        let (occ2, syms2) = coded.decode()?;
        let mut full_assign = vec![u32::MAX; weights.len()];
        let mut si = 0usize;
        for (i, &occ) in occ2.iter().enumerate() {
            if occ {
                full_assign[i] = syms2[si];
                si += 1;
            }
        }
        let decoded = reconstruct(&centroids, &full_assign);
        let centroid_bits = centroids.len() * 32;
        let header_bits = 64; // n, counts
        (
            coded.total_bits() + centroid_bits + header_bits,
            decoded,
        )
    };
    Ok(CompressedWeights {
        weights: decoded,
        bits,
        descr: format!(
            "deep-compression sparsity={:.2} clusters={}",
            cfg.sparsity, cfg.clusters
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn toy_weights(n: usize) -> Vec<f32> {
        let mut rng = Pcg64::seed(3);
        (0..n)
            .map(|_| {
                // heavy-tailed: most weights tiny, few large (prunable)
                let v = rng.next_normal() as f32;
                if rng.next_f64() < 0.1 {
                    v * 2.0
                } else {
                    v * 0.05
                }
            })
            .collect()
    }

    #[test]
    fn compresses_and_reconstructs() {
        let w = toy_weights(2000);
        let c = deep_compress(&w, &DeepCompCfg::default()).unwrap();
        assert_eq!(c.weights.len(), w.len());
        assert!(c.ratio_vs_fp32(w.len()) > 5.0, "ratio {}", c.ratio_vs_fp32(w.len()));
        // surviving large weights approximated decently
        for (x, y) in w.iter().zip(&c.weights) {
            if x.abs() > 1.0 {
                assert!((x - y).abs() < 0.5, "{x} -> {y}");
            }
        }
    }

    #[test]
    fn higher_sparsity_smaller() {
        let w = toy_weights(3000);
        let lo = deep_compress(&w, &DeepCompCfg { sparsity: 0.5, ..Default::default() })
            .unwrap();
        let hi = deep_compress(&w, &DeepCompCfg { sparsity: 0.95, ..Default::default() })
            .unwrap();
        assert!(hi.bits < lo.bits);
    }

    #[test]
    fn fewer_clusters_smaller_but_lossier() {
        let w = toy_weights(3000);
        let fine =
            deep_compress(&w, &DeepCompCfg { clusters: 64, sparsity: 0.8, ..Default::default() })
                .unwrap();
        let coarse =
            deep_compress(&w, &DeepCompCfg { clusters: 4, sparsity: 0.8, ..Default::default() })
                .unwrap();
        assert!(coarse.bits < fine.bits);
        let err = |c: &CompressedWeights| -> f64 {
            w.iter()
                .zip(&c.weights)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum()
        };
        assert!(err(&coarse) >= err(&fine));
    }
}
