//! Baseline compressors implemented for the Table 1 / Figure 1 comparison.
//!
//! The paper quotes Deep Compression (Han et al., 2016), Weightless (Reagen
//! et al., 2018) and Bayesian Compression (Louizos et al., 2017) from their
//! source papers; since our benchmark substrate differs (synthetic data,
//! scaled models), we *implement* the baseline pipelines and measure them on
//! the same workloads (DESIGN.md §4):
//!
//! * [`deepcomp`]  — magnitude pruning → k-means weight clustering → Huffman
//!   coding of cluster indices + sparse run lengths.
//! * [`bayescomp`] — variational posterior → precision-aware deterministic
//!   rounding + sparsification by signal-to-noise, then the same
//!   Shannon-style back end (this is the "deterministic weight-set from q"
//!   scheme §2 argues is restricted to point-measure coding).
//! * `fp32` / `fp16` uncompressed reference sizes.

pub mod bayescomp;
pub mod bloomier;
pub mod deepcomp;
pub mod kmeans;
pub mod prune;
pub mod runner;
pub mod sparse;
pub mod weightless;

/// A compressed deterministic weight-set: decoded values + honest size.
#[derive(Debug, Clone)]
pub struct CompressedWeights {
    /// decompressed flat weights (same layout the encoder saw)
    pub weights: Vec<f32>,
    /// total coded size in bits (payload + tables + container overhead)
    pub bits: usize,
    /// human-readable description of the operating point
    pub descr: String,
}

impl CompressedWeights {
    pub fn bytes(&self) -> f64 {
        self.bits as f64 / 8.0
    }

    pub fn ratio_vs_fp32(&self, n_weights: usize) -> f64 {
        (n_weights * 32) as f64 / self.bits as f64
    }
}

/// Uncompressed reference (fp32 or fp16 cast).
pub fn uncompressed(weights: &[f32], half: bool) -> CompressedWeights {
    if half {
        let dec: Vec<f32> = weights
            .iter()
            .map(|&w| f32::from_bits(half_round_trip(w)))
            .collect();
        CompressedWeights {
            weights: dec,
            bits: weights.len() * 16,
            descr: "fp16".into(),
        }
    } else {
        CompressedWeights {
            weights: weights.to_vec(),
            bits: weights.len() * 32,
            descr: "fp32".into(),
        }
    }
}

/// f32 -> f16 -> f32 round trip (software; no `half` crate offline).
fn half_round_trip(x: f32) -> u32 {
    let bits = x.to_bits();
    let sign = bits >> 31;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;
    // to f16
    let (h_exp, h_frac) = if exp == 0xff {
        (0x1f, if frac != 0 { 0x200 } else { 0 })
    } else {
        let unbiased = exp - 127;
        if unbiased > 15 {
            (0x1f, 0) // overflow -> inf
        } else if unbiased < -14 {
            (0, 0) // flush subnormal to zero (fine for weights)
        } else {
            ((unbiased + 15) as u32, frac >> 13)
        }
    };
    // back to f32
    if h_exp == 0 {
        return sign << 31;
    }
    if h_exp == 0x1f {
        return (sign << 31) | 0x7f80_0000 | (h_frac << 13);
    }
    let r_exp = (h_exp as i32 - 15 + 127) as u32;
    (sign << 31) | (r_exp << 23) | (h_frac << 13)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncompressed_sizes() {
        let w = vec![1.0f32; 100];
        assert_eq!(uncompressed(&w, false).bits, 3200);
        assert_eq!(uncompressed(&w, true).bits, 1600);
    }

    #[test]
    fn fp16_round_trip_accuracy() {
        for &x in &[0.0f32, 1.0, -1.5, 0.1, 100.0, -0.003] {
            let y = f32::from_bits(half_round_trip(x));
            assert!((x - y).abs() <= x.abs() * 1e-3 + 1e-4, "{x} -> {y}");
        }
    }

    #[test]
    fn ratio() {
        let c = CompressedWeights { weights: vec![], bits: 32, descr: "".into() };
        assert_eq!(c.ratio_vs_fp32(10), 10.0);
    }
}
