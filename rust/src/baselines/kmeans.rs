//! 1-D k-means (Lloyd) weight clustering — Deep Compression's quantizer.

use crate::prng::Pcg64;

/// Cluster nonzero weights into `k` centroids. Returns (centroids,
/// assignment per input index; pruned zeros keep assignment `u32::MAX`).
pub fn kmeans_1d(
    weights: &[f32],
    k: usize,
    iters: usize,
    seed: u64,
) -> (Vec<f32>, Vec<u32>) {
    let nz: Vec<f32> = weights.iter().cloned().filter(|&w| w != 0.0).collect();
    if nz.is_empty() || k == 0 {
        return (vec![], vec![u32::MAX; weights.len()]);
    }
    let k = k.min(nz.len());
    // linear init across the weight range (Deep Compression's linear init)
    let (lo, hi) = nz
        .iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &w| {
            (l.min(w), h.max(w))
        });
    let mut centroids: Vec<f32> = (0..k)
        .map(|i| lo + (hi - lo) * (i as f32 + 0.5) / k as f32)
        .collect();
    let mut rng = Pcg64::seed(seed);
    let mut assign = vec![0u32; nz.len()];
    for _ in 0..iters {
        // assignment step (centroids are sorted -> binary search)
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, &w) in nz.iter().enumerate() {
            assign[i] = nearest(&centroids, w) as u32;
        }
        // update step
        let mut sums = vec![0f64; k];
        let mut counts = vec![0usize; k];
        for (i, &w) in nz.iter().enumerate() {
            sums[assign[i] as usize] += w as f64;
            counts[assign[i] as usize] += 1;
        }
        for c in 0..k {
            if counts[c] > 0 {
                centroids[c] = (sums[c] / counts[c] as f64) as f32;
            } else {
                // re-seed empty cluster at a random weight
                centroids[c] = nz[rng.below(nz.len() as u64) as usize];
            }
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // final assignment over all weights
    let mut full_assign = vec![u32::MAX; weights.len()];
    for (i, &w) in weights.iter().enumerate() {
        if w != 0.0 {
            full_assign[i] = nearest(&centroids, w) as u32;
        }
    }
    (centroids, full_assign)
}

fn nearest(sorted: &[f32], w: f32) -> usize {
    match sorted.binary_search_by(|c| c.partial_cmp(&w).unwrap()) {
        Ok(i) => i,
        Err(i) => {
            if i == 0 {
                0
            } else if i >= sorted.len() {
                sorted.len() - 1
            } else if (w - sorted[i - 1]).abs() <= (sorted[i] - w).abs() {
                i - 1
            } else {
                i
            }
        }
    }
}

/// Reconstruct weights from centroids + assignments.
pub fn reconstruct(centroids: &[f32], assign: &[u32]) -> Vec<f32> {
    assign
        .iter()
        .map(|&a| {
            if a == u32::MAX {
                0.0
            } else {
                centroids[a as usize]
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop;

    #[test]
    fn separates_clear_clusters() {
        let mut w = vec![1.0f32; 50];
        w.extend(vec![-1.0f32; 50]);
        w.extend(vec![5.0f32; 50]);
        let (c, a) = kmeans_1d(&w, 3, 20, 0);
        assert_eq!(c.len(), 3);
        let rec = reconstruct(&c, &a);
        for (x, y) in w.iter().zip(&rec) {
            assert!((x - y).abs() < 0.05, "{x} {y}");
        }
    }

    #[test]
    fn zeros_stay_zero() {
        let w = [0.0f32, 1.0, 0.0, 2.0];
        let (c, a) = kmeans_1d(&w, 2, 10, 0);
        let rec = reconstruct(&c, &a);
        assert_eq!(rec[0], 0.0);
        assert_eq!(rec[2], 0.0);
        assert!(a[0] == u32::MAX);
    }

    #[test]
    fn quantization_error_shrinks_with_k() {
        quickprop::check("kmeans error vs k", 10, |g| {
            let n = 400;
            let w = g.vec_f32(n, -2.0, 2.0);
            let err = |k: usize| {
                let (c, a) = kmeans_1d(&w, k, 15, 1);
                let rec = reconstruct(&c, &a);
                w.iter()
                    .zip(&rec)
                    .map(|(x, y)| ((x - y) * (x - y)) as f64)
                    .sum::<f64>()
            };
            let e2 = err(2);
            let e16 = err(16);
            assert!(e16 <= e2 + 1e-9, "e2={e2} e16={e16}");
        });
    }

    #[test]
    fn assignments_in_range() {
        let w: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 10.0).collect();
        let (c, a) = kmeans_1d(&w, 8, 10, 2);
        for &x in &a {
            assert!(x == u32::MAX || (x as usize) < c.len());
        }
    }
}
