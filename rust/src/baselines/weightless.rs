//! Weightless-style lossy weight encoding (Reagen et al., 2018):
//! magnitude-prune, k-means quantize the survivors, then store the sparse
//! (index -> cluster) map in a Bloomier filter. Querying a pruned index
//! passes the tag check with probability 2^-t and injects a junk weight —
//! the lossy part the original paper shows networks tolerate.

use super::bloomier::Bloomier;
use super::kmeans::kmeans_1d;
use super::prune::magnitude_prune;
use super::CompressedWeights;
use crate::util::Result;

#[derive(Debug, Clone, Copy)]
pub struct WeightlessCfg {
    /// fraction pruned before encoding
    pub sparsity: f64,
    /// k-means clusters for the survivors (value_bits = ceil(log2(k)))
    pub clusters: usize,
    /// tag bits: false-positive rate 2^-t
    pub tag_bits: u32,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for WeightlessCfg {
    fn default() -> WeightlessCfg {
        WeightlessCfg {
            sparsity: 0.9,
            clusters: 16,
            tag_bits: 6,
            kmeans_iters: 25,
            seed: 0,
        }
    }
}

/// Run the Weightless pipeline. The decoded weight-set includes the false
/// positives, i.e. it is the *lossy* reconstruction a reader of the filter
/// would see.
pub fn weightless_compress(
    weights: &[f32],
    cfg: &WeightlessCfg,
) -> Result<CompressedWeights> {
    let (pruned, _) = magnitude_prune(weights, cfg.sparsity);
    let (centroids, assign) =
        kmeans_1d(&pruned, cfg.clusters, cfg.kmeans_iters, cfg.seed);
    let value_bits = (usize::BITS - (centroids.len().max(2) - 1).leading_zeros()).max(1);
    let pairs: Vec<(u64, u32)> = assign
        .iter()
        .enumerate()
        .filter(|(_, &a)| a != u32::MAX)
        .map(|(i, &a)| (i as u64, a))
        .collect();
    if pairs.is_empty() {
        return Ok(CompressedWeights {
            weights: vec![0.0; weights.len()],
            bits: 64,
            descr: "weightless (all pruned)".into(),
        });
    }
    let filter = Bloomier::build(&pairs, value_bits, cfg.tag_bits)?;
    // decode through the filter: stored keys exact, non-keys junk at 2^-t
    let decoded: Vec<f32> = (0..weights.len())
        .map(|i| match filter.query(i as u64) {
            Some(v) if (v as usize) < centroids.len() => centroids[v as usize],
            Some(_) => 0.0, // junk value outside the codebook
            None => 0.0,
        })
        .collect();
    let header_bits = 64 + 64; // seed + counts
    let centroid_bits = centroids.len() * 32;
    Ok(CompressedWeights {
        weights: decoded,
        bits: filter.bits() + centroid_bits + header_bits,
        descr: format!(
            "weightless sparsity={:.2} clusters={} t={}",
            cfg.sparsity,
            centroids.len(),
            cfg.tag_bits
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn toy_weights(n: usize) -> Vec<f32> {
        let mut rng = Pcg64::seed(17);
        (0..n)
            .map(|_| {
                let v = rng.next_normal() as f32;
                if rng.next_f64() < 0.1 {
                    v * 2.0
                } else {
                    v * 0.03
                }
            })
            .collect()
    }

    #[test]
    fn surviving_weights_reconstructed() {
        let w = toy_weights(3000);
        let c = weightless_compress(&w, &WeightlessCfg::default()).unwrap();
        // large weights survive pruning and must be near their cluster
        for (x, y) in w.iter().zip(&c.weights) {
            if x.abs() > 1.5 {
                assert!((x - y).abs() < 0.6, "{x} -> {y}");
            }
        }
        assert!(c.ratio_vs_fp32(w.len()) > 8.0, "{}", c.ratio_vs_fp32(w.len()));
    }

    #[test]
    fn false_positive_noise_rate_bounded() {
        let w = toy_weights(5000);
        let cfg = WeightlessCfg { tag_bits: 8, ..Default::default() };
        let c = weightless_compress(&w, &cfg).unwrap();
        let (pruned, _) = magnitude_prune(&w, cfg.sparsity);
        let mut junk = 0usize;
        let mut pruned_count = 0usize;
        for i in 0..w.len() {
            if pruned[i] == 0.0 {
                pruned_count += 1;
                if c.weights[i] != 0.0 {
                    junk += 1;
                }
            }
        }
        let rate = junk as f64 / pruned_count as f64;
        assert!(rate < 2f64.powi(-8) * 2.0 + 0.002, "fp rate {rate}");
    }

    #[test]
    fn fewer_tag_bits_smaller_but_noisier() {
        let w = toy_weights(4000);
        let small = weightless_compress(
            &w,
            &WeightlessCfg { tag_bits: 2, ..Default::default() },
        )
        .unwrap();
        let big = weightless_compress(
            &w,
            &WeightlessCfg { tag_bits: 10, ..Default::default() },
        )
        .unwrap();
        assert!(small.bits < big.bits);
        let noise = |c: &CompressedWeights| {
            c.weights
                .iter()
                .zip(&w)
                .filter(|(&y, &x)| x.abs() < 0.1 && y != 0.0)
                .count()
        };
        assert!(noise(&small) > noise(&big));
    }
}
