//! Magnitude-based weight pruning (Han et al., 2015).

/// Zero out the smallest-magnitude fraction `sparsity` of weights.
/// Returns the pruned copy and the surviving count.
pub fn magnitude_prune(weights: &[f32], sparsity: f64) -> (Vec<f32>, usize) {
    assert!((0.0..1.0).contains(&sparsity) || sparsity == 0.0);
    let n = weights.len();
    let keep = n - ((n as f64) * sparsity).round() as usize;
    if keep == n {
        return (weights.to_vec(), n);
    }
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let threshold = if keep == 0 { f32::INFINITY } else { mags[n - keep] };
    let mut survivors = 0usize;
    let out: Vec<f32> = weights
        .iter()
        .map(|&w| {
            if w.abs() >= threshold && survivors < keep {
                survivors += 1;
                w
            } else {
                0.0
            }
        })
        .collect();
    (out, survivors)
}

/// Prune by explicit threshold on |w|.
pub fn threshold_prune(weights: &[f32], threshold: f32) -> (Vec<f32>, usize) {
    let mut survivors = 0usize;
    let out: Vec<f32> = weights
        .iter()
        .map(|&w| {
            if w.abs() > threshold {
                survivors += 1;
                w
            } else {
                0.0
            }
        })
        .collect();
    (out, survivors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop;

    #[test]
    fn prunes_smallest() {
        let w = [0.1f32, -5.0, 0.01, 3.0, -0.2, 0.0];
        let (out, kept) = magnitude_prune(&w, 0.5);
        assert_eq!(kept, 3);
        assert_eq!(out[1], -5.0);
        assert_eq!(out[3], 3.0);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn zero_sparsity_is_identity() {
        let w = [1.0f32, 2.0, 3.0];
        let (out, kept) = magnitude_prune(&w, 0.0);
        assert_eq!(out, w);
        assert_eq!(kept, 3);
    }

    #[test]
    fn survivor_count_matches_request() {
        quickprop::check("prune count", 50, |g| {
            let n = g.usize_in(1, 500);
            let w = g.vec_f32(n, -1.0, 1.0);
            let s = g.f64_in(0.0, 0.95);
            let keep = n - ((n as f64) * s).round() as usize;
            let (_, kept) = magnitude_prune(&w, s);
            assert_eq!(kept, keep.min(n));
        });
    }

    #[test]
    fn threshold_variant() {
        let w = [0.5f32, -0.05, 2.0];
        let (out, kept) = threshold_prune(&w, 0.1);
        assert_eq!(kept, 2);
        assert_eq!(out, vec![0.5, 0.0, 2.0]);
    }
}
