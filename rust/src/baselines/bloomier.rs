//! Bloomier filter — the data structure behind the Weightless baseline
//! (Reagen et al., 2018).
//!
//! An immutable approximate key->value map: `n` keys are stored in
//! `m ≈ 1.23 n` cells of `b + t` bits (value + tag) using 3-way hashing and
//! peeling construction (as in XOR filters). Queries for stored keys return
//! the exact value; queries for other keys fail the tag check with
//! probability `1 - 2^-t` (returning None) and otherwise return junk — the
//! controlled lossiness Weightless exploits for weight matrices.

use crate::prng::mix64;
use crate::util::{Error, Result};

/// Immutable Bloomier filter storing `value_bits`-bit values with
/// `tag_bits`-bit false-positive protection.
#[derive(Debug, Clone)]
pub struct Bloomier {
    cells: Vec<u32>,
    seed: u64,
    pub value_bits: u32,
    pub tag_bits: u32,
}

fn hashes(seed: u64, key: u64, m: usize) -> [usize; 3] {
    // three independent positions via double hashing on mix64
    let h = mix64(seed ^ key);
    let a = (h >> 0) as u32 as u64;
    let b = (h >> 32) as u32 as u64;
    let c = mix64(h) as u32 as u64;
    [
        (a % m as u64) as usize,
        (b % m as u64) as usize,
        (c % m as u64) as usize,
    ]
}

fn tag_of(seed: u64, key: u64, tag_bits: u32) -> u32 {
    if tag_bits == 0 {
        0
    } else {
        (mix64(seed ^ key.rotate_left(17) ^ 0x7A6) as u32) & ((1 << tag_bits) - 1)
    }
}

impl Bloomier {
    /// Build from (key, value) pairs; values must fit in `value_bits`.
    /// Retries with different seeds until the peeling succeeds.
    pub fn build(
        pairs: &[(u64, u32)],
        value_bits: u32,
        tag_bits: u32,
    ) -> Result<Bloomier> {
        if value_bits + tag_bits > 32 {
            return Err(Error::msg("value_bits + tag_bits must be <= 32"));
        }
        for v in pairs {
            if value_bits < 32 && v.1 >= (1 << value_bits) {
                return Err(Error::msg(format!("value {} exceeds {value_bits} bits", v.1)));
            }
        }
        let n = pairs.len();
        let m = ((n as f64 * 1.23).ceil() as usize + 32).max(8);
        'seed: for attempt in 0..64u64 {
            let seed = mix64(0xB100_311E ^ attempt);
            // peeling: count key occurrences per cell
            let mut count = vec![0u32; m];
            let mut xorkey = vec![0usize; m]; // xor of pair indices
            for (i, &(k, _)) in pairs.iter().enumerate() {
                for h in hashes(seed, k, m) {
                    count[h] += 1;
                    xorkey[h] ^= i;
                }
            }
            let mut stack = Vec::with_capacity(n);
            let mut queue: Vec<usize> =
                (0..m).filter(|&c| count[c] == 1).collect();
            let mut placed = vec![false; n];
            while let Some(c) = queue.pop() {
                if count[c] != 1 {
                    continue;
                }
                let i = xorkey[c];
                if placed[i] {
                    continue;
                }
                placed[i] = true;
                stack.push((i, c));
                let (k, _) = pairs[i];
                for h in hashes(seed, k, m) {
                    count[h] -= 1;
                    xorkey[h] ^= i;
                    if count[h] == 1 {
                        queue.push(h);
                    }
                }
            }
            if stack.len() != n {
                continue 'seed; // peeling failed; retry with a new seed
            }
            // assign cells in reverse peel order
            let mut cells = vec![0u32; m];
            for &(i, home) in stack.iter().rev() {
                let (k, v) = pairs[i];
                let payload = (v << tag_bits) | tag_of(seed, k, tag_bits);
                let mut acc = payload;
                for h in hashes(seed, k, m) {
                    if h != home {
                        acc ^= cells[h];
                    }
                }
                cells[home] = acc;
            }
            return Ok(Bloomier { cells, seed, value_bits, tag_bits });
        }
        Err(Error::msg("bloomier: peeling failed for all seeds"))
    }

    /// Query: Some(value) if the tag matches (always true for stored keys,
    /// probability 2^-tag_bits for others), None otherwise.
    pub fn query(&self, key: u64) -> Option<u32> {
        let m = self.cells.len();
        let mut acc = 0u32;
        for h in hashes(self.seed, key, m) {
            acc ^= self.cells[h];
        }
        let tag_mask = if self.tag_bits == 0 {
            0
        } else {
            (1u32 << self.tag_bits) - 1
        };
        if acc & tag_mask == tag_of(self.seed, key, self.tag_bits) {
            Some(acc >> self.tag_bits)
        } else {
            None
        }
    }

    /// Storage size in bits (cells only; the seed is 8 bytes of header).
    pub fn bits(&self) -> usize {
        self.cells.len() * (self.value_bits + self.tag_bits) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::util::quickprop;

    #[test]
    fn stored_keys_exact() {
        let pairs: Vec<(u64, u32)> = (0..500u64).map(|k| (k * 7 + 1, (k % 16) as u32)).collect();
        let f = Bloomier::build(&pairs, 4, 8).unwrap();
        for &(k, v) in &pairs {
            assert_eq!(f.query(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn false_positive_rate_near_2_pow_minus_t() {
        let pairs: Vec<(u64, u32)> = (0..2000u64).map(|k| (k, (k % 8) as u32)).collect();
        for t in [4u32, 8] {
            let f = Bloomier::build(&pairs, 3, t).unwrap();
            let mut fp = 0usize;
            let trials = 20000u64;
            for k in 0..trials {
                if f.query(1_000_000 + k).is_some() {
                    fp += 1;
                }
            }
            let rate = fp as f64 / trials as f64;
            let expect = 2f64.powi(-(t as i32));
            assert!(
                (rate - expect).abs() < expect * 0.5 + 0.002,
                "t={t}: rate {rate} expect {expect}"
            );
        }
    }

    #[test]
    fn size_is_1_23_n_cells() {
        let pairs: Vec<(u64, u32)> = (0..1000u64).map(|k| (k, 1)).collect();
        let f = Bloomier::build(&pairs, 4, 4).unwrap();
        let cells = f.bits() / 8;
        assert!(cells >= 1230 && cells < 1400, "{cells}");
    }

    #[test]
    fn empty_and_tiny() {
        let f = Bloomier::build(&[], 4, 4).unwrap();
        assert_eq!(f.query(42), None);
        let f = Bloomier::build(&[(9, 3)], 4, 4).unwrap();
        assert_eq!(f.query(9), Some(3));
    }

    #[test]
    fn rejects_oversized_values() {
        assert!(Bloomier::build(&[(1, 16)], 4, 4).is_err());
        assert!(Bloomier::build(&[(1, 1)], 20, 20).is_err());
    }

    #[test]
    fn random_key_sets_round_trip() {
        quickprop::check("bloomier round trip", 25, |g| {
            let n = g.usize_in(1, 800);
            let vbits = g.usize_in(1, 8) as u32;
            let mut rng = Pcg64::seed(g.rng.next_u64());
            let mut keys = std::collections::BTreeSet::new();
            while keys.len() < n {
                keys.insert(rng.next_u64());
            }
            let pairs: Vec<(u64, u32)> = keys
                .into_iter()
                .map(|k| (k, (rng.next_u64() & ((1 << vbits) - 1) as u64) as u32))
                .collect();
            let f = Bloomier::build(&pairs, vbits, 6).unwrap();
            for &(k, v) in &pairs {
                assert_eq!(f.query(k), Some(v));
            }
        });
    }
}
