//! Bayesian-Compression-style baseline (Louizos et al., 2017, simplified):
//! given a trained variational posterior (mu, sigma) per weight, (1) prune
//! weights with low signal-to-noise |mu|/sigma, (2) round surviving means to
//! a precision derived from their posterior stddev (high variance -> fewer
//! bits), (3) Shannon-code the quantized values. Produces a *deterministic*
//! weight-set — exactly the point-measure coding scheme §2 of the paper
//! argues is dominated by MIRACLE's random coding.

use std::collections::BTreeMap;

use super::sparse::encode_sparse;
use super::CompressedWeights;
use crate::util::Result;

#[derive(Debug, Clone, Copy)]
pub struct BayesCompCfg {
    /// prune weights with |mu|/sigma below this
    pub snr_threshold: f32,
    /// quantization step = step_scale * sigma (posterior-variance-aware
    /// rounding: noisier weights get coarser grids)
    pub step_scale: f32,
}

impl Default for BayesCompCfg {
    fn default() -> BayesCompCfg {
        BayesCompCfg { snr_threshold: 1.0, step_scale: 1.0 }
    }
}

/// Compress a variational posterior into a deterministic coded weight-set.
/// `mu`/`sigma` are per-weight (flat layout).
pub fn bayes_compress(
    mu: &[f32],
    sigma: &[f32],
    cfg: &BayesCompCfg,
) -> Result<CompressedWeights> {
    assert_eq!(mu.len(), sigma.len());
    let n = mu.len();
    // global grid step from the median surviving sigma (shared quantizer so
    // the decoder needs one f32, not one per weight)
    let mut survivors: Vec<usize> = (0..n)
        .filter(|&i| sigma[i] > 0.0 && mu[i].abs() / sigma[i] > cfg.snr_threshold)
        .collect();
    if survivors.is_empty() {
        // degenerate: everything pruned
        return Ok(CompressedWeights {
            weights: vec![0.0; n],
            bits: 64,
            descr: format!("bayes-comp snr>{} (all pruned)", cfg.snr_threshold),
        });
    }
    let mut sig_sorted: Vec<f32> = survivors.iter().map(|&i| sigma[i]).collect();
    sig_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let step = (cfg.step_scale * sig_sorted[sig_sorted.len() / 2]).max(1e-6);

    // quantize survivors onto the grid; symbol = signed grid index, offset
    // to be non-negative for the coder
    let q_idx: Vec<i64> = survivors
        .iter()
        .map(|&i| (mu[i] / step).round() as i64)
        .collect();
    let min_idx = *q_idx.iter().min().unwrap();
    let symbols: Vec<u32> = q_idx.iter().map(|&q| (q - min_idx) as u32).collect();
    // drop survivors that quantize to zero (they carry no information)
    let mut occupancy = vec![false; n];
    let mut kept_syms = Vec::new();
    for (k, &i) in survivors.iter().enumerate() {
        if q_idx[k] != 0 {
            occupancy[i] = true;
            kept_syms.push(symbols[k]);
        }
    }
    survivors.retain(|&i| occupancy[i]);
    if kept_syms.is_empty() {
        return Ok(CompressedWeights {
            weights: vec![0.0; n],
            bits: 64,
            descr: format!("bayes-comp snr>{} (all zero)", cfg.snr_threshold),
        });
    }
    let coded = encode_sparse(&occupancy, &kept_syms)?;
    // decode to produce the deterministic weight-set
    let (occ2, syms2) = coded.decode()?;
    let mut weights = vec![0f32; n];
    let mut si = 0usize;
    for (i, &occ) in occ2.iter().enumerate() {
        if occ {
            weights[i] = ((syms2[si] as i64 + min_idx) as f32) * step;
            si += 1;
        }
    }
    let header_bits = 32 + 64 + 64; // step, min_idx, counts
    Ok(CompressedWeights {
        weights,
        bits: coded.total_bits() + header_bits,
        descr: format!(
            "bayes-comp snr>{} step={:.4}",
            cfg.snr_threshold, step
        ),
    })
}

/// Entropy of the quantized symbol stream (diagnostics / ablations).
pub fn symbol_entropy(symbols: &[u32]) -> f64 {
    let mut freqs: BTreeMap<u32, usize> = BTreeMap::new();
    for &s in symbols {
        *freqs.entry(s).or_insert(0) += 1;
    }
    let n = symbols.len() as f64;
    freqs
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn posterior(n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seed(9);
        let mu: Vec<f32> = (0..n)
            .map(|_| {
                if rng.next_f64() < 0.15 {
                    rng.next_normal() as f32 * 1.5 // informative weight
                } else {
                    rng.next_normal() as f32 * 0.02 // noise weight
                }
            })
            .collect();
        let sigma: Vec<f32> = mu
            .iter()
            .map(|&m| if m.abs() > 0.3 { 0.05 } else { 0.5 })
            .collect();
        (mu, sigma)
    }

    #[test]
    fn prunes_low_snr_keeps_high_snr() {
        let (mu, sigma) = posterior(2000);
        let c = bayes_compress(&mu, &sigma, &BayesCompCfg::default()).unwrap();
        for i in 0..mu.len() {
            if mu[i].abs() / sigma[i] < 1.0 {
                assert_eq!(c.weights[i], 0.0, "low SNR weight survived");
            } else if mu[i].abs() > 0.5 {
                assert!(
                    (c.weights[i] - mu[i]).abs() < 0.2,
                    "{} -> {}",
                    mu[i],
                    c.weights[i]
                );
            }
        }
        assert!(c.ratio_vs_fp32(mu.len()) > 5.0, "ratio {}", c.ratio_vs_fp32(mu.len()));
    }

    #[test]
    fn stricter_threshold_compresses_more() {
        let (mu, sigma) = posterior(2000);
        let a = bayes_compress(&mu, &sigma, &BayesCompCfg { snr_threshold: 0.5, step_scale: 0.5 })
            .unwrap();
        let b = bayes_compress(&mu, &sigma, &BayesCompCfg { snr_threshold: 3.0, step_scale: 0.5 })
            .unwrap();
        assert!(b.bits <= a.bits);
    }

    #[test]
    fn degenerate_all_pruned() {
        let mu = vec![0.001f32; 50];
        let sigma = vec![1.0f32; 50];
        let c = bayes_compress(&mu, &sigma, &BayesCompCfg::default()).unwrap();
        assert!(c.weights.iter().all(|&w| w == 0.0));
        assert!(c.bits <= 64);
    }

    #[test]
    fn entropy_sane() {
        assert_eq!(symbol_entropy(&[1, 1, 1, 1]), 0.0);
        let e = symbol_entropy(&[0, 1, 2, 3]);
        assert!((e - 2.0).abs() < 1e-12);
    }
}
