//! Sparse index coding for pruned weight vectors (Deep Compression style):
//! store run lengths between surviving weights, Huffman-coded, with an
//! escape symbol for runs exceeding the cap (Han et al. use 3-bit/8-bit
//! relative indexing with zero-padding; the escape plays that role here).

use std::collections::BTreeMap;

use crate::bitstream::huffman::Huffman;
use crate::bitstream::{BitReader, BitWriter};
use crate::util::Result;

const RUN_CAP: u32 = 255;
const ESCAPE: u32 = RUN_CAP + 1;

/// Gap symbols for a 0/1 occupancy pattern (true = nonzero weight kept).
pub fn gaps(occupancy: &[bool]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut run = 0u32;
    for &occ in occupancy {
        if occ {
            while run > RUN_CAP {
                out.push(ESCAPE);
                run -= RUN_CAP;
            }
            out.push(run);
            run = 0;
        } else {
            run += 1;
        }
    }
    out
}

/// Rebuild occupancy from gap symbols (`n` = total length).
pub fn occupancy_from_gaps(gaps: &[u32], n: usize) -> Vec<bool> {
    let mut occ = vec![false; n];
    let mut pos = 0usize;
    let mut carry = 0usize;
    for &g in gaps {
        if g == ESCAPE {
            carry += RUN_CAP as usize;
            continue;
        }
        pos += carry + g as usize;
        carry = 0;
        if pos < n {
            occ[pos] = true;
        }
        pos += 1;
    }
    occ
}

/// Encoded sparse payload with honest size accounting.
#[derive(Debug)]
pub struct SparseCoded {
    pub payload: Vec<u8>,
    pub payload_bits: usize,
    pub table_bits: usize,
    gap_book: Huffman,
    sym_book: Huffman,
    n: usize,
    count: usize,
}

/// Huffman-code occupancy gaps + per-survivor symbols (cluster indices).
pub fn encode_sparse(occupancy: &[bool], symbols: &[u32]) -> Result<SparseCoded> {
    assert_eq!(
        occupancy.iter().filter(|&&o| o).count(),
        symbols.len(),
        "one symbol per surviving weight"
    );
    let gap_syms = gaps(occupancy);
    let mut gf = BTreeMap::new();
    for &g in &gap_syms {
        *gf.entry(g).or_insert(0u64) += 1;
    }
    let mut sf = BTreeMap::new();
    for &s in symbols {
        *sf.entry(s).or_insert(0u64) += 1;
    }
    let gap_book = Huffman::from_freqs(&gf)?;
    let sym_book = Huffman::from_freqs(&sf)?;
    let mut w = BitWriter::new();
    for &g in &gap_syms {
        gap_book.encode_symbol(&mut w, g)?;
    }
    for &s in symbols {
        sym_book.encode_symbol(&mut w, s)?;
    }
    let payload_bits = w.bit_len();
    let table_bits = gap_book.table_bits() + sym_book.table_bits();
    Ok(SparseCoded {
        payload: w.finish(),
        payload_bits,
        table_bits,
        gap_book,
        sym_book,
        n: occupancy.len(),
        count: symbols.len(),
    })
}

impl SparseCoded {
    pub fn total_bits(&self) -> usize {
        self.payload_bits + self.table_bits
    }

    /// Decode back to (occupancy, symbols).
    pub fn decode(&self) -> Result<(Vec<bool>, Vec<u32>)> {
        let mut r = BitReader::new(&self.payload);
        // number of gap symbols = survivors + escapes; we re-derive by
        // consuming gaps until `count` non-escape symbols were read.
        let mut gap_syms = Vec::new();
        let mut real = 0usize;
        while real < self.count {
            let g = self.gap_book.decode_symbol(&mut r)?;
            if g != ESCAPE {
                real += 1;
            }
            gap_syms.push(g);
        }
        let occ = occupancy_from_gaps(&gap_syms, self.n);
        let mut syms = Vec::with_capacity(self.count);
        for _ in 0..self.count {
            syms.push(self.sym_book.decode_symbol(&mut r)?);
        }
        Ok((occ, syms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop;

    #[test]
    fn gaps_round_trip_basic() {
        let occ = [false, true, false, false, true, true, false];
        let g = gaps(&occ);
        assert_eq!(g, vec![1, 2, 0]);
        assert_eq!(occupancy_from_gaps(&g, 7), occ.to_vec());
    }

    #[test]
    fn long_runs_use_escape() {
        let mut occ = vec![false; 600];
        occ[599] = true;
        let g = gaps(&occ);
        assert!(g.contains(&ESCAPE));
        assert_eq!(occupancy_from_gaps(&g, 600), occ);
    }

    #[test]
    fn sparse_encode_decode_prop() {
        quickprop::check("sparse round trip", 40, |gen| {
            let n = gen.usize_in(1, 800);
            let occ: Vec<bool> = (0..n).map(|_| gen.f64_in(0.0, 1.0) < 0.15).collect();
            let count = occ.iter().filter(|&&o| o).count();
            if count == 0 {
                return;
            }
            let syms: Vec<u32> =
                (0..count).map(|_| gen.usize_in(0, 15) as u32).collect();
            let coded = encode_sparse(&occ, &syms).unwrap();
            let (occ2, syms2) = coded.decode().unwrap();
            assert_eq!(occ, occ2);
            assert_eq!(syms, syms2);
        });
    }

    #[test]
    fn sparse_beats_dense_for_high_sparsity() {
        let n = 4000;
        let mut occ = vec![false; n];
        for i in (0..n).step_by(40) {
            occ[i] = true; // 2.5% density
        }
        let count = occ.iter().filter(|&&o| o).count();
        let syms = vec![3u32; count];
        let coded = encode_sparse(&occ, &syms).unwrap();
        assert!(
            coded.total_bits() < n, // << 1 bit per original weight
            "{} bits for {n} weights",
            coded.total_bits()
        );
    }
}
