//! Chrome trace-event sink.
//!
//! Writes the JSON-array flavor of the Trace Event Format understood by
//! `chrome://tracing` and Perfetto: complete events
//! (`"ph":"X"`, microsecond `ts`/`dur`) plus `"ph":"M"` `thread_name`
//! metadata so pool workers get their own lanes. The array is opened at
//! create time and closed by [`TraceSink::finish`]; events stream out as
//! they complete, so even an aborted run yields a recoverable prefix.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::{Error, Result};

pub struct TraceSink {
    epoch: Instant,
    out: Mutex<TraceOut>,
    path: String,
}

struct TraceOut {
    w: BufWriter<File>,
    first: bool,
    closed: bool,
}

impl TraceSink {
    pub fn create(path: &str, epoch: Instant) -> Result<TraceSink> {
        let mut f = BufWriter::new(
            File::create(path)
                .map_err(|e| Error::msg(format!("--trace-out {path}: {e}")))?,
        );
        let _ = f.write_all(b"[");
        Ok(TraceSink {
            epoch,
            out: Mutex::new(TraceOut { w: f, first: true, closed: false }),
            path: path.to_string(),
        })
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Microseconds since telemetry init (the trace time base).
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn write_event(&self, j: Json) {
        if let Ok(mut o) = self.out.lock() {
            if o.closed {
                return;
            }
            let sep = if o.first { "\n" } else { ",\n" };
            o.first = false;
            let line = j.to_string();
            let _ = o.w.write_all(sep.as_bytes());
            let _ = o.w.write_all(line.as_bytes());
        }
    }

    /// A `"ph":"X"` complete event on thread lane `tid`.
    pub fn complete(&self, name: &str, tid: u64, ts_us: u64, dur_us: u64) {
        self.write_event(Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str("X")),
            ("cat", Json::str("miracle")),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(ts_us as f64)),
            ("dur", Json::Num(dur_us as f64)),
        ]));
    }

    /// `thread_name` metadata so the viewer labels lane `tid`.
    pub fn thread_meta(&self, tid: u64, name: &str) {
        self.write_event(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }

    /// Close the JSON array and flush. Idempotent.
    pub fn finish(&self) {
        if let Ok(mut o) = self.out.lock() {
            if o.closed {
                return;
            }
            o.closed = true;
            let _ = o.w.write_all(b"\n]\n");
            let _ = o.w.flush();
        }
    }
}

/// Stable per-thread trace lane id; registers a `thread_name` metadata
/// event the first time a thread touches the sink.
pub fn thread_lane(t: &TraceSink) -> u64 {
    use std::cell::Cell;
    static NEXT: AtomicU64 = AtomicU64::new(0);
    thread_local! {
        static LANE: Cell<u64> = const { Cell::new(u64::MAX) };
    }
    LANE.with(|c| {
        let mut id = c.get();
        if id == u64::MAX {
            id = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(id);
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{id}"));
            t.thread_meta(id, &name);
        }
        id
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_well_formed_json_array() {
        let path = std::env::temp_dir()
            .join(format!("miracle_trace_test_{}.json", std::process::id()));
        let t = TraceSink::create(path.to_str().unwrap(), Instant::now())
            .unwrap();
        let lane = thread_lane(&t);
        t.complete("unit_span", lane, 10, 5);
        t.complete("unit_span2", lane, 20, 1);
        t.finish();
        t.finish(); // idempotent
        let j = Json::from_file(path.to_str().unwrap()).unwrap();
        let arr = j.as_arr().unwrap();
        // thread_name metadata + 2 complete events
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(arr[1].get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(arr[1].get("name").unwrap().as_str().unwrap(), "unit_span");
        assert_eq!(arr[1].get("dur").unwrap().as_usize().unwrap(), 5);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_trace_is_still_valid() {
        let path = std::env::temp_dir()
            .join(format!("miracle_trace_empty_{}.json", std::process::id()));
        let t = TraceSink::create(path.to_str().unwrap(), Instant::now())
            .unwrap();
        t.finish();
        let j = Json::from_file(path.to_str().unwrap()).unwrap();
        assert!(j.as_arr().unwrap().is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
