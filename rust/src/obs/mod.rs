//! Zero-dependency telemetry: structured events, metrics, timing spans.
//!
//! Process-wide sinks are configured at most once (from `main`, before any
//! work starts) via [`init`]; everything else in the codebase talks to
//! telemetry through three cheap accessors:
//!
//! - [`events`] — the `--events-out` JSON-lines log (or `None`),
//!   usually via the [`obs_event!`] macro which skips field construction
//!   entirely when no sink is configured or the level is filtered;
//! - [`span`] — a drop-guard that records a Chrome-trace complete event
//!   to the `--trace-out` sink (inert `None` guard otherwise);
//! - [`metrics`] — always-on atomic counters/gauges (plain relaxed
//!   atomics; a periodic `--metrics-out` snapshot is driven by
//!   [`metrics_tick`]).
//!
//! **Determinism contract:** telemetry is strictly write-only — no value
//! read from a sink, counter, or clock ever feeds back into compression
//! or serving decisions, so `.mrc` bytes and ledger counts are identical
//! with telemetry on or off (`rust/tests/observability.rs` asserts this
//! end to end). When [`init`] is never called (library use, unit tests)
//! every accessor returns `None` and instrumentation reduces to a relaxed
//! atomic load.

pub mod events;
pub mod hist;
pub mod metrics;
pub mod trace;

use std::sync::OnceLock;
use std::time::Instant;

pub use events::{EventLog, Level, Value};
pub use hist::{AtomicHist, Hist, HistSummary};
pub use metrics::{metrics, Counter, Gauge, Metrics, MetricsSink};
pub use trace::TraceSink;

use crate::util::json::Json;
use crate::util::{Error, Result};

/// Sink configuration, parsed from the shared CLI telemetry flags.
#[derive(Debug, Clone)]
pub struct ObsCfg {
    /// `--events-out PATH` — JSON-lines event log.
    pub events_out: Option<String>,
    /// `--events-level {debug|info|warn}` (default info).
    pub events_level: Level,
    /// `--metrics-out PATH` — atomically rewritten JSON snapshot.
    pub metrics_out: Option<String>,
    /// `--metrics-every N` — snapshot every N ticks (default 32).
    pub metrics_every: u64,
    /// `--trace-out PATH` — Chrome trace-event JSON array.
    pub trace_out: Option<String>,
}

impl Default for ObsCfg {
    fn default() -> ObsCfg {
        ObsCfg {
            events_out: None,
            events_level: Level::Info,
            metrics_out: None,
            metrics_every: 32,
            trace_out: None,
        }
    }
}

impl ObsCfg {
    pub fn any_sink(&self) -> bool {
        self.events_out.is_some()
            || self.metrics_out.is_some()
            || self.trace_out.is_some()
    }
}

static EVENTS: OnceLock<Option<EventLog>> = OnceLock::new();
static TRACE: OnceLock<Option<TraceSink>> = OnceLock::new();
static METRICS_SINK: OnceLock<Option<MetricsSink>> = OnceLock::new();

/// Configure the process-wide sinks. Call at most once, before spawning
/// any workers; `ctx` fields (command, seeds, pid) go into the initial
/// `run_start` event. A second call is an error.
pub fn init(cfg: &ObsCfg, ctx: &[(&str, Value)]) -> Result<()> {
    let epoch = Instant::now();
    let ev = match &cfg.events_out {
        Some(p) => Some(EventLog::create(p, cfg.events_level, epoch)?),
        None => None,
    };
    let tr = match &cfg.trace_out {
        Some(p) => Some(TraceSink::create(p, epoch)?),
        None => None,
    };
    let ms = cfg
        .metrics_out
        .as_ref()
        .map(|p| MetricsSink::new(p, cfg.metrics_every, epoch));
    if EVENTS.set(ev).is_err() {
        return Err(Error::msg("telemetry already initialized for this process"));
    }
    let _ = TRACE.set(tr);
    let _ = METRICS_SINK.set(ms);
    if let Some(log) = self::events() {
        log.emit(Level::Info, "run_start", ctx);
    }
    Ok(())
}

/// The event log, or `None` when `--events-out` was not configured.
#[inline]
pub fn events() -> Option<&'static EventLog> {
    EVENTS.get().and_then(|o| o.as_ref())
}

/// The trace sink, or `None` when `--trace-out` was not configured.
#[inline]
pub fn trace() -> Option<&'static TraceSink> {
    TRACE.get().and_then(|o| o.as_ref())
}

/// The metrics snapshot sink, or `None` when `--metrics-out` was not set.
#[inline]
pub fn metrics_sink() -> Option<&'static MetricsSink> {
    METRICS_SINK.get().and_then(|o| o.as_ref())
}

/// Path of the configured event log (used by `chaos-serve` to reconcile
/// its own event stream against `ServeStats`).
pub fn events_path() -> Option<&'static str> {
    events().map(|e| e.path())
}

/// Count one unit of work toward the periodic snapshot. The `extras`
/// closure (live values like qps/p95) runs only when a snapshot is due,
/// and nothing at all happens without a `--metrics-out` sink.
pub fn metrics_tick<F>(extras: F)
where
    F: FnOnce() -> Vec<(&'static str, Json)>,
{
    if let Some(m) = metrics_sink() {
        m.tick_with(extras);
    }
}

/// Flush and finalize every configured sink: final metrics snapshot,
/// event-log flush, trace-array close. Safe to call multiple times and
/// with no sinks configured.
pub fn finish() {
    if let Some(m) = metrics_sink() {
        m.write_snapshot(&[]);
    }
    if let Some(e) = events() {
        e.flush();
    }
    if let Some(t) = trace() {
        t.finish();
    }
}

/// Drop-guard timing span. When no trace sink is configured this is an
/// inert two-word struct and drop does nothing.
pub struct Span {
    name: &'static str,
    start_us: u64,
    active: bool,
}

/// Open a span named `name` on the current thread's trace lane; the
/// complete event is written when the guard drops.
#[inline]
pub fn span(name: &'static str) -> Span {
    match trace() {
        Some(t) => Span { name, start_us: t.now_us(), active: true },
        None => Span { name, start_us: 0, active: false },
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        if let Some(t) = trace() {
            let end = t.now_us();
            let lane = trace::thread_lane(t);
            t.complete(self.name, lane, self.start_us, end.saturating_sub(self.start_us));
        }
    }
}

/// Emit a structured event iff an event sink is configured *and* the
/// level passes its filter — field expressions are not evaluated
/// otherwise, so instrumented hot paths pay nothing when disabled.
///
/// ```ignore
/// obs_event!(Level::Info, "shed", "reason" => "overloaded", "depth" => depth);
/// ```
#[macro_export]
macro_rules! obs_event {
    ($lvl:expr, $ev:expr $(, $k:expr => $v:expr)* $(,)?) => {
        if let Some(__obs_log) = $crate::obs::events() {
            if __obs_log.enabled($lvl) {
                __obs_log.emit(
                    $lvl,
                    $ev,
                    &[$(($k, $crate::obs::Value::from($v))),*],
                );
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these run in the library test binary where `init` is never
    // called — they pin down the "free when disabled" contract.
    #[test]
    fn accessors_are_none_without_init() {
        assert!(events().is_none());
        assert!(trace().is_none());
        assert!(metrics_sink().is_none());
        assert!(events_path().is_none());
    }

    #[test]
    fn span_and_macro_are_inert_without_sinks() {
        let s = span("noop");
        drop(s);
        let mut evaluated = false;
        // field expressions must not run when no sink is configured
        obs_event!(Level::Warn, "noop", "x" => {
            evaluated = true;
            1u64
        });
        assert!(!evaluated);
        metrics_tick(|| panic!("extras must not run without a sink"));
        finish(); // no-op
    }

    #[test]
    fn metrics_registry_always_works() {
        let before = metrics().pool_worker_panics.get();
        metrics().pool_worker_panics.inc();
        assert_eq!(metrics().pool_worker_panics.get(), before + 1);
    }
}
