//! Structured JSON-lines event log.
//!
//! One JSON object per line, built with the in-tree [`crate::util::json`]
//! writer (no external crates). Every line carries a monotonic
//! microsecond timestamp relative to process telemetry init (`ts_us`), a
//! process-wide sequence number (`seq`), a level (`lvl`) and an event name
//! (`ev`); remaining keys are event-specific fields. Schema:
//! `docs/observability.md`.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::{Error, Result};

/// Event severity. `Debug` is per-step/per-attempt detail, `Info` is
/// lifecycle milestones, `Warn` is degraded-mode transitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
        }
    }

    pub fn parse(s: &str) -> Result<Level> {
        match s {
            "debug" => Ok(Level::Debug),
            "info" => Ok(Level::Info),
            "warn" => Ok(Level::Warn),
            other => Err(Error::msg(format!(
                "unknown event level {other:?} (expected debug|info|warn)"
            ))),
        }
    }
}

/// Typed field value; `From` impls let call sites pass plain literals.
#[derive(Debug, Clone)]
pub enum Value {
    U(u64),
    I(i64),
    F(f64),
    S(String),
    B(bool),
}

impl Value {
    fn to_json(&self) -> Json {
        match self {
            Value::U(v) => Json::Num(*v as f64),
            Value::I(v) => Json::Num(*v as f64),
            Value::F(v) => Json::Num(*v),
            Value::S(v) => Json::str(v),
            Value::B(v) => Json::Bool(*v),
        }
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Value {
        Value::U(v)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Value {
        Value::U(v as u64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::U(v as u64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Value {
        Value::I(v)
    }
}
impl From<i32> for Value {
    fn from(v: i32) -> Value {
        Value::I(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::F(v)
    }
}
impl From<f32> for Value {
    fn from(v: f32) -> Value {
        Value::F(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Value {
        Value::B(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Value {
        Value::S(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Value {
        Value::S(v)
    }
}

/// Leveled JSON-lines sink. Cheap to share: `emit` takes `&self`.
pub struct EventLog {
    level: Level,
    epoch: Instant,
    seq: AtomicU64,
    out: Mutex<BufWriter<File>>,
    path: String,
}

impl EventLog {
    pub fn create(path: &str, level: Level, epoch: Instant) -> Result<EventLog> {
        let f = File::create(path)
            .map_err(|e| Error::msg(format!("--events-out {path}: {e}")))?;
        Ok(EventLog {
            level,
            epoch,
            seq: AtomicU64::new(0),
            out: Mutex::new(BufWriter::new(f)),
            path: path.to_string(),
        })
    }

    #[inline]
    pub fn enabled(&self, l: Level) -> bool {
        l >= self.level
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Write one event line. Field keys must not collide with the
    /// reserved `ts_us`/`seq`/`lvl`/`ev` keys.
    pub fn emit(&self, l: Level, ev: &str, fields: &[(&str, Value)]) {
        if !self.enabled(l) {
            return;
        }
        let ts = self.epoch.elapsed().as_micros() as u64;
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut pairs: Vec<(&str, Json)> = Vec::with_capacity(4 + fields.len());
        pairs.push(("ts_us", Json::Num(ts as f64)));
        pairs.push(("seq", Json::Num(seq as f64)));
        pairs.push(("lvl", Json::str(l.name())));
        pairs.push(("ev", Json::str(ev)));
        for (k, v) in fields {
            pairs.push((k, v.to_json()));
        }
        let line = Json::obj(pairs).to_string();
        if let Ok(mut w) = self.out.lock() {
            let _ = writeln!(w, "{line}");
        }
    }

    pub fn flush(&self) {
        if let Ok(mut w) = self.out.lock() {
            let _ = w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Debug < Level::Info && Level::Info < Level::Warn);
        assert_eq!(Level::parse("warn").unwrap(), Level::Warn);
        assert!(Level::parse("verbose").is_err());
        assert_eq!(Level::Info.name(), "info");
    }

    #[test]
    fn emits_parseable_lines_with_reserved_keys() {
        let path = std::env::temp_dir().join(format!(
            "miracle_events_test_{}.jsonl",
            std::process::id()
        ));
        let log =
            EventLog::create(path.to_str().unwrap(), Level::Info, Instant::now())
                .unwrap();
        log.emit(Level::Debug, "dropped", &[]); // below level: filtered
        log.emit(
            Level::Info,
            "unit_test",
            &[("k", Value::from(3u64)), ("s", Value::from("x"))],
        );
        log.emit(Level::Warn, "unit_warn", &[("flag", Value::from(true))]);
        log.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "debug line must be filtered out");
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("ev").unwrap().as_str().unwrap(), "unit_test");
        assert_eq!(j.get("lvl").unwrap().as_str().unwrap(), "info");
        assert_eq!(j.get("k").unwrap().as_usize().unwrap(), 3);
        assert!(j.get("ts_us").unwrap().as_f64().unwrap() >= 0.0);
        let j2 = Json::parse(lines[1]).unwrap();
        assert_eq!(j2.get("seq").unwrap().as_usize().unwrap(), 1);
        assert!(j2.get("flag").unwrap().as_bool().unwrap());
        let _ = std::fs::remove_file(&path);
    }
}
