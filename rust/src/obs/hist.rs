//! Fixed-bucket log₂-scale latency histograms.
//!
//! Values are recorded as `u64` microseconds into 65 power-of-two buckets:
//! bucket 0 holds exact zeros, bucket `i` (1..=64) holds values in
//! `[2^(i-1), 2^i - 1]`. The bucket index is a single `leading_zeros`
//! instruction, so recording is branch-light and allocation-free.
//!
//! Percentiles use the same nearest-rank convention as
//! [`crate::util::stats::summarize`] (rank `round(p·(n-1))`, 0-based) with
//! linear interpolation inside the landing bucket, clamped to the observed
//! `[min, max]` — exact for `n == 1` and for degenerate all-equal streams.
//!
//! [`Hist::merge`] is component-wise addition plus min/max folds, so it is
//! exactly commutative and associative: recording a stream sequentially or
//! sharded across threads and merged yields the identical histogram.
//! [`AtomicHist`] provides the lock-free multi-thread variant: each thread
//! records into one of a fixed set of shards (plain atomic adds, no locks)
//! and [`AtomicHist::snapshot`] merges the shards into a [`Hist`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Bucket 0 for zero, buckets 1..=64 for each power-of-two magnitude.
pub const BUCKETS: usize = 65;

const SHARDS: usize = 8;

/// Plain (single-writer) log₂ histogram over `u64` microsecond values.
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    counts: [u64; BUCKETS],
    n: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist { counts: [0; BUCKETS], n: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Bucket index for a value: 0 for 0, else `64 - leading_zeros(v)`.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Smallest value that lands in bucket `i`.
    pub fn bucket_lo(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    /// Largest value that lands in bucket `i`.
    pub fn bucket_hi(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.n += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a duration given in seconds (converted to whole microseconds).
    #[inline]
    pub fn record_secs(&mut self, secs: f64) {
        self.record(secs_to_us(secs));
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn min_us(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max
    }

    pub fn mean_us(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Exactly commutative and associative component-wise merge.
    pub fn merge(&self, other: &Hist) -> Hist {
        let mut out = Hist::new();
        for i in 0..BUCKETS {
            out.counts[i] = self.counts[i] + other.counts[i];
        }
        out.n = self.n + other.n;
        out.sum = self.sum.saturating_add(other.sum);
        out.min = self.min.min(other.min);
        out.max = self.max.max(other.max);
        out
    }

    /// Percentile in microseconds, `p` in `[0, 1]`.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let rank = (p.clamp(0.0, 1.0) * (self.n - 1) as f64).round() as u64;
        let mut before = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if rank < before + c {
                let lo = Self::bucket_lo(i) as f64;
                let hi = Self::bucket_hi(i) as f64;
                let frac = ((rank - before) as f64 + 0.5) / c as f64;
                let v = lo + frac * (hi - lo);
                let v = v.clamp(self.min as f64, self.max as f64);
                return v.round() as u64;
            }
            before += c;
        }
        self.max
    }

    /// Summary in seconds, mirroring `util::stats::Summary` field names.
    pub fn summary_secs(&self) -> HistSummary {
        HistSummary {
            n: self.n as usize,
            mean: self.mean_us() / 1e6,
            min: self.min_us() as f64 / 1e6,
            max: self.max_us() as f64 / 1e6,
            p50: self.percentile(0.50) as f64 / 1e6,
            p95: self.percentile(0.95) as f64 / 1e6,
            p99: self.percentile(0.99) as f64 / 1e6,
        }
    }
}

/// Percentile summary in seconds. Field names match the printed ledger and
/// the old `util::stats::Summary` so downstream readers stay source-stable.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistSummary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

#[inline]
fn secs_to_us(secs: f64) -> u64 {
    let us = (secs * 1e6).round();
    if !(us > 0.0) {
        0
    } else if us >= u64::MAX as f64 {
        u64::MAX
    } else {
        us as u64
    }
}

struct Shard {
    counts: [AtomicU64; BUCKETS],
    n: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            n: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

/// Lock-free histogram: each thread records into its own shard (plain
/// atomic adds), [`AtomicHist::snapshot`] merges shards into a [`Hist`].
pub struct AtomicHist {
    shards: [Shard; SHARDS],
}

impl Default for AtomicHist {
    fn default() -> AtomicHist {
        AtomicHist::new()
    }
}

impl AtomicHist {
    pub fn new() -> AtomicHist {
        AtomicHist { shards: std::array::from_fn(|_| Shard::new()) }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        let s = &self.shards[shard_index()];
        s.counts[Hist::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        s.n.fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(v, Ordering::Relaxed);
        s.min.fetch_min(v, Ordering::Relaxed);
        s.max.fetch_max(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_secs(&self, secs: f64) {
        self.record(secs_to_us(secs));
    }

    pub fn snapshot(&self) -> Hist {
        let mut out = Hist::new();
        for s in &self.shards {
            for i in 0..BUCKETS {
                out.counts[i] += s.counts[i].load(Ordering::Relaxed);
            }
            out.n += s.n.load(Ordering::Relaxed);
            out.sum = out.sum.saturating_add(s.sum.load(Ordering::Relaxed));
            out.min = out.min.min(s.min.load(Ordering::Relaxed));
            out.max = out.max.max(s.max.load(Ordering::Relaxed));
        }
        out
    }
}

/// Stable per-thread shard assignment (round-robin at first use).
fn shard_index() -> usize {
    use std::cell::Cell;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    SHARD.with(|c| {
        let mut i = c.get();
        if i == usize::MAX {
            i = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
            c.set(i);
        }
        i
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(state: &mut u64) -> u64 {
        *state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        *state >> 16
    }

    #[test]
    fn bucket_boundaries() {
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 1);
        assert_eq!(Hist::bucket_of(2), 2);
        assert_eq!(Hist::bucket_of(3), 2);
        assert_eq!(Hist::bucket_of(4), 3);
        assert_eq!(Hist::bucket_of(255), 8);
        assert_eq!(Hist::bucket_of(256), 9);
        assert_eq!(Hist::bucket_of(1u64 << 63), 64);
        assert_eq!(Hist::bucket_of(u64::MAX), 64);
        for i in 1..BUCKETS {
            assert_eq!(Hist::bucket_of(Hist::bucket_lo(i)), i);
            assert_eq!(Hist::bucket_of(Hist::bucket_hi(i)), i);
            assert_eq!(Hist::bucket_hi(i - 1).wrapping_add(1), Hist::bucket_lo(i));
        }
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Hist::new();
        let s = h.summary_secs();
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 0.0);
        assert_eq!(s.p50, 0.0);
        assert_eq!(s.p99, 0.0);
        assert_eq!(h.percentile(0.5), 0);
    }

    #[test]
    fn single_value_percentiles_are_exact() {
        let mut h = Hist::new();
        h.record(777);
        assert_eq!(h.percentile(0.0), 777);
        assert_eq!(h.percentile(0.5), 777);
        assert_eq!(h.percentile(0.95), 777);
        assert_eq!(h.percentile(1.0), 777);
        assert_eq!(h.min_us(), 777);
        assert_eq!(h.max_us(), 777);
        assert!((h.summary_secs().p50 - 777e-6).abs() < 1e-12);
    }

    #[test]
    fn all_in_one_bucket_stays_within_min_max() {
        let mut h = Hist::new();
        for _ in 0..100 {
            h.record(600);
        }
        // degenerate stream: every percentile is exactly the value
        for p in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.percentile(p), 600);
        }
        // uniform fill of one bucket: interpolation is exact
        let mut u = Hist::new();
        for v in 512..=1023u64 {
            u.record(v);
        }
        assert_eq!(u.percentile(0.5), 768);
        assert!(u.percentile(0.99) >= 512 && u.percentile(0.99) <= 1023);
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        let (mut a, mut b, mut c) = (Hist::new(), Hist::new(), Hist::new());
        let mut st = 42u64;
        for _ in 0..500 {
            a.record(lcg(&mut st) % 100_000);
            b.record(lcg(&mut st) % 10);
            c.record(lcg(&mut st));
        }
        assert_eq!(a.merge(&b), b.merge(&a));
        assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
        // identity
        assert_eq!(a.merge(&Hist::new()), a);
    }

    #[test]
    fn sequential_equals_merged_across_threads() {
        let vals: Vec<u64> = {
            let mut st = 7u64;
            (0..4000).map(|_| lcg(&mut st) % 1_000_000).collect()
        };
        let mut seq = Hist::new();
        for &v in &vals {
            seq.record(v);
        }
        // shard by hand into 4 Hists, merge
        let merged = std::thread::scope(|scope| {
            let handles: Vec<_> = vals
                .chunks(1000)
                .map(|chunk| {
                    scope.spawn(move || {
                        let mut h = Hist::new();
                        for &v in chunk {
                            h.record(v);
                        }
                        h
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .fold(Hist::new(), |acc, h| acc.merge(&h))
        });
        assert_eq!(seq, merged);
        // lock-free shard recording snapshots to the same histogram
        let at = AtomicHist::new();
        std::thread::scope(|scope| {
            for chunk in vals.chunks(1000) {
                let at = &at;
                scope.spawn(move || {
                    for &v in chunk {
                        at.record(v);
                    }
                });
            }
        });
        assert_eq!(at.snapshot(), seq);
    }

    #[test]
    fn record_secs_rounds_to_microseconds() {
        let mut h = Hist::new();
        h.record_secs(0.0015); // 1500 us
        assert_eq!(h.max_us(), 1500);
        h.record_secs(-1.0); // clamped to 0
        assert_eq!(h.min_us(), 0);
        h.record_secs(f64::NAN); // NaN clamps to 0, never panics
        assert_eq!(h.n(), 3);
    }
}
