//! Always-on atomic counters/gauges and the periodic snapshot writer.
//!
//! The [`Metrics`] registry is a fixed set of named atomics — incrementing
//! one is a single relaxed `fetch_add` whether or not any sink is
//! configured, so instrumentation costs nothing beyond the atomic itself.
//! When `--metrics-out PATH` is set, [`MetricsSink`] rewrites a JSON
//! snapshot of the registry every N ticks using the same atomic
//! tmp + fsync + rename discipline as `coordinator/checkpoint.rs`, so a
//! reader never observes a torn file.

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::json::Json;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Process-wide metric registry. Names in snapshots match the struct
/// fields; schema is documented in `docs/observability.md`.
#[derive(Debug, Default)]
pub struct Metrics {
    // serve path
    pub serve_accepted: Counter,
    pub serve_served: Counter,
    pub serve_shed: Counter,
    pub serve_errored: Counter,
    pub serve_batches: Counter,
    pub serve_reloads: Counter,
    pub serve_reloads_rejected: Counter,
    pub breaker_trips: Counter,
    // shared resilience plumbing
    pub retries_absorbed: Counter,
    pub retries_exhausted: Counter,
    pub pool_worker_panics: Counter,
    pub pool_worker_retries: Counter,
    // compress path
    pub train_steps: Counter,
    pub blocks_encoded: Counter,
    pub checkpoint_writes: Counter,
    pub checkpoint_resumes: Counter,
    // gauges
    pub queue_depth: Gauge,
    /// 0 = closed, 1 = open, 2 = half-open
    pub breaker_state: Gauge,
}

impl Metrics {
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("serve_accepted", self.serve_accepted.get()),
            ("serve_served", self.serve_served.get()),
            ("serve_shed", self.serve_shed.get()),
            ("serve_errored", self.serve_errored.get()),
            ("serve_batches", self.serve_batches.get()),
            ("serve_reloads", self.serve_reloads.get()),
            ("serve_reloads_rejected", self.serve_reloads_rejected.get()),
            ("breaker_trips", self.breaker_trips.get()),
            ("retries_absorbed", self.retries_absorbed.get()),
            ("retries_exhausted", self.retries_exhausted.get()),
            ("pool_worker_panics", self.pool_worker_panics.get()),
            ("pool_worker_retries", self.pool_worker_retries.get()),
            ("train_steps", self.train_steps.get()),
            ("blocks_encoded", self.blocks_encoded.get()),
            ("checkpoint_writes", self.checkpoint_writes.get()),
            ("checkpoint_resumes", self.checkpoint_resumes.get()),
        ]
    }

    pub fn gauges(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("queue_depth", self.queue_depth.get()),
            ("breaker_state", self.breaker_state.get()),
        ]
    }
}

/// The process-wide registry. Always available; costs one lazy init.
pub fn metrics() -> &'static Metrics {
    static M: OnceLock<Metrics> = OnceLock::new();
    M.get_or_init(Metrics::default)
}

/// Periodic `--metrics-out` snapshot writer.
pub struct MetricsSink {
    path: String,
    every: u64,
    ticks: AtomicU64,
    epoch: Instant,
}

impl MetricsSink {
    pub fn new(path: &str, every: u64, epoch: Instant) -> MetricsSink {
        MetricsSink {
            path: path.to_string(),
            every: every.max(1),
            ticks: AtomicU64::new(0),
            epoch,
        }
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Count one unit of work (a serve batch, a training step, an encoded
    /// block); every `every`-th tick rewrites the snapshot. `extras` is
    /// only invoked when a snapshot is actually due.
    pub fn tick_with<F>(&self, extras: F) -> bool
    where
        F: FnOnce() -> Vec<(&'static str, Json)>,
    {
        let t = self.ticks.fetch_add(1, Ordering::Relaxed) + 1;
        if t % self.every != 0 {
            return false;
        }
        self.write_snapshot(&extras());
        true
    }

    /// Serialize the registry (+ live extras) and atomically replace the
    /// snapshot file: write `{path}.tmp`, fsync, rename — the checkpoint
    /// discipline, so readers never see a partial snapshot.
    pub fn write_snapshot(&self, extras: &[(&'static str, Json)]) {
        let m = metrics();
        let counters = Json::Obj(
            m.counters()
                .into_iter()
                .map(|(k, v)| (k.to_string(), Json::Num(v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            m.gauges()
                .into_iter()
                .map(|(k, v)| (k.to_string(), Json::Num(v as f64)))
                .collect(),
        );
        let live = Json::Obj(
            extras.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        );
        let snap = Json::obj(vec![
            ("ts_us", Json::Num(self.epoch.elapsed().as_micros() as f64)),
            ("ticks", Json::Num(self.ticks.load(Ordering::Relaxed) as f64)),
            ("counters", counters),
            ("gauges", gauges),
            ("live", live),
        ]);
        let _ = atomic_write(&self.path, &snap.to_pretty());
    }
}

fn atomic_write(path: &str, text: &str) -> std::io::Result<()> {
    let tmp = format!("{path}.tmp");
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(text.as_bytes())?;
    f.write_all(b"\n")?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    // best-effort directory fsync so the rename itself is durable
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_are_plain_atomics() {
        let m = Metrics::default();
        m.serve_accepted.inc();
        m.serve_accepted.add(2);
        assert_eq!(m.serve_accepted.get(), 3);
        m.queue_depth.set(7);
        m.queue_depth.set(4);
        assert_eq!(m.queue_depth.get(), 4);
        assert!(m.counters().iter().any(|(k, v)| *k == "serve_accepted" && *v == 3));
        assert!(m.gauges().iter().any(|(k, v)| *k == "queue_depth" && *v == 4));
    }

    #[test]
    fn snapshot_is_atomic_and_parses() {
        let path = std::env::temp_dir()
            .join(format!("miracle_metrics_test_{}.json", std::process::id()));
        let sink = MetricsSink::new(path.to_str().unwrap(), 2, Instant::now());
        // tick 1: not due, extras must not be invoked
        let ran = sink.tick_with(|| panic!("extras invoked before due tick"));
        assert!(!ran);
        // tick 2: due
        let ran = sink.tick_with(|| vec![("qps", Json::num(12.5))]);
        assert!(ran);
        let j = Json::from_file(path.to_str().unwrap()).unwrap();
        assert!(j.get("counters").unwrap().as_obj().unwrap().contains_key("serve_shed"));
        assert!(j.get("gauges").unwrap().as_obj().unwrap().contains_key("breaker_state"));
        assert_eq!(j.get("live").unwrap().get("qps").unwrap().as_f64().unwrap(), 12.5);
        assert!(!std::path::Path::new(&format!("{}.tmp", path.display())).exists());
        let _ = std::fs::remove_file(&path);
    }
}
