//! Block encoder/decoder — Algorithm 1 with chunked candidate scoring.
//!
//! `K = 2^C_loc` candidates per block are scored in `k_chunk`-sized
//! invocations of the backend's `score_chunk` entry (the compute hot-spot);
//! the categorical draw over the proxy distribution  q̃ streams over chunks
//! via Gumbel-max so the full logit vector never needs to be materialized at
//! once. Decoding replays `decode_chunk` for the chunk containing `k*` —
//! shared randomness by construction (both entries derive candidates from
//! the same `(protocol_seed, block, chunk)` stream: jax threefry on the
//! PJRT backend, [`crate::prng::candidate_stream`] on the native one).

use crate::codec::MrcFile;
use crate::model::Layout;
use crate::prng::{Pcg64, StreamingCategorical};
use crate::runtime::ModelArtifacts;
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::Result;
use crate::{ensure, err};

/// Result of encoding one block.
#[derive(Debug, Clone)]
pub struct EncodeOutcome {
    /// transmitted index k* in [0, 2^C_loc)
    pub index: u64,
    /// decoded candidate weights (the values the block is frozen to)
    pub weights: Vec<f32>,
    /// realized KL(q_b || p_b) at encode time in bits (analytic, from the
    /// last training step's KL vector)
    pub kl_bits: f64,
    /// importance-sampling normalizer gap log K - logsumexp(logits) in bits:
    /// ~0 when q̃ approximates q well, large when the K-sample budget was
    /// insufficient (Theorem 3.2 diagnostics)
    pub is_gap_bits: f64,
    /// number of candidates scored
    pub k: u64,
}

/// Score all K candidates of block `b` and draw k* ~ q̃ (Algorithm 1).
/// Freezes the block in the session.
pub fn encode_block(
    session: &mut super::Session,
    b: usize,
) -> Result<EncodeOutcome> {
    let arts = session.arts;
    let meta = &arts.meta;
    let s = meta.s;
    let c_loc_bits = session.cfg.c_loc_bits as u32;
    let k: u64 = 1 << c_loc_bits;
    let (mu_b, rho_b) = session.state.block(b, s);
    let lsp_b = session.layout.block_lsp(b, &session.state.lsp);
    let mask_b = session.layout.block_mask(b).to_vec();

    // upload block parameters once; reuse the device buffers across chunks
    // (perf: K/k_chunk invocations share them)
    let mu_buf = arts.upload(&Arg::F32(TensorF32::new(vec![s], mu_b.to_vec())?))?;
    let rho_buf = arts.upload(&Arg::F32(TensorF32::new(vec![s], rho_b.to_vec())?))?;
    let lsp_buf = arts.upload(&Arg::F32(TensorF32::new(vec![s], lsp_b.clone())?))?;
    let mask_buf = arts.upload(&Arg::F32(TensorF32::new(vec![s], mask_b)?))?;
    let seed_arg = Arg::I32(TensorI32::scalar(session.cfg.protocol_seed));
    let block_arg = Arg::I32(TensorI32::scalar(b as i32));

    // deterministic per-block sampler stream (selection need not be shared;
    // only candidate generation is protocol randomness)
    let draw_rng = Pcg64::seed(session.cfg.train_seed ^ (b as u64) << 1 ^ 0x5E1);
    let mut sampler = StreamingCategorical::new(draw_rng);
    let k_chunk = meta.k_chunk as u64;
    let n_chunks = if k >= k_chunk { k / k_chunk } else { 1 };
    for chunk in 0..n_chunks {
        use crate::runtime::Input;
        let chunk_arg = Arg::I32(TensorI32::scalar(chunk as i32));
        let outs = arts.invoke_mixed(
            "score_chunk",
            &[
                Input::Host(&seed_arg),
                Input::Host(&block_arg),
                Input::Host(&chunk_arg),
                Input::Dev(&mu_buf),
                Input::Dev(&rho_buf),
                Input::Dev(&lsp_buf),
                Input::Dev(&mask_buf),
            ],
        )?;
        let logits = outs[0].f32s()?;
        let take = if k < k_chunk { k as usize } else { logits.len() };
        sampler.push(&logits[..take]);
    }
    let total = sampler.total() as u64;
    ensure!(total == k, "scored {total} candidates, expected {k}");
    let (index, lse) = sampler.finish();
    let index = index as u64;

    let is_gap_bits = ((k as f64).ln() - lse) / std::f64::consts::LN_2;
    let kl_bits = session.last_kl[b] as f64 / std::f64::consts::LN_2;

    let weights = decode_block_row(arts, session.cfg.protocol_seed, b, index, &lsp_b)?;
    session.freeze_block(b, &weights);
    Ok(EncodeOutcome { index, weights, kl_bits, is_gap_bits, k })
}

/// Decode candidate `index` of block `b`: replay the shared generator for
/// the containing chunk and take the row.
pub fn decode_block_row(
    arts: &ModelArtifacts,
    protocol_seed: i32,
    b: usize,
    index: u64,
    lsp_b: &[f32],
) -> Result<Vec<f32>> {
    let meta = &arts.meta;
    let s = meta.s;
    let k_chunk = meta.k_chunk as u64;
    let (chunk, row) = (index / k_chunk, (index % k_chunk) as usize);
    let outs = arts.invoke(
        "decode_chunk",
        &[
            Arg::I32(TensorI32::scalar(protocol_seed)),
            Arg::I32(TensorI32::scalar(b as i32)),
            Arg::I32(TensorI32::scalar(chunk as i32)),
            Arg::F32(TensorF32::new(vec![s], lsp_b.to_vec())?),
        ],
    )?;
    let cand = outs[0].as_f32()?;
    ensure!(
        cand.shape == vec![meta.k_chunk, s],
        "decode_chunk returned {:?}",
        cand.shape
    );
    Ok(cand.row(row).to_vec())
}

/// Decode a whole `.mrc` into block-layout weights [B*S].
pub fn decode_model(arts: &ModelArtifacts, mrc: &MrcFile) -> Result<Vec<f32>> {
    mrc.validate_for(&arts.meta, arts.backend_family())?;
    let meta = &arts.meta;
    let layout = Layout::generate(meta, mrc.layout_seed);
    let mut w = vec![0f32; meta.b * meta.s];
    for b in 0..meta.b {
        let lsp_b = layout.block_lsp(b, &mrc.lsp);
        let row = decode_block_row(arts, mrc.protocol_seed, b, mrc.indices[b], &lsp_b)?;
        w[b * meta.s..(b + 1) * meta.s].copy_from_slice(&row);
    }
    Ok(w)
}

/// Decode a single block of a `.mrc` (lazy decode path for the server).
pub fn decode_single_block(
    arts: &ModelArtifacts,
    mrc: &MrcFile,
    layout: &Layout,
    b: usize,
) -> Result<Vec<f32>> {
    if b >= mrc.b {
        return err!("block {b} out of range ({} blocks)", mrc.b);
    }
    let lsp_b = layout.block_lsp(b, &mrc.lsp);
    decode_block_row(arts, mrc.protocol_seed, b, mrc.indices[b], &lsp_b)
}
