//! Block encoder/decoder — Algorithm 1 over the batched candidate entries.
//!
//! `K = 2^C_loc` candidates per block are scored in ONE `score_block`
//! backend invocation covering every `k_chunk`-sized chunk (the compute
//! hot-spot; the native backend fans the chunks across the worker pool —
//! see `docs/perf.md`). The categorical draw over the proxy distribution q̃
//! uses streaming Gumbel-max in flat candidate order, so the selected index
//! is independent of how the backend parallelized the scoring. Decoding
//! replays only the transmitted row via `decode_block` — shared randomness
//! by construction (both entries derive candidates from the same
//! `(protocol_seed, block, chunk)` stream: jax threefry on the PJRT
//! backend, [`crate::prng::candidate_stream`] on the native one).
//!
//! [`encode_blocks`] additionally batches the *session-level* loop: all
//! still-unfrozen blocks of an I = 0 schedule are scored in a single
//! `score_blocks` invocation, bit-identical to encoding them one by one.

use crate::codec::MrcFile;
use crate::model::Layout;
use crate::prng::{Pcg64, StreamingCategorical};
use crate::runtime::ModelArtifacts;
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::{pool, Result};
use crate::{ensure, err};

/// Result of encoding one block.
#[derive(Debug, Clone)]
pub struct EncodeOutcome {
    /// transmitted index k* in [0, 2^C_loc)
    pub index: u64,
    /// decoded candidate weights (the values the block is frozen to)
    pub weights: Vec<f32>,
    /// realized KL(q_b || p_b) at encode time in bits (analytic, from the
    /// last training step's KL vector)
    pub kl_bits: f64,
    /// importance-sampling normalizer gap log K - logsumexp(logits) in bits:
    /// ~0 when q̃ approximates q well, large when the K-sample budget was
    /// insufficient (Theorem 3.2 diagnostics)
    pub is_gap_bits: f64,
    /// number of candidates scored
    pub k: u64,
}

/// The per-block Gumbel-max selection stream. Deterministic per block and
/// independent of encode order / thread count; only candidate *generation*
/// is protocol randomness, the draw is encoder-local.
fn draw_rng(train_seed: u64, b: usize) -> Pcg64 {
    Pcg64::seed(train_seed ^ (b as u64) << 1 ^ 0x5E1)
}

/// Upper bound on logits materialized by one batched scoring invocation
/// (2^21 f32 = 8 MB). Budgets above it fall back to streaming chunk-level
/// calls, so huge `C_loc` settings cannot balloon memory — the pre-batching
/// O(k_chunk) behavior is preserved where it matters.
const MAX_CANDIDATES_PER_CALL: usize = 1 << 21;

/// (K, n_chunks) for a session's local coding budget, bounded to the i32
/// scalar range the backend entries speak. `n_chunks` rounds up, so chunk
/// sizes that do not divide K still cover every candidate (the trailing
/// chunk is scored past K and truncated by the caller).
fn candidate_geometry(c_loc_bits: u8, k_chunk: usize) -> Result<(u64, u64)> {
    ensure!(
        c_loc_bits >= 1 && c_loc_bits <= 30,
        "c_loc_bits {c_loc_bits} outside the supported range 1..=30 \
         (indices travel as i32 scalars through the backend entries)"
    );
    let k: u64 = 1 << c_loc_bits;
    let k_chunk = (k_chunk as u64).max(1);
    let n_chunks = (k + k_chunk - 1) / k_chunk;
    Ok((k, n_chunks))
}

/// Selection epilogue shared by every encode path: (index, IS-gap bits,
/// realized KL bits) from a finished Gumbel-max draw.
fn selection_stats(
    session: &super::Session,
    b: usize,
    index: usize,
    lse: f64,
    k: u64,
) -> (u64, f64, f64) {
    let is_gap_bits = ((k as f64).ln() - lse) / std::f64::consts::LN_2;
    let kl_bits = session.last_kl[b] as f64 / std::f64::consts::LN_2;
    (index as u64, is_gap_bits, kl_bits)
}

/// Finish one block's selection from its flat logit slice.
fn select_index(
    session: &super::Session,
    b: usize,
    logits: &[f32],
    k: u64,
) -> (u64, f64, f64) {
    let mut sampler = StreamingCategorical::new(draw_rng(session.cfg.train_seed, b));
    sampler.push(&logits[..k as usize]);
    let (index, lse) = sampler.finish();
    selection_stats(session, b, index, lse, k)
}

/// Score all K candidates of block `b` and draw k* ~ q̃ (Algorithm 1).
/// Freezes the block in the session.
///
/// For budgets within `MAX_CANDIDATES_PER_CALL` (every practical
/// setting) this is ONE batched `score_block` invocation; larger budgets
/// stream `score_chunk` calls with upload-once row buffers so memory stays
/// O(k_chunk). Both paths select bit-identical indices: the logits are the
/// same values in the same flat order, and the Gumbel-max stream is
/// per-block deterministic.
pub fn encode_block(
    session: &mut super::Session,
    b: usize,
) -> Result<EncodeOutcome> {
    let _sp = crate::obs::span("encode_block");
    let arts = session.arts;
    let meta = &arts.meta;
    let s = meta.s;
    let (k, n_chunks) = candidate_geometry(session.cfg.c_loc_bits, meta.k_chunk)?;
    let (mu_b, rho_b) = session.state.block(b, s);
    let lsp_b = session.layout.block_lsp(b, &session.state.lsp);
    let mask_b = session.layout.block_mask(b).to_vec();

    let _threads = pool::override_threads(session.cfg.threads);
    let batched = (n_chunks as usize).saturating_mul(meta.k_chunk)
        <= MAX_CANDIDATES_PER_CALL;
    let (index, is_gap_bits, kl_bits) = if batched {
        let outs = arts.invoke(
            "score_block",
            &[
                Arg::I32(TensorI32::scalar(session.cfg.protocol_seed)),
                Arg::I32(TensorI32::scalar(b as i32)),
                Arg::I32(TensorI32::scalar(n_chunks as i32)),
                Arg::F32(TensorF32::new(vec![s], mu_b.to_vec())?),
                Arg::F32(TensorF32::new(vec![s], rho_b.to_vec())?),
                Arg::F32(TensorF32::new(vec![s], lsp_b.clone())?),
                Arg::F32(TensorF32::new(vec![s], mask_b)?),
            ],
        )?;
        let logits = outs[0].f32s()?;
        ensure!(
            logits.len() as u64 >= k,
            "score_block returned {} logits, expected >= {k}",
            logits.len()
        );
        select_index(session, b, logits, k)
    } else {
        // huge-K fallback: chunk-level calls against uploaded-once block
        // rows, streamed straight into the Gumbel-max sampler
        use crate::runtime::Input;
        let mu_buf =
            arts.upload(&Arg::F32(TensorF32::new(vec![s], mu_b.to_vec())?))?;
        let rho_buf =
            arts.upload(&Arg::F32(TensorF32::new(vec![s], rho_b.to_vec())?))?;
        let lsp_buf =
            arts.upload(&Arg::F32(TensorF32::new(vec![s], lsp_b.clone())?))?;
        let mask_buf =
            arts.upload(&Arg::F32(TensorF32::new(vec![s], mask_b)?))?;
        let seed_arg = Arg::I32(TensorI32::scalar(session.cfg.protocol_seed));
        let block_arg = Arg::I32(TensorI32::scalar(b as i32));
        let mut sampler =
            StreamingCategorical::new(draw_rng(session.cfg.train_seed, b));
        let mut remaining = k as usize;
        for chunk in 0..n_chunks {
            let chunk_arg = Arg::I32(TensorI32::scalar(chunk as i32));
            let outs = arts.invoke_mixed(
                "score_chunk",
                &[
                    Input::Host(&seed_arg),
                    Input::Host(&block_arg),
                    Input::Host(&chunk_arg),
                    Input::Dev(&mu_buf),
                    Input::Dev(&rho_buf),
                    Input::Dev(&lsp_buf),
                    Input::Dev(&mask_buf),
                ],
            )?;
            let logits = outs[0].f32s()?;
            let take = remaining.min(logits.len());
            sampler.push(&logits[..take]);
            remaining -= take;
        }
        ensure!(remaining == 0, "scored {} candidates short of K={k}", remaining);
        let (index, lse) = sampler.finish();
        selection_stats(session, b, index, lse, k)
    };

    let weights = decode_block_row(arts, session.cfg.protocol_seed, b, index, &lsp_b)?;
    session.freeze_block(b, &weights);
    Ok(EncodeOutcome { index, weights, kl_bits, is_gap_bits, k })
}

/// Encode several blocks against the *current* session state via batched
/// `score_blocks` backend invocations (the session-level encode fan-out),
/// grouped so no single call materializes more than
/// `MAX_CANDIDATES_PER_CALL` logits.
///
/// Only valid when no variational updates happen between the individual
/// encodes — the paper's I = 0 schedule — because every block is scored
/// against the state as of entry (freezing a block never feeds back into
/// the scoring inputs). Under that schedule the result is bit-identical to
/// calling [`encode_block`] on each block in order: the candidate streams,
/// per-block selection streams and logits are all independent of batching,
/// grouping and thread count.
pub fn encode_blocks(
    session: &mut super::Session,
    blocks: &[usize],
) -> Result<Vec<EncodeOutcome>> {
    if blocks.is_empty() {
        return Ok(Vec::new());
    }
    let _sp = crate::obs::span("encode_blocks");
    let k_chunk = session.arts.meta.k_chunk;
    let (_, n_chunks) = candidate_geometry(session.cfg.c_loc_bits, k_chunk)?;
    let per = (n_chunks as usize).saturating_mul(k_chunk);
    if per > MAX_CANDIDATES_PER_CALL {
        // one block alone exceeds the batch budget — stream block by block
        // (encode_block's huge-K path); freezing never feeds back into the
        // scoring inputs, so this is still bit-identical
        return blocks.iter().map(|&b| encode_block(session, b)).collect();
    }
    // bound the materialized logits at group_len * per <= the budget
    let group_len = (MAX_CANDIDATES_PER_CALL / per).max(1);
    let mut outcomes = Vec::with_capacity(blocks.len());
    for group in blocks.chunks(group_len) {
        outcomes.extend(encode_block_group(session, group)?);
    }
    Ok(outcomes)
}

/// One `score_blocks` invocation for a bounded group of blocks.
fn encode_block_group(
    session: &mut super::Session,
    blocks: &[usize],
) -> Result<Vec<EncodeOutcome>> {
    let arts = session.arts;
    let meta = &arts.meta;
    let s = meta.s;
    let (k, n_chunks) = candidate_geometry(session.cfg.c_loc_bits, meta.k_chunk)?;
    let nb = blocks.len();
    let mut blk_ids = Vec::with_capacity(nb);
    let mut mu = Vec::with_capacity(nb * s);
    let mut rho = Vec::with_capacity(nb * s);
    let mut lsp_flat = Vec::with_capacity(nb * s);
    let mut mask_flat = Vec::with_capacity(nb * s);
    let mut lsp_rows: Vec<Vec<f32>> = Vec::with_capacity(nb);
    for &b in blocks {
        ensure!(b < meta.b, "block {b} out of range ({} blocks)", meta.b);
        let (mu_b, rho_b) = session.state.block(b, s);
        mu.extend_from_slice(mu_b);
        rho.extend_from_slice(rho_b);
        let lsp_b = session.layout.block_lsp(b, &session.state.lsp);
        lsp_flat.extend_from_slice(&lsp_b);
        lsp_rows.push(lsp_b);
        mask_flat.extend_from_slice(session.layout.block_mask(b));
        blk_ids.push(b as i32);
    }

    let _threads = pool::override_threads(session.cfg.threads);
    let outs = arts.invoke(
        "score_blocks",
        &[
            Arg::I32(TensorI32::scalar(session.cfg.protocol_seed)),
            Arg::I32(TensorI32::new(vec![nb], blk_ids)?),
            Arg::I32(TensorI32::scalar(n_chunks as i32)),
            Arg::F32(TensorF32::new(vec![nb * s], mu)?),
            Arg::F32(TensorF32::new(vec![nb * s], rho)?),
            Arg::F32(TensorF32::new(vec![nb * s], lsp_flat)?),
            Arg::F32(TensorF32::new(vec![nb * s], mask_flat)?),
        ],
    )?;
    let logits = outs[0].f32s()?;
    let per = (n_chunks as usize) * meta.k_chunk;
    ensure!(
        logits.len() == nb * per,
        "score_blocks returned {} logits, expected {nb} x {per}",
        logits.len()
    );

    let mut outcomes = Vec::with_capacity(nb);
    for (bi, &b) in blocks.iter().enumerate() {
        let (index, is_gap_bits, kl_bits) =
            select_index(session, b, &logits[bi * per..(bi + 1) * per], k);
        let weights =
            decode_block_row(arts, session.cfg.protocol_seed, b, index, &lsp_rows[bi])?;
        session.freeze_block(b, &weights);
        outcomes.push(EncodeOutcome { index, weights, kl_bits, is_gap_bits, k });
    }
    Ok(outcomes)
}

/// Decode candidate `index` of block `b`: one `decode_block` invocation
/// replaying only the transmitted row of the shared generator.
pub fn decode_block_row(
    arts: &ModelArtifacts,
    protocol_seed: i32,
    b: usize,
    index: u64,
    lsp_b: &[f32],
) -> Result<Vec<f32>> {
    let meta = &arts.meta;
    ensure!(
        index <= i32::MAX as u64,
        "candidate index {index} exceeds the i32 range of the decode_block entry"
    );
    let mut outs = arts.invoke(
        "decode_block",
        &[
            Arg::I32(TensorI32::scalar(protocol_seed)),
            Arg::I32(TensorI32::scalar(b as i32)),
            Arg::I32(TensorI32::scalar(index as i32)),
            Arg::F32(TensorF32::new(vec![meta.s], lsp_b.to_vec())?),
        ],
    )?;
    let row = outs.remove(0).into_f32s()?;
    ensure!(
        row.len() == meta.s,
        "decode_block returned {} values, expected S={}",
        row.len(),
        meta.s
    );
    Ok(row)
}

/// Decode a whole `.mrc` into block-layout weights [B*S].
pub fn decode_model(arts: &ModelArtifacts, mrc: &MrcFile) -> Result<Vec<f32>> {
    mrc.validate_for(&arts.meta, arts.backend_family())?;
    let meta = &arts.meta;
    let layout = Layout::generate(meta, mrc.layout_seed);
    let mut w = vec![0f32; meta.b * meta.s];
    for b in 0..meta.b {
        let lsp_b = layout.block_lsp(b, &mrc.lsp);
        let row = decode_block_row(arts, mrc.protocol_seed, b, mrc.indices[b], &lsp_b)
            .map_err(|e| e.context(format!("decode block {b}")))?;
        w[b * meta.s..(b + 1) * meta.s].copy_from_slice(&row);
    }
    Ok(w)
}

/// Decode a single block of a `.mrc` (lazy decode path for the server).
pub fn decode_single_block(
    arts: &ModelArtifacts,
    mrc: &MrcFile,
    layout: &Layout,
    b: usize,
) -> Result<Vec<f32>> {
    if b >= mrc.b {
        return err!("block {b} out of range ({} blocks)", mrc.b);
    }
    let lsp_b = layout.block_lsp(b, &mrc.lsp);
    decode_block_row(arts, mrc.protocol_seed, b, mrc.indices[b], &lsp_b)
}
