//! Training session state + the variational update driver.
//!
//! One `Session` owns everything Algorithm 2 mutates: the variational state
//! in block layout, the β controller, the freeze set, and the batch stream.
//! `train_step` performs one in-graph Adam update through the backend's
//! `train_step` entry point and applies the β annealing sweep on the
//! returned per-block KL vector.

use crate::data::{BatchIter, Dataset};
use crate::model::init::{InitCfg, VarState};
use crate::model::Layout;
use crate::prng::Pcg64;
use crate::runtime::{DeviceBuf, ModelArtifacts};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::Result;

use super::beta::BetaController;
use super::MiracleCfg;

/// Metrics of one variational update.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepMetrics {
    pub loss: f32,
    pub ce: f32,
    pub acc: f32,
    pub mean_kl_nats: f32,
}

/// Typed payload of the error [`Session::train_step`] returns when the loss
/// or a per-block KL stops being finite — divergence, not a code bug, so the
/// coordinator can apply a policy (`--on-nonfinite {abort|rewind}`) instead
/// of propagating NaNs into the `.mrc`. Retrieve it with
/// [`crate::util::Error::payload`]`::<NonFinite>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NonFinite {
    /// 1-based global step at which the divergence was detected
    pub step: i32,
    /// offending block for a KL blow-up; `None` when the loss itself is bad
    pub block: Option<usize>,
}

pub struct Session<'a> {
    pub arts: &'a ModelArtifacts,
    pub layout: Layout,
    pub state: VarState,
    pub betas: BetaController,
    pub frozen_mask: Vec<f32>,
    pub frozen_w: Vec<f32>,
    pub cfg: MiracleCfg,
    pub history: Vec<StepMetrics>,
    /// last per-block KL (nats) returned by the graph
    pub last_kl: Vec<f32>,
    /// fault injection (tests / fuzzing): report a synthetic non-finite
    /// loss at this 1-based step. Consumed when it fires, so a rewound
    /// retry of the same schedule runs clean.
    pub fault_nonfinite_at: Option<i32>,
    train: &'a Dataset,
    iter: BatchIter,
    seed_rng: Pcg64,
    // static layout maps, uploaded to the backend once (perf: ~0.5 MB/step
    // of re-validation + host->device copies saved at lenet scale)
    amap_buf: DeviceBuf,
    lmap_buf: DeviceBuf,
    smask_buf: DeviceBuf,
}

impl<'a> Session<'a> {
    pub fn new(
        arts: &'a ModelArtifacts,
        train: &'a Dataset,
        cfg: &MiracleCfg,
    ) -> Result<Session<'a>> {
        let meta = &arts.meta;
        let layout = Layout::generate(meta, cfg.layout_seed);
        let state = VarState::init(meta, &layout, &InitCfg::default(), cfg.train_seed);
        let betas = BetaController::new(meta.b, cfg.beta0, cfg.eps_beta, cfg.c_loc_bits);
        let amap_buf = arts.upload(&Arg::I32(TensorI32::new(
            vec![meta.n_total],
            layout.assemble_map.clone(),
        )?))?;
        let lmap_buf = arts.upload(&Arg::I32(TensorI32::new(
            vec![meta.b, meta.s],
            layout.layer_map.clone(),
        )?))?;
        let smask_buf = arts.upload(&Arg::F32(TensorF32::new(
            vec![meta.b, meta.s],
            layout.slot_mask.clone(),
        )?))?;
        Ok(Session {
            arts,
            state,
            betas,
            frozen_mask: vec![0.0; meta.b],
            frozen_w: vec![0.0; meta.b * meta.s],
            cfg: cfg.clone(),
            history: Vec::new(),
            last_kl: vec![0.0; meta.b],
            fault_nonfinite_at: None,
            train,
            iter: BatchIter::new(train.len(), meta.batch, cfg.train_seed),
            seed_rng: Pcg64::seed(cfg.train_seed ^ 0x57EB),
            layout,
            amap_buf,
            lmap_buf,
            smask_buf,
        })
    }

    pub fn b(&self) -> usize {
        self.arts.meta.b
    }

    /// One variational update (in-graph Adam) + β annealing sweep.
    /// `learn_p` controls whether the encoding distribution p still adapts;
    /// it must be false once any block has been encoded.
    pub fn train_step(&mut self, learn_p: bool) -> Result<StepMetrics> {
        let meta = &self.arts.meta;
        let step = self.state.step + 1;
        if self.fault_nonfinite_at == Some(step) {
            // fire before any stream is consumed: the session stays at the
            // pre-step state, exactly as if the backend had reported NaN
            self.fault_nonfinite_at = None;
            return Err(crate::util::Error::with_payload(
                format!("non-finite loss at step {step} (injected fault)"),
                NonFinite { step, block: None },
            ));
        }
        let (bx, by) = self.train.gather(&self.iter.next_indices());
        let seed = (self.seed_rng.next_u32() & 0x7fff_ffff) as i32;
        let bs = vec![meta.b, meta.s];
        let l = vec![meta.n_layers];
        let f = |shape: &Vec<usize>, data: &Vec<f32>| -> Result<Arg> {
            Ok(Arg::F32(TensorF32::new(shape.clone(), data.clone())?))
        };
        let host: Vec<Arg> = vec![
            f(&bs, &self.state.mu)?,
            f(&bs, &self.state.rho)?,
            f(&l, &self.state.lsp)?,
            f(&bs, &self.state.m_mu)?,
            f(&bs, &self.state.v_mu)?,
            f(&bs, &self.state.m_rho)?,
            f(&bs, &self.state.v_rho)?,
            f(&l, &self.state.m_lsp)?,
            f(&l, &self.state.v_lsp)?,
            Arg::I32(TensorI32::scalar(step)),
            Arg::F32(bx),
            Arg::I32(TensorI32::new(vec![meta.batch], by)?),
            f(&vec![meta.b], &self.betas.beta)?,
            f(&vec![meta.b], &self.frozen_mask)?,
            f(&bs, &self.frozen_w)?,
            Arg::I32(TensorI32::scalar(seed)),
            Arg::F32(TensorF32::scalar(self.cfg.data_scale)),
            Arg::F32(TensorF32::scalar(if learn_p { 1.0 } else { 0.0 })),
            Arg::F32(TensorF32::scalar(self.cfg.lr)),
        ];
        use crate::runtime::Input;
        let ins: Vec<Input> = vec![
            Input::Host(&host[0]),
            Input::Host(&host[1]),
            Input::Host(&host[2]),
            Input::Host(&host[3]),
            Input::Host(&host[4]),
            Input::Host(&host[5]),
            Input::Host(&host[6]),
            Input::Host(&host[7]),
            Input::Host(&host[8]),
            Input::Host(&host[9]),
            Input::Host(&host[10]),
            Input::Host(&host[11]),
            Input::Host(&host[12]),
            Input::Host(&host[13]),
            Input::Host(&host[14]),
            Input::Host(&host[15]),
            Input::Dev(&self.amap_buf),
            Input::Dev(&self.lmap_buf),
            Input::Dev(&self.smask_buf),
            Input::Host(&host[16]),
            Input::Host(&host[17]),
            Input::Host(&host[18]),
        ];
        let outs = self.arts.invoke_mixed("train_step", &ins)?;
        // consume the outputs in order — moves the backend's buffers into
        // the session state instead of re-copying ~0.5 MB/step at lenet
        // scale
        let mut outs = outs.into_iter();
        let mut take = || -> Result<Vec<f32>> {
            outs.next()
                .ok_or_else(|| crate::util::Error::msg("train_step: missing output"))?
                .into_f32s()
        };
        self.state.mu = take()?;
        self.state.rho = take()?;
        self.state.lsp = take()?;
        self.state.m_mu = take()?;
        self.state.v_mu = take()?;
        self.state.m_rho = take()?;
        self.state.v_rho = take()?;
        self.state.m_lsp = take()?;
        self.state.v_lsp = take()?;
        let loss = take()?[0];
        let ce = take()?[0];
        let acc = take()?[0];
        self.last_kl = take()?;
        self.state.step = step;

        // Divergence tripwire: a NaN/Inf loss or per-block KL means the
        // variational state can no longer be trusted — every later step and
        // every encode would launder the poison into the `.mrc`. Surface it
        // as a structured error the coordinator's --on-nonfinite policy can
        // downcast, instead of a number that fails much later.
        if !loss.is_finite() {
            return Err(crate::util::Error::with_payload(
                format!("non-finite loss ({loss}) at step {step}"),
                NonFinite { step, block: None },
            ));
        }
        if let Some(b) = self.last_kl.iter().position(|k| !k.is_finite()) {
            return Err(crate::util::Error::with_payload(
                format!("non-finite KL for block {b} at step {step}"),
                NonFinite { step, block: Some(b) },
            ));
        }

        self.betas.update(&self.last_kl, &self.frozen_mask);

        let mean_kl = unfrozen_mean(&self.last_kl, &self.frozen_mask);
        crate::obs::metrics().train_steps.inc();
        crate::obs_event!(crate::obs::Level::Debug, "train_step",
            "step" => step,
            "loss" => loss,
            "ce" => ce,
            "acc" => acc,
            "mean_kl_nats" => mean_kl,
            "beta_mean" => {
                let n = self.betas.beta.len().max(1) as f32;
                self.betas.beta.iter().copied().sum::<f32>() / n
            });
        let m = StepMetrics { loss, ce, acc, mean_kl_nats: mean_kl };
        self.history.push(m);
        Ok(m)
    }

    /// Advance the batch-order and per-step seed streams past `steps`
    /// already-performed updates without touching any other state. Resume
    /// support: each `train_step` consumes exactly one `BatchIter` draw and
    /// one seed-rng `next_u32`, so a *fresh* session fast-forwarded by the
    /// checkpointed step count is stream-for-stream identical to the
    /// session that performed those steps live — the key to byte-identical
    /// `.mrc` output after a crash (see `docs/checkpoint-format.md`).
    pub fn fast_forward_streams(&mut self, steps: usize) {
        for _ in 0..steps {
            let _ = self.iter.next_indices();
            let _ = self.seed_rng.next_u32();
        }
    }

    /// Initialize means from a pretrained dense weight vector (paper §4:
    /// VGG means start from a pretrained model). Call before training.
    pub fn init_means_from_dense(&mut self, w_full: &[f32]) {
        self.state.init_means_from_dense(&self.layout, w_full);
    }

    /// Pin block `b` to encoded values.
    pub fn freeze_block(&mut self, b: usize, w: &[f32]) {
        let s = self.arts.meta.s;
        debug_assert_eq!(w.len(), s);
        self.frozen_mask[b] = 1.0;
        self.frozen_w[b * s..(b + 1) * s].copy_from_slice(w);
    }

    pub fn last_loss(&self) -> f32 {
        self.history.last().map(|m| m.loss).unwrap_or(f32::NAN)
    }

    pub fn last_acc(&self) -> f32 {
        self.history.last().map(|m| m.acc).unwrap_or(f32::NAN)
    }

    /// Mean unfrozen per-block KL in bits.
    pub fn mean_kl_bits(&self) -> f64 {
        unfrozen_mean(&self.last_kl, &self.frozen_mask) as f64 / std::f64::consts::LN_2
    }

    /// Draw a posterior weight sample (frozen blocks pinned) — for
    /// stochastic evaluation.
    pub fn sample_weights(&self, seed: i32) -> Result<Vec<f32>> {
        let meta = &self.arts.meta;
        let bs = vec![meta.b, meta.s];
        let mut outs = self.arts.invoke(
            "sample_weights",
            &[
                Arg::F32(TensorF32::new(bs.clone(), self.state.mu.clone())?),
                Arg::F32(TensorF32::new(bs.clone(), self.state.rho.clone())?),
                Arg::F32(TensorF32::new(vec![meta.b], self.frozen_mask.clone())?),
                Arg::F32(TensorF32::new(bs, self.frozen_w.clone())?),
                Arg::I32(TensorI32::scalar(seed)),
            ],
        )?;
        outs.remove(0).into_f32s()
    }
}

fn unfrozen_mean(kl: &[f32], fm: &[f32]) -> f32 {
    let mut sum = 0f64;
    let mut n = 0usize;
    for (&k, &f) in kl.iter().zip(fm) {
        if f == 0.0 {
            sum += k as f64;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::unfrozen_mean;

    #[test]
    fn unfrozen_mean_ignores_frozen() {
        let kl = [1.0f32, 100.0, 3.0];
        let fm = [0.0f32, 1.0, 0.0];
        assert!((unfrozen_mean(&kl, &fm) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn unfrozen_mean_all_frozen() {
        assert_eq!(unfrozen_mean(&[5.0], &[1.0]), 0.0);
    }
}
