//! Per-block β annealing controller (Algorithm 2, lines 19–25).
//!
//! Every variational update, each not-yet-coded block whose KL exceeds the
//! local coding goal `C_loc` gets its penalty multiplied by `(1 + ε_β)`, and
//! divided by the same factor otherwise. The controller is the paper's
//! "explicit control over the compression rate": β_b converges to the value
//! that pins `KL_b ≈ C_loc`.

/// β state for all blocks.
#[derive(Debug, Clone)]
pub struct BetaController {
    pub beta: Vec<f32>,
    pub c_loc_nats: f64,
    pub eps_beta: f32,
    /// clamp range keeps β finite under long runs
    pub min_beta: f32,
    pub max_beta: f32,
}

impl BetaController {
    pub fn new(b: usize, beta0: f32, eps_beta: f32, c_loc_bits: u8) -> BetaController {
        BetaController {
            beta: vec![beta0; b],
            c_loc_nats: c_loc_bits as f64 * std::f64::consts::LN_2,
            eps_beta,
            min_beta: 1e-12,
            max_beta: 1e4,
        }
    }

    /// One annealing sweep given per-block KL (nats) and the frozen mask.
    pub fn update(&mut self, kl_nats: &[f32], frozen_mask: &[f32]) {
        debug_assert_eq!(kl_nats.len(), self.beta.len());
        let up = 1.0 + self.eps_beta;
        for ((beta, &kl), &fm) in self
            .beta
            .iter_mut()
            .zip(kl_nats)
            .zip(frozen_mask)
        {
            if fm > 0.0 {
                continue; // coded blocks keep their last β (unused anyway)
            }
            if (kl as f64) > self.c_loc_nats {
                *beta = (*beta * up).min(self.max_beta);
            } else {
                *beta = (*beta / up).max(self.min_beta);
            }
        }
    }

    /// Fraction of unfrozen blocks currently within the coding goal.
    pub fn within_goal(&self, kl_nats: &[f32], frozen_mask: &[f32]) -> f64 {
        let mut total = 0usize;
        let mut ok = 0usize;
        for (&kl, &fm) in kl_nats.iter().zip(frozen_mask) {
            if fm > 0.0 {
                continue;
            }
            total += 1;
            if (kl as f64) <= self.c_loc_nats {
                ok += 1;
            }
        }
        if total == 0 {
            1.0
        } else {
            ok as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anneals_up_when_over_budget() {
        let mut c = BetaController::new(3, 1e-8, 5e-5, 10);
        let kl = [100.0f32, 0.1, 100.0];
        let fm = [0.0f32, 0.0, 1.0];
        let before = c.beta.clone();
        c.update(&kl, &fm);
        assert!(c.beta[0] > before[0]); // over budget -> up
        assert!(c.beta[1] < before[1]); // under budget -> down
        assert_eq!(c.beta[2], before[2]); // frozen -> untouched
    }

    #[test]
    fn converges_to_equilibrium_in_simulation() {
        // toy dynamics: KL responds to beta as kl = a / (1 + c*beta); the
        // controller should drive kl toward c_loc
        let mut c = BetaController::new(1, 1e-8, 5e-3, 8);
        let target = c.c_loc_nats;
        let mut kl = 50.0f64;
        for _ in 0..200_000 {
            kl = 50.0 / (1.0 + 2000.0 * c.beta[0] as f64);
            c.update(&[kl as f32], &[0.0]);
        }
        assert!(
            (kl - target).abs() / target < 0.2,
            "kl {kl} vs target {target}"
        );
    }

    #[test]
    fn clamps() {
        let mut c = BetaController::new(1, 1e-8, 5e-1, 4);
        for _ in 0..2000 {
            c.update(&[1e9], &[0.0]);
        }
        assert!(c.beta[0] <= c.max_beta);
        for _ in 0..5000 {
            c.update(&[0.0], &[0.0]);
        }
        assert!(c.beta[0] >= c.min_beta);
    }

    #[test]
    fn within_goal_counts() {
        let c = BetaController::new(4, 1e-8, 5e-5, 10);
        let nats = c.c_loc_nats as f32;
        let kl = [nats * 0.5, nats * 2.0, nats * 0.9, nats * 3.0];
        let fm = [0.0f32, 0.0, 0.0, 1.0];
        assert!((c.within_goal(&kl, &fm) - 2.0 / 3.0).abs() < 1e-9);
    }
}
