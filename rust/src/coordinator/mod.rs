//! The MIRACLE coordinator — Algorithm 2 of the paper.
//!
//! Owns the full compression run: initial variational convergence (I_0
//! steps), the random block-encode schedule, per-block β annealing against
//! the local coding goal `C_loc`, intermediate variational updates of
//! not-yet-coded blocks, and final `.mrc` emission. All numerical work runs
//! through the pluggable runtime backend ([`crate::runtime`] — pure-Rust by
//! default, AOT/PJRT behind the `xla` feature); this module owns only
//! control flow and state. See `DESIGN.md` for the full Algorithm-2 walk.

pub mod beta;
pub mod checkpoint;
pub mod encoder;
pub mod session;

pub use beta::BetaController;
pub use checkpoint::{fingerprint, Checkpoint, CkptError, CkptResult};
pub use encoder::{decode_model, encode_block, encode_blocks, EncodeOutcome};
pub use session::{NonFinite, Session, StepMetrics};

use crate::codec::MrcFile;
use crate::data::Dataset;
use crate::obs::{self, Level as Ev};
use crate::prng::Pcg64;
use crate::runtime::ModelArtifacts;
use crate::util::json::Json;
use crate::util::{Error, Result, Timer};
use crate::{ensure, err, info, obs_event};

/// Hyper-parameters of a MIRACLE run (paper §3.3 / §4 defaults).
#[derive(Debug, Clone)]
pub struct MiracleCfg {
    /// local coding goal per block, in bits (K = 2^c_loc_bits)
    pub c_loc_bits: u8,
    /// initial variational iterations before any encoding (paper: 1e4)
    pub i0: usize,
    /// intermediate variational iterations per encoded block (paper: 50 / 1)
    pub i_intermediate: usize,
    pub lr: f32,
    /// β starting value ε_β0 (paper: 1e-8)
    pub beta0: f32,
    /// β annealing rate ε_β (paper: 5e-5)
    pub eps_beta: f32,
    /// dataset size factor applied to the batch-mean CE (ELBO sum scale)
    pub data_scale: f32,
    /// seed for the hashing trick + block permutation (travels in .mrc)
    pub layout_seed: u64,
    /// base seed of the shared candidate generator (travels in .mrc)
    pub protocol_seed: i32,
    /// seed for batch order + per-step reparameterization keys
    pub train_seed: u64,
    /// worker threads for the candidate hot path (0 = auto: the
    /// `MIRACLE_THREADS` env var, else all cores). Selected indices and
    /// decoded weights are identical at every setting — see `docs/perf.md`.
    pub threads: usize,
}

impl Default for MiracleCfg {
    fn default() -> MiracleCfg {
        MiracleCfg {
            c_loc_bits: 12,
            i0: 300,
            i_intermediate: 1,
            lr: 1e-3,
            beta0: 1e-8,
            // The paper uses ε_β = 5e-5 over ~10^5-10^6 total updates; our
            // sandbox runs are 10^2-10^4 updates, so the default annealing
            // rate is scaled up to reach the same β range. The CLI exposes
            // --eps-beta for faithful settings.
            eps_beta: 2e-3,
            data_scale: 1.0,
            layout_seed: 0x4D31_7261_636C_6531, // "M1racle1"
            protocol_seed: 7,
            train_seed: 42,
            threads: 0,
        }
    }
}

/// What `compress` does when `train_step` reports a non-finite loss/KL.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NonFinitePolicy {
    /// Fail the run with the structured [`NonFinite`] error (default).
    #[default]
    Abort,
    /// Reload the last durable checkpoint (or restart from scratch if none
    /// was written yet) and retry ONCE with the same protocol seeds; a
    /// second non-finite aborts. The retried run encodes the exact same
    /// schedule, so its `.mrc` is as valid and decodable as an
    /// uninterrupted run's.
    Rewind,
}

/// Typed payload of the structured error returned when a test kill-switch
/// ([`RunOptions::stop_after_blocks`]/[`RunOptions::stop_after_steps`])
/// stops a run after writing its checkpoint — the crash-injection hook the
/// kill-resume equivalence suite is built on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interrupted {
    /// global step count at the simulated kill
    pub step: i32,
    /// blocks encoded at the simulated kill
    pub encoded_blocks: usize,
}

/// Durability / crash-safety options of a [`compress_with`] run. The plain
/// [`compress`] entry point uses `RunOptions::default()` (no checkpointing).
#[derive(Debug)]
pub struct RunOptions {
    /// checkpoint file path (`None` = no durability; the run behaves
    /// exactly as before this option existed)
    pub checkpoint: Option<String>,
    /// encoded blocks between Phase-2 checkpoints (CLI `--checkpoint-every`)
    pub every_blocks: usize,
    /// I_0 steps between Phase-1 checkpoints
    pub every_steps: usize,
    /// resume from `checkpoint` instead of starting fresh (the file must
    /// exist and carry this run's config fingerprint)
    pub resume: bool,
    pub on_nonfinite: NonFinitePolicy,
    /// tests: simulate a kill — checkpoint, then fail with [`Interrupted`]
    /// once this many blocks are encoded (ignored if the run has fewer)
    pub stop_after_blocks: Option<usize>,
    /// tests: simulate a kill after this many I_0 steps
    pub stop_after_steps: Option<usize>,
    /// tests: report a synthetic non-finite loss at this 1-based step; fires
    /// once per run (not re-armed on a rewind retry)
    pub nonfinite_fault: Option<i32>,
}

impl Default for RunOptions {
    fn default() -> RunOptions {
        RunOptions {
            checkpoint: None,
            every_blocks: 64,
            every_steps: 500,
            resume: false,
            on_nonfinite: NonFinitePolicy::Abort,
            stop_after_blocks: None,
            stop_after_steps: None,
            nonfinite_fault: None,
        }
    }
}

/// Outcome of a full compression run.
pub struct CompressResult {
    pub mrc: MrcFile,
    /// test error of the decoded (fully frozen) weights
    pub test_error: f64,
    /// bits actually spent (container total)
    pub total_bits: usize,
    pub train_secs: f64,
    pub encode_secs: f64,
    /// mean realized per-block KL at encode time, in bits
    pub mean_block_kl_bits: f64,
    pub history: Vec<StepMetrics>,
}

/// Run Algorithm 2 end to end on a training set; returns the compressed
/// model and its measured quality.
pub fn compress(
    arts: &ModelArtifacts,
    train: &Dataset,
    test: &Dataset,
    cfg: &MiracleCfg,
) -> Result<CompressResult> {
    compress_with(arts, train, test, cfg, &RunOptions::default())
}

/// [`compress`] with durability: periodic MCK2 checkpoints every
/// [`RunOptions::every_steps`] I_0 steps and every [`RunOptions::every_blocks`]
/// encoded blocks, `--resume` support and the `--on-nonfinite` policy.
/// Resuming from any checkpoint taken at a block boundary produces a
/// **byte-identical** `.mrc` to an uninterrupted run — see
/// `docs/checkpoint-format.md` for the resume-exactness contract.
pub fn compress_with(
    arts: &ModelArtifacts,
    train: &Dataset,
    test: &Dataset,
    cfg: &MiracleCfg,
    opts: &RunOptions,
) -> Result<CompressResult> {
    ensure!(
        (1 << cfg.c_loc_bits as usize) >= 1,
        "c_loc_bits out of range"
    );
    ensure!(
        opts.checkpoint.is_some() || !opts.resume,
        "--resume requires --checkpoint PATH"
    );
    // honor cfg.threads for the WHOLE run (encode fan-out, eval row
    // fan-out), not just the encoder's own invocations
    let _threads = crate::util::pool::override_threads(cfg.threads);
    // pins everything protocol-relevant; `threads` is deliberately absent —
    // selected indices are thread-count invariant (docs/perf.md), so a
    // checkpoint may resume on a machine with a different core count
    let fp = fingerprint(&arts.meta, arts.backend_family(), cfg, train);
    let mut fault = opts.nonfinite_fault;
    let mut rewound = false;
    let (session, indices, encode_secs, kl_bits_sum, train_secs) = loop {
        match run_schedule(arts, train, cfg, opts, fp, fault, rewound) {
            Ok(done) => break done,
            Err(e)
                if e.payload::<NonFinite>().is_some()
                    && opts.on_nonfinite == NonFinitePolicy::Rewind
                    && !rewound =>
            {
                info!(
                    "{e} — rewinding to the last checkpoint and retrying once"
                );
                rewound = true;
                fault = None; // an injected fault fires once per run
            }
            Err(e) => return Err(e),
        }
    };

    let mrc = MrcFile {
        model: arts.meta.name.clone(),
        layout_seed: cfg.layout_seed,
        protocol_seed: cfg.protocol_seed,
        backend: arts.backend_family(),
        b: session.b(),
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: cfg.c_loc_bits,
        lsp: session.state.lsp.clone(),
        indices,
    };

    // Final quality: decode from the container (full round trip) and eval.
    let w_blocks = decode_model(arts, &mrc)?;
    let test_error = eval_error(arts, &session.layout.assemble_map, &w_blocks, test)?;
    let total_bits = mrc.total_bits();
    Ok(CompressResult {
        mrc,
        test_error,
        total_bits,
        train_secs,
        encode_secs,
        mean_block_kl_bits: kl_bits_sum / session.b() as f64,
        history: session.history.clone(),
    })
}

/// One attempt at the full Algorithm-2 schedule (Phase 1 variational
/// convergence + Phase 2 block encoding), resuming from the durable
/// checkpoint when asked to. Returns the finished session, the transmitted
/// indices, the encode/train timings and the realized-KL sum.
fn run_schedule<'a>(
    arts: &'a ModelArtifacts,
    train: &'a Dataset,
    cfg: &MiracleCfg,
    opts: &RunOptions,
    fp: u64,
    fault: Option<i32>,
    rewound: bool,
) -> Result<(Session<'a>, Vec<u64>, f64, f64, f64)> {
    let mut session = Session::new(arts, train, cfg)?;
    session.fault_nonfinite_at = fault;
    let mut indices = vec![u64::MAX; session.b()];
    let mut kl_bits_sum = 0.0f64;

    // Resume: reload the snapshot. Both --resume and a rewind retry land
    // here; a rewind with no checkpoint on disk (crash before the first
    // save) restarts from scratch instead.
    let path = opts.checkpoint.as_deref();
    if opts.resume || rewound {
        if let Some(path) = path {
            let exists = std::path::Path::new(path).exists();
            if !exists && opts.resume && !rewound {
                return err!("--resume: checkpoint {path} does not exist");
            }
            if exists {
                let ck = Checkpoint::load_verified(path, fp)?;
                indices = ck.restore(&mut session)?;
                kl_bits_sum = ck.kl_bits_sum;
                obs::metrics().checkpoint_resumes.inc();
                obs_event!(Ev::Info, "checkpoint_resumed",
                    "path" => path,
                    "step" => ck.step,
                    "encoded_blocks" => ck.encoded_blocks());
                info!(
                    "resumed from {path}: step {}, {}/{} blocks encoded",
                    ck.step,
                    ck.encoded_blocks(),
                    session.b()
                );
            }
        }
    }

    // Phase 2's block order is config-derived, so resume re-derives it and
    // validates that the checkpoint's encode set is exactly a prefix — a
    // checkpoint that disagrees cannot silently alter the protocol.
    let order: Vec<usize> = Pcg64::seed(cfg.train_seed ^ 0x0B10_C0DE)
        .permutation(session.b())
        .into_iter()
        .map(|b| b as usize)
        .collect();
    let done0 = indices.iter().filter(|&&i| i != u64::MAX).count();
    for (i, &b) in order.iter().enumerate() {
        ensure!(
            (indices[b] != u64::MAX) == (i < done0),
            "checkpoint encode set is not a prefix of the derived block \
             order (block {b}) — checkpoint from a different run?"
        );
    }

    let save = |session: &Session, indices: &[u64], kl_sum: f64| -> Result<()> {
        if let Some(p) = path {
            Checkpoint::capture(session, indices, kl_sum).save(p, fp)?;
        }
        Ok(())
    };
    let every_steps = opts.every_steps.max(1);
    let every_blocks = opts.every_blocks.max(1);

    let t_train = Timer::start();
    if done0 == 0 {
        // Phase 1: variational convergence with p learned jointly (I_0
        // steps; a resumed run continues from the checkpointed step).
        while (session.state.step as usize) < cfg.i0 {
            session.train_step(true)?;
            let s = session.state.step as usize;
            obs::metrics_tick(|| {
                vec![
                    ("phase", Json::str("train")),
                    ("step", Json::num(s as f64)),
                    ("loss", Json::num(session.last_loss() as f64)),
                    ("acc", Json::num(session.last_acc() as f64)),
                    ("mean_kl_bits", Json::num(session.mean_kl_bits())),
                ]
            });
            if s % every_steps == 0 && s < cfg.i0 {
                save(&session, &indices, kl_bits_sum)?;
            }
            if opts.stop_after_steps == Some(s) && s < cfg.i0 {
                save(&session, &indices, kl_bits_sum)?;
                return Err(Error::with_payload(
                    format!("interrupted after {s} I0 steps (test kill switch)"),
                    Interrupted { step: session.state.step, encoded_blocks: 0 },
                ));
            }
        }
        // p is frozen from here on: its stddevs travel in the .mrc header
        // and every block must be coded against the same encoding
        // distribution.
        obs_event!(Ev::Info, "i0_done",
            "steps" => cfg.i0,
            "loss" => session.last_loss(),
            "acc" => session.last_acc(),
            "mean_kl_bits" => session.mean_kl_bits(),
            "target_bits" => cfg.c_loc_bits as u32);
        info!(
            "I0 done: loss {:.4} acc {:.3} mean KL {:.2} bits (target {} bits)",
            session.last_loss(),
            session.last_acc(),
            session.mean_kl_bits(),
            cfg.c_loc_bits
        );
    }

    // Phase 2: random block order; encode, then I intermediate updates.
    let mut encode_secs = 0.0;
    if cfg.i_intermediate == 0 {
        // No updates between encodes (paper ablation I = 0): every block is
        // coded against the same variational state, so the sweep is scored
        // in batched backend invocations — grouped in `every_blocks`-sized
        // slices with a checkpoint after each. encode_blocks's grouping is
        // documented bit-identical, so durability costs no protocol change.
        let mut done = done0;
        while done < order.len() {
            let take = every_blocks.min(order.len() - done);
            let group = order[done..done + take].to_vec();
            obs_event!(Ev::Debug, "encode_group_start",
                "first" => done, "take" => take);
            let t = Timer::start();
            let outcomes = encode_blocks(&mut session, &group)?;
            encode_secs += t.secs();
            for (&b, outcome) in group.iter().zip(&outcomes) {
                kl_bits_sum += outcome.kl_bits;
                indices[b] = outcome.index;
                obs::metrics().blocks_encoded.inc();
                obs_event!(Ev::Info, "encode_block",
                    "block" => b,
                    "index" => outcome.index,
                    "kl_bits" => outcome.kl_bits,
                    "is_gap_bits" => outcome.is_gap_bits);
            }
            done += take;
            obs::metrics_tick(|| {
                vec![
                    ("phase", Json::str("encode")),
                    ("blocks_done", Json::num(done as f64)),
                    ("blocks_total", Json::num(order.len() as f64)),
                    ("kl_bits_sum", Json::num(kl_bits_sum)),
                ]
            });
            if done < order.len() {
                save(&session, &indices, kl_bits_sum)?;
            }
            if let Some(stop) = opts.stop_after_blocks {
                if done >= stop && done < order.len() {
                    save(&session, &indices, kl_bits_sum)?;
                    return Err(Error::with_payload(
                        format!(
                            "interrupted after {done} encoded blocks \
                             (test kill switch)"
                        ),
                        Interrupted {
                            step: session.state.step,
                            encoded_blocks: done,
                        },
                    ));
                }
            }
        }
        info!(
            "encoded {} blocks in batched sweeps ({:.2}s)",
            order.len() - done0,
            encode_secs
        );
    } else {
        for i in done0..order.len() {
            let b = order[i];
            obs_event!(Ev::Debug, "encode_block_start", "block" => b);
            let t = Timer::start();
            let outcome = encode_block(&mut session, b)?;
            encode_secs += t.secs();
            kl_bits_sum += outcome.kl_bits;
            indices[b] = outcome.index;
            obs::metrics().blocks_encoded.inc();
            obs_event!(Ev::Info, "encode_block",
                "block" => b,
                "index" => outcome.index,
                "kl_bits" => outcome.kl_bits,
                "is_gap_bits" => outcome.is_gap_bits);
            for _ in 0..cfg.i_intermediate {
                session.train_step(false)?;
            }
            let done = i + 1;
            obs::metrics_tick(|| {
                vec![
                    ("phase", Json::str("encode")),
                    ("blocks_done", Json::num(done as f64)),
                    ("blocks_total", Json::num(order.len() as f64)),
                    ("kl_bits_sum", Json::num(kl_bits_sum)),
                ]
            });
            if done % every_blocks == 0 && done < order.len() {
                save(&session, &indices, kl_bits_sum)?;
            }
            if done % 200 == 0 {
                info!(
                    "encoded {}/{} blocks (last: k*={} kl={:.2}b is-gap={:.2}b)",
                    done,
                    session.b(),
                    outcome.index,
                    outcome.kl_bits,
                    outcome.is_gap_bits
                );
            }
            if let Some(stop) = opts.stop_after_blocks {
                if done >= stop && done < order.len() {
                    save(&session, &indices, kl_bits_sum)?;
                    return Err(Error::with_payload(
                        format!(
                            "interrupted after {done} encoded blocks \
                             (test kill switch)"
                        ),
                        Interrupted {
                            step: session.state.step,
                            encoded_blocks: done,
                        },
                    ));
                }
            }
        }
    }
    // Final durable checkpoint: marks the run complete (encoded B/B, which
    // `miracle info` reports), and a kill after this point resumes into an
    // immediate no-op re-emission of the same `.mrc`.
    save(&session, &indices, kl_bits_sum)?;
    let train_secs = t_train.secs() - encode_secs;
    Ok((session, indices, encode_secs, kl_bits_sum, train_secs))
}

/// Test error of explicit block-layout weights.
pub fn eval_error(
    arts: &ModelArtifacts,
    assemble_map: &[i32],
    w_blocks: &[f32],
    test: &Dataset,
) -> Result<f64> {
    use crate::runtime::Input;
    use crate::tensor::{Arg, TensorF32, TensorI32};
    let meta = &arts.meta;
    let eb = meta.eval_batch;
    // weights + map uploaded once, shared across all eval batches
    let w_buf = arts.upload(&Arg::F32(TensorF32::new(
        vec![meta.b, meta.s],
        w_blocks.to_vec(),
    )?))?;
    let amap_buf = arts.upload(&Arg::I32(TensorI32::new(
        vec![meta.n_total],
        assemble_map.to_vec(),
    )?))?;
    let mut wrong = 0usize;
    let mut start = 0usize;
    while start < test.len() {
        let (x, y) = test.batch_range(start, eb);
        let x_arg = Arg::F32(x);
        let outs = arts.invoke_mixed(
            "eval_batch",
            &[Input::Dev(&w_buf), Input::Dev(&amap_buf), Input::Host(&x_arg)],
        )?;
        let logits = outs[0].as_f32()?;
        let n_valid = eb.min(test.len() - start);
        for i in 0..n_valid {
            let row = logits.row(i);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 != y[i] {
                wrong += 1;
            }
        }
        start += eb;
    }
    Ok(wrong as f64 / test.len() as f64)
}

/// Test error of a raw flat weight vector (baseline path).
pub fn eval_error_full(
    arts: &ModelArtifacts,
    w_full: &[f32],
    test: &Dataset,
) -> Result<f64> {
    use crate::tensor::{Arg, TensorF32};
    let meta = &arts.meta;
    let eb = meta.eval_batch;
    let w = TensorF32::new(vec![meta.n_total], w_full.to_vec())?;
    let mut wrong = 0usize;
    let mut start = 0usize;
    while start < test.len() {
        let (x, y) = test.batch_range(start, eb);
        let outs = arts.invoke("eval_full", &[Arg::F32(w.clone()), Arg::F32(x)])?;
        let logits = outs[0].as_f32()?;
        let n_valid = eb.min(test.len() - start);
        for i in 0..n_valid {
            let row = logits.row(i);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 != y[i] {
                wrong += 1;
            }
        }
        start += eb;
    }
    Ok(wrong as f64 / test.len() as f64)
}
