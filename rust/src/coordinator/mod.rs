//! The MIRACLE coordinator — Algorithm 2 of the paper.
//!
//! Owns the full compression run: initial variational convergence (I_0
//! steps), the random block-encode schedule, per-block β annealing against
//! the local coding goal `C_loc`, intermediate variational updates of
//! not-yet-coded blocks, and final `.mrc` emission. All numerical work runs
//! through the pluggable runtime backend ([`crate::runtime`] — pure-Rust by
//! default, AOT/PJRT behind the `xla` feature); this module owns only
//! control flow and state. See `DESIGN.md` for the full Algorithm-2 walk.

pub mod beta;
pub mod checkpoint;
pub mod encoder;
pub mod session;

pub use beta::BetaController;
pub use encoder::{decode_model, encode_block, encode_blocks, EncodeOutcome};
pub use session::{Session, StepMetrics};

use crate::codec::MrcFile;
use crate::data::Dataset;
use crate::prng::Pcg64;
use crate::runtime::ModelArtifacts;
use crate::util::{Result, Timer};
use crate::{ensure, info};

/// Hyper-parameters of a MIRACLE run (paper §3.3 / §4 defaults).
#[derive(Debug, Clone)]
pub struct MiracleCfg {
    /// local coding goal per block, in bits (K = 2^c_loc_bits)
    pub c_loc_bits: u8,
    /// initial variational iterations before any encoding (paper: 1e4)
    pub i0: usize,
    /// intermediate variational iterations per encoded block (paper: 50 / 1)
    pub i_intermediate: usize,
    pub lr: f32,
    /// β starting value ε_β0 (paper: 1e-8)
    pub beta0: f32,
    /// β annealing rate ε_β (paper: 5e-5)
    pub eps_beta: f32,
    /// dataset size factor applied to the batch-mean CE (ELBO sum scale)
    pub data_scale: f32,
    /// seed for the hashing trick + block permutation (travels in .mrc)
    pub layout_seed: u64,
    /// base seed of the shared candidate generator (travels in .mrc)
    pub protocol_seed: i32,
    /// seed for batch order + per-step reparameterization keys
    pub train_seed: u64,
    /// worker threads for the candidate hot path (0 = auto: the
    /// `MIRACLE_THREADS` env var, else all cores). Selected indices and
    /// decoded weights are identical at every setting — see `docs/perf.md`.
    pub threads: usize,
}

impl Default for MiracleCfg {
    fn default() -> MiracleCfg {
        MiracleCfg {
            c_loc_bits: 12,
            i0: 300,
            i_intermediate: 1,
            lr: 1e-3,
            beta0: 1e-8,
            // The paper uses ε_β = 5e-5 over ~10^5-10^6 total updates; our
            // sandbox runs are 10^2-10^4 updates, so the default annealing
            // rate is scaled up to reach the same β range. The CLI exposes
            // --eps-beta for faithful settings.
            eps_beta: 2e-3,
            data_scale: 1.0,
            layout_seed: 0x4D31_7261_636C_6531, // "M1racle1"
            protocol_seed: 7,
            train_seed: 42,
            threads: 0,
        }
    }
}

/// Outcome of a full compression run.
pub struct CompressResult {
    pub mrc: MrcFile,
    /// test error of the decoded (fully frozen) weights
    pub test_error: f64,
    /// bits actually spent (container total)
    pub total_bits: usize,
    pub train_secs: f64,
    pub encode_secs: f64,
    /// mean realized per-block KL at encode time, in bits
    pub mean_block_kl_bits: f64,
    pub history: Vec<StepMetrics>,
}

/// Run Algorithm 2 end to end on a training set; returns the compressed
/// model and its measured quality.
pub fn compress(
    arts: &ModelArtifacts,
    train: &Dataset,
    test: &Dataset,
    cfg: &MiracleCfg,
) -> Result<CompressResult> {
    ensure!(
        (1 << cfg.c_loc_bits as usize) >= 1,
        "c_loc_bits out of range"
    );
    // honor cfg.threads for the WHOLE run (encode fan-out, eval row
    // fan-out), not just the encoder's own invocations
    let _threads = crate::util::pool::override_threads(cfg.threads);
    let mut session = Session::new(arts, train, cfg)?;

    // Phase 1: variational convergence with p learned jointly (I_0 steps).
    let t_train = Timer::start();
    for _ in 0..cfg.i0 {
        session.train_step(true)?;
    }
    // p is frozen from here on: its stddevs travel in the .mrc header and
    // every block must be coded against the same encoding distribution.
    info!(
        "I0 done: loss {:.4} acc {:.3} mean KL {:.2} bits (target {} bits)",
        session.last_loss(),
        session.last_acc(),
        session.mean_kl_bits(),
        cfg.c_loc_bits
    );

    // Phase 2: random block order; encode, then I intermediate updates.
    let mut order_rng = Pcg64::seed(cfg.train_seed ^ 0x0B10_C0DE);
    let order = order_rng.permutation(session.b());
    let mut encode_secs = 0.0;
    let mut kl_bits_sum = 0.0;
    let mut indices = vec![0u64; session.b()];
    if cfg.i_intermediate == 0 {
        // No updates between encodes (paper ablation I = 0): every block is
        // coded against the same variational state, so the whole sweep can
        // be scored in one batched backend invocation. Bit-identical to the
        // sequential loop below.
        let blocks: Vec<usize> = order.iter().map(|&b| b as usize).collect();
        let t = Timer::start();
        let outcomes = encode_blocks(&mut session, &blocks)?;
        encode_secs += t.secs();
        for (&b, outcome) in blocks.iter().zip(&outcomes) {
            kl_bits_sum += outcome.kl_bits;
            indices[b] = outcome.index;
        }
        info!(
            "encoded {} blocks in one batched sweep ({:.2}s)",
            blocks.len(),
            encode_secs
        );
    } else {
        for (done, &b) in order.iter().enumerate() {
            let b = b as usize;
            let t = Timer::start();
            let outcome = encode_block(&mut session, b)?;
            encode_secs += t.secs();
            kl_bits_sum += outcome.kl_bits;
            indices[b] = outcome.index;
            for _ in 0..cfg.i_intermediate {
                session.train_step(false)?;
            }
            if (done + 1) % 200 == 0 {
                info!(
                    "encoded {}/{} blocks (last: k*={} kl={:.2}b is-gap={:.2}b)",
                    done + 1,
                    session.b(),
                    outcome.index,
                    outcome.kl_bits,
                    outcome.is_gap_bits
                );
            }
        }
    }
    let train_secs = t_train.secs() - encode_secs;

    let mrc = MrcFile {
        model: arts.meta.name.clone(),
        layout_seed: cfg.layout_seed,
        protocol_seed: cfg.protocol_seed,
        backend: arts.backend_family(),
        b: session.b(),
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: cfg.c_loc_bits,
        lsp: session.state.lsp.clone(),
        indices,
    };

    // Final quality: decode from the container (full round trip) and eval.
    let w_blocks = decode_model(arts, &mrc)?;
    let test_error = eval_error(arts, &session.layout.assemble_map, &w_blocks, test)?;
    let total_bits = mrc.total_bits();
    Ok(CompressResult {
        mrc,
        test_error,
        total_bits,
        train_secs,
        encode_secs,
        mean_block_kl_bits: kl_bits_sum / session.b() as f64,
        history: session.history.clone(),
    })
}

/// Test error of explicit block-layout weights.
pub fn eval_error(
    arts: &ModelArtifacts,
    assemble_map: &[i32],
    w_blocks: &[f32],
    test: &Dataset,
) -> Result<f64> {
    use crate::runtime::Input;
    use crate::tensor::{Arg, TensorF32, TensorI32};
    let meta = &arts.meta;
    let eb = meta.eval_batch;
    // weights + map uploaded once, shared across all eval batches
    let w_buf = arts.upload(&Arg::F32(TensorF32::new(
        vec![meta.b, meta.s],
        w_blocks.to_vec(),
    )?))?;
    let amap_buf = arts.upload(&Arg::I32(TensorI32::new(
        vec![meta.n_total],
        assemble_map.to_vec(),
    )?))?;
    let mut wrong = 0usize;
    let mut start = 0usize;
    while start < test.len() {
        let (x, y) = test.batch_range(start, eb);
        let x_arg = Arg::F32(x);
        let outs = arts.invoke_mixed(
            "eval_batch",
            &[Input::Dev(&w_buf), Input::Dev(&amap_buf), Input::Host(&x_arg)],
        )?;
        let logits = outs[0].as_f32()?;
        let n_valid = eb.min(test.len() - start);
        for i in 0..n_valid {
            let row = logits.row(i);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 != y[i] {
                wrong += 1;
            }
        }
        start += eb;
    }
    Ok(wrong as f64 / test.len() as f64)
}

/// Test error of a raw flat weight vector (baseline path).
pub fn eval_error_full(
    arts: &ModelArtifacts,
    w_full: &[f32],
    test: &Dataset,
) -> Result<f64> {
    use crate::tensor::{Arg, TensorF32};
    let meta = &arts.meta;
    let eb = meta.eval_batch;
    let w = TensorF32::new(vec![meta.n_total], w_full.to_vec())?;
    let mut wrong = 0usize;
    let mut start = 0usize;
    while start < test.len() {
        let (x, y) = test.batch_range(start, eb);
        let outs = arts.invoke("eval_full", &[Arg::F32(w.clone()), Arg::F32(x)])?;
        let logits = outs[0].as_f32()?;
        let n_valid = eb.min(test.len() - start);
        for i in 0..n_valid {
            let row = logits.row(i);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 != y[i] {
                wrong += 1;
            }
        }
        start += eb;
    }
    Ok(wrong as f64 / test.len() as f64)
}
