//! Session checkpointing: resumable compression runs.
//!
//! Paper-scale runs (I_0 = 10^4 steps + thousands of block encodes) benefit
//! from durable progress. A checkpoint captures everything Algorithm 2
//! mutates — variational state, Adam slots, β vector, freeze set and the
//! already-transmitted indices — keyed by the config fingerprint so a resume
//! cannot silently change the protocol.

use crate::bitstream::{BitReader, BitWriter};
use crate::util::{Error, Result};
use crate::{ensure, err};

use super::session::Session;

const MAGIC: &[u8; 4] = b"MCK1";

/// Serializable snapshot of a running compression session.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub b: usize,
    pub s: usize,
    pub n_layers: usize,
    pub step: i32,
    pub mu: Vec<f32>,
    pub rho: Vec<f32>,
    pub lsp: Vec<f32>,
    pub m_mu: Vec<f32>,
    pub v_mu: Vec<f32>,
    pub m_rho: Vec<f32>,
    pub v_rho: Vec<f32>,
    pub m_lsp: Vec<f32>,
    pub v_lsp: Vec<f32>,
    pub beta: Vec<f32>,
    pub frozen_mask: Vec<f32>,
    pub frozen_w: Vec<f32>,
    /// indices of blocks already encoded (u64::MAX = not yet encoded)
    pub indices: Vec<u64>,
}

fn write_f32s(w: &mut BitWriter, xs: &[f32]) {
    w.write_varint(xs.len() as u64);
    for &x in xs {
        w.write_bits(x.to_bits() as u64, 32);
    }
}

fn read_f32s(r: &mut BitReader) -> Result<Vec<f32>> {
    let n = r.read_varint()? as usize;
    // bound by what the buffer physically holds BEFORE allocating: a
    // hostile varint must not drive Vec::with_capacity
    ensure!(
        n <= r.remaining_bits() / 32,
        "declared vector length {n} exceeds the {} f32s left in the file",
        r.remaining_bits() / 32
    );
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f32::from_bits(r.read_bits(32)? as u32));
    }
    Ok(out)
}

impl Checkpoint {
    pub fn capture(session: &Session, indices: &[u64]) -> Checkpoint {
        let st = &session.state;
        Checkpoint {
            model: session.arts.meta.name.clone(),
            b: session.arts.meta.b,
            s: session.arts.meta.s,
            n_layers: session.arts.meta.n_layers,
            step: st.step,
            mu: st.mu.clone(),
            rho: st.rho.clone(),
            lsp: st.lsp.clone(),
            m_mu: st.m_mu.clone(),
            v_mu: st.v_mu.clone(),
            m_rho: st.m_rho.clone(),
            v_rho: st.v_rho.clone(),
            m_lsp: st.m_lsp.clone(),
            v_lsp: st.v_lsp.clone(),
            beta: session.betas.beta.clone(),
            frozen_mask: session.frozen_mask.clone(),
            frozen_w: session.frozen_w.clone(),
            indices: indices.to_vec(),
        }
    }

    /// Restore into a freshly-created session (same config + seeds).
    pub fn restore(&self, session: &mut Session) -> Result<Vec<u64>> {
        let meta = &session.arts.meta;
        ensure!(self.model == meta.name, "checkpoint for model {}", self.model);
        ensure!(
            self.b == meta.b && self.s == meta.s && self.n_layers == meta.n_layers,
            "checkpoint geometry mismatch"
        );
        let st = &mut session.state;
        st.step = self.step;
        st.mu = self.mu.clone();
        st.rho = self.rho.clone();
        st.lsp = self.lsp.clone();
        st.m_mu = self.m_mu.clone();
        st.v_mu = self.v_mu.clone();
        st.m_rho = self.m_rho.clone();
        st.v_rho = self.v_rho.clone();
        st.m_lsp = self.m_lsp.clone();
        st.v_lsp = self.v_lsp.clone();
        session.betas.beta = self.beta.clone();
        session.frozen_mask = self.frozen_mask.clone();
        session.frozen_w = self.frozen_w.clone();
        Ok(self.indices.clone())
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = BitWriter::new();
        for &b in MAGIC {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(self.model.len() as u64);
        for &b in self.model.as_bytes() {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(self.b as u64);
        w.write_varint(self.s as u64);
        w.write_varint(self.n_layers as u64);
        w.write_bits(self.step as u32 as u64, 32);
        for v in [
            &self.mu, &self.rho, &self.lsp, &self.m_mu, &self.v_mu,
            &self.m_rho, &self.v_rho, &self.m_lsp, &self.v_lsp, &self.beta,
            &self.frozen_mask, &self.frozen_w,
        ] {
            write_f32s(&mut w, v);
        }
        w.write_varint(self.indices.len() as u64);
        for &i in &self.indices {
            w.write_varint(i);
        }
        w.finish()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = BitReader::new(bytes);
        let mut magic = [0u8; 4];
        for m in magic.iter_mut() {
            *m = r.read_bits(8)? as u8;
        }
        if &magic != MAGIC {
            return err!("not a checkpoint file");
        }
        let name_len = r.read_varint()? as usize;
        ensure!(
            name_len < 4096 && name_len <= r.remaining_bits() / 8,
            "bad name length {name_len}"
        );
        let mut name = Vec::with_capacity(name_len);
        for _ in 0..name_len {
            name.push(r.read_bits(8)? as u8);
        }
        let model = String::from_utf8(name).map_err(|_| Error::msg("bad name"))?;
        let b = r.read_varint()? as usize;
        let s = r.read_varint()? as usize;
        let n_layers = r.read_varint()? as usize;
        let step = r.read_bits(32)? as u32 as i32;
        let mut vecs: Vec<Vec<f32>> = Vec::with_capacity(12);
        for _ in 0..12 {
            vecs.push(read_f32s(&mut r)?);
        }
        let n_idx = r.read_varint()? as usize;
        // each index varint is at least one byte on the wire
        ensure!(
            n_idx <= r.remaining_bits() / 8,
            "declared index count {n_idx} exceeds the {} bytes left",
            r.remaining_bits() / 8
        );
        let mut indices = Vec::with_capacity(n_idx);
        for _ in 0..n_idx {
            indices.push(r.read_varint()?);
        }
        let mut it = vecs.into_iter();
        Ok(Checkpoint {
            model,
            b,
            s,
            n_layers,
            step,
            mu: it.next().unwrap(),
            rho: it.next().unwrap(),
            lsp: it.next().unwrap(),
            m_mu: it.next().unwrap(),
            v_mu: it.next().unwrap(),
            m_rho: it.next().unwrap(),
            v_rho: it.next().unwrap(),
            m_lsp: it.next().unwrap(),
            v_lsp: it.next().unwrap(),
            beta: it.next().unwrap(),
            frozen_mask: it.next().unwrap(),
            frozen_w: it.next().unwrap(),
            indices,
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::msg(format!("read {path}: {e}")))?;
        Checkpoint::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            model: "tiny_mlp".into(),
            b: 22,
            s: 8,
            n_layers: 2,
            step: 1234,
            mu: (0..176).map(|i| i as f32 * 0.1).collect(),
            rho: vec![-3.0; 176],
            lsp: vec![-1.0, -2.0],
            m_mu: vec![0.5; 176],
            v_mu: vec![0.25; 176],
            m_rho: vec![0.0; 176],
            v_rho: vec![0.0; 176],
            m_lsp: vec![0.1, 0.2],
            v_lsp: vec![0.3, 0.4],
            beta: vec![1e-4; 22],
            frozen_mask: vec![0.0; 22],
            frozen_w: vec![0.0; 176],
            indices: (0..22).map(|i| if i < 5 { i * 3 } else { u64::MAX }).collect(),
        }
    }

    #[test]
    fn round_trip() {
        let c = sample();
        let c2 = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::from_bytes(b"nope").is_err());
        let mut bytes = sample().to_bytes();
        bytes[1] ^= 0xff;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn hostile_vector_length_refused_before_allocation() {
        // overwrite the first f32-vector length varint (right after the
        // fixed-width step field) with ~2^28: must fail fast, not OOM
        let c = sample();
        let bytes = c.to_bytes();
        // locate the step field's end: magic + name varint + name + 3 geometry
        // varints (all single-byte here) + 4-byte step
        let off = 4 + 1 + c.model.len() + 3 + 4;
        let mut hostile = bytes.clone();
        hostile.splice(off..off + 1, [0xFF, 0xFF, 0xFF, 0x7F]);
        let t = std::time::Instant::now();
        assert!(Checkpoint::from_bytes(&hostile).is_err());
        assert!(t.elapsed().as_secs_f64() < 1.0);
    }
}
