//! Session checkpointing: crash-safe, resume-exact compression runs.
//!
//! Paper-scale runs (I_0 = 10^4 steps + thousands of block encodes) are
//! hours-long; a checkpoint captures everything Algorithm 2 mutates —
//! variational state, Adam slots, β vector, freeze set, metric history and
//! the already-transmitted indices — so a killed run resumes to a
//! **byte-identical** `.mrc` (see `docs/checkpoint-format.md` for the
//! contract and why no PRNG internals need to travel: the per-step streams
//! are fast-forwarded by the step counter, everything else is re-derived
//! from the config).
//!
//! On disk a checkpoint is an `MCK2` container: a fixed 28-byte CRC-32
//! protected header (magic, config fingerprint, payload length, payload
//! CRC) followed by the serialized snapshot. Like the `.mrc` MRC2 container
//! (PR 6), every load failure is a structured one-line [`CkptError`] —
//! never a panic, never an unbounded allocation, and never a silently-wrong
//! resume: the fingerprint pins the protocol-relevant config, so a
//! checkpoint from a different run (or a different model, dataset, seed …)
//! is rejected instead of quietly changing what gets encoded.
//!
//! ```text
//! magic "MCK2"
//! u64   config fingerprint (big-endian; see [`fingerprint`])
//! u64   payload length in bytes
//! u32   payload CRC-32
//! u32   header CRC-32 (over the 24 preceding bytes)
//! payload: the MCK1 snapshot body (bitstream-serialized)
//! ```
//!
//! Writes are torn-write-proof: [`Checkpoint::save`] writes `PATH.tmp`,
//! fsyncs, then atomically renames onto `PATH` — a reader observes either
//! the previous complete checkpoint or the new one, never a prefix.

use crate::bitstream::{BitReader, BitWriter};
use crate::codec::BackendFamily;
use crate::data::Dataset;
use crate::runtime::ModelMeta;
use crate::util::crc32::crc32;
use crate::util::{Error, Result};
use crate::{ensure, err};

use super::session::{Session, StepMetrics};
use super::MiracleCfg;

/// Container magic (framing revision 2: CRC + fingerprint protected).
pub const MAGIC: &[u8; 4] = b"MCK2";
/// Inner snapshot-body magic (kept as a second line of defense).
const BODY_MAGIC: &[u8; 4] = b"MCK1";
/// magic + fingerprint + payload_len + payload CRC + header CRC
const HEADER_LEN: usize = 4 + 8 + 8 + 4 + 4;

/// Structured load failure for `MCK2` checkpoint files. Mirrors
/// [`crate::codec::MrcError`]: every variant renders as a one-line
/// diagnosis, and no input of any shape (truncation, bit flips, hostile
/// length fields, stale configs) can produce a panic or an unbounded
/// allocation — it lands here instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CkptError {
    /// Reading or writing the file itself failed.
    Io { path: String, detail: String },
    /// The first four bytes are not the MCK2 magic.
    NotCheckpoint { found: [u8; 4] },
    /// The file ended before the declared content did.
    Truncated,
    /// Header bytes fail their CRC — nothing in the file can be trusted.
    HeaderCrc { stored: u32, computed: u32 },
    /// The snapshot body fails its CRC — the state is corrupt.
    PayloadCrc { stored: u32, computed: u32 },
    /// The checkpoint was written by a run with a different
    /// protocol-relevant config — resuming would silently change the
    /// encoded stream, so it is refused.
    Fingerprint { stored: u64, expected: u64 },
    /// Bytes remain after the declared payload.
    TrailingGarbage { extra_bytes: u64 },
    /// Anything else structurally wrong inside the snapshot body.
    Malformed(String),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io { path, detail } => write!(f, "{path}: {detail}"),
            CkptError::NotCheckpoint { found } => {
                write!(f, "not an MCK2 checkpoint file (magic {found:?})")
            }
            CkptError::Truncated => {
                write!(f, "checkpoint truncated: ran out of bytes mid-field")
            }
            CkptError::HeaderCrc { stored, computed } => write!(
                f,
                "checkpoint header CRC mismatch (stored {stored:#010x}, \
                 computed {computed:#010x}) — header bytes are corrupt"
            ),
            CkptError::PayloadCrc { stored, computed } => write!(
                f,
                "checkpoint payload CRC mismatch (stored {stored:#010x}, \
                 computed {computed:#010x}) — snapshot state is corrupt"
            ),
            CkptError::Fingerprint { stored, expected } => write!(
                f,
                "checkpoint config fingerprint {stored:#018x} does not match \
                 this run's {expected:#018x} — resuming would change the \
                 protocol, refusing"
            ),
            CkptError::TrailingGarbage { extra_bytes } => write!(
                f,
                "{extra_bytes} unexpected bytes after the declared payload"
            ),
            CkptError::Malformed(m) => {
                write!(f, "malformed checkpoint: {m}")
            }
        }
    }
}

impl std::error::Error for CkptError {}

impl From<CkptError> for Error {
    fn from(e: CkptError) -> Error {
        Error::msg(e.to_string())
    }
}

pub type CkptResult<T> = std::result::Result<T, CkptError>;

/// Order-sensitive FNV-1a over every input that pins the encode protocol:
/// model geometry, backend family, all [`MiracleCfg`] fields except
/// `threads` (selected indices are thread-count invariant — `docs/perf.md`)
/// and the training data itself (batch contents feed the gradient stream).
/// A resume under any differing input would produce a different `.mrc`, so
/// [`Checkpoint::load_verified`] refuses mismatches.
pub fn fingerprint(
    meta: &ModelMeta,
    backend: BackendFamily,
    cfg: &MiracleCfg,
    train: &Dataset,
) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(b"MCK2-fp-v1");
    eat(meta.name.as_bytes());
    for v in [meta.b, meta.s, meta.k_chunk, meta.n_layers, meta.batch] {
        eat(&(v as u64).to_le_bytes());
    }
    eat(&[backend.code()]);
    eat(&[cfg.c_loc_bits]);
    for v in [cfg.i0 as u64, cfg.i_intermediate as u64] {
        eat(&v.to_le_bytes());
    }
    for v in [cfg.lr, cfg.beta0, cfg.eps_beta, cfg.data_scale] {
        eat(&v.to_bits().to_le_bytes());
    }
    eat(&cfg.layout_seed.to_le_bytes());
    eat(&cfg.protocol_seed.to_le_bytes());
    eat(&cfg.train_seed.to_le_bytes());
    eat(&(train.len() as u64).to_le_bytes());
    eat(&(train.feature_dim() as u64).to_le_bytes());
    eat(&(train.classes as u64).to_le_bytes());
    for &x in &train.x {
        eat(&x.to_bits().to_le_bytes());
    }
    for &y in &train.y {
        eat(&y.to_le_bytes());
    }
    h
}

/// Serializable snapshot of a running compression session.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub b: usize,
    pub s: usize,
    pub n_layers: usize,
    pub step: i32,
    pub mu: Vec<f32>,
    pub rho: Vec<f32>,
    pub lsp: Vec<f32>,
    pub m_mu: Vec<f32>,
    pub v_mu: Vec<f32>,
    pub m_rho: Vec<f32>,
    pub v_rho: Vec<f32>,
    pub m_lsp: Vec<f32>,
    pub v_lsp: Vec<f32>,
    pub beta: Vec<f32>,
    pub frozen_mask: Vec<f32>,
    pub frozen_w: Vec<f32>,
    /// indices of blocks already encoded (u64::MAX = not yet encoded)
    pub indices: Vec<u64>,
    /// last per-block KL (nats) — `selection_stats` reads it at encode time
    pub last_kl: Vec<f32>,
    /// running sum of realized per-block KL bits (reporting state of the
    /// compress loop, so a resumed run's mean matches the uninterrupted one)
    pub kl_bits_sum: f64,
    /// full metric history, so `CompressResult::history` is resume-invariant
    pub history: Vec<StepMetrics>,
}

fn write_f32s(w: &mut BitWriter, xs: &[f32]) {
    w.write_varint(xs.len() as u64);
    for &x in xs {
        w.write_bits(x.to_bits() as u64, 32);
    }
}

fn read_f32s(r: &mut BitReader) -> Result<Vec<f32>> {
    let n = r.read_varint()? as usize;
    // bound by what the buffer physically holds BEFORE allocating: a
    // hostile varint must not drive Vec::with_capacity
    ensure!(
        n <= r.remaining_bits() / 32,
        "declared vector length {n} exceeds the {} f32s left in the file",
        r.remaining_bits() / 32
    );
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f32::from_bits(r.read_bits(32)? as u32));
    }
    Ok(out)
}

impl Checkpoint {
    /// Snapshot a session plus the compress loop's own reporting state.
    pub fn capture(
        session: &Session,
        indices: &[u64],
        kl_bits_sum: f64,
    ) -> Checkpoint {
        let st = &session.state;
        Checkpoint {
            model: session.arts.meta.name.clone(),
            b: session.arts.meta.b,
            s: session.arts.meta.s,
            n_layers: session.arts.meta.n_layers,
            step: st.step,
            mu: st.mu.clone(),
            rho: st.rho.clone(),
            lsp: st.lsp.clone(),
            m_mu: st.m_mu.clone(),
            v_mu: st.v_mu.clone(),
            m_rho: st.m_rho.clone(),
            v_rho: st.v_rho.clone(),
            m_lsp: st.m_lsp.clone(),
            v_lsp: st.v_lsp.clone(),
            beta: session.betas.beta.clone(),
            frozen_mask: session.frozen_mask.clone(),
            frozen_w: session.frozen_w.clone(),
            indices: indices.to_vec(),
            last_kl: session.last_kl.clone(),
            kl_bits_sum,
            history: session.history.clone(),
        }
    }

    /// Number of blocks already encoded at capture time.
    pub fn encoded_blocks(&self) -> usize {
        self.indices.iter().filter(|&&i| i != u64::MAX).count()
    }

    /// Restore into a freshly-created session (same config + seeds) and
    /// fast-forward its per-step streams so the next `train_step` consumes
    /// exactly the draws an uninterrupted run would have. Returns the
    /// indices of already-encoded blocks.
    pub fn restore(&self, session: &mut Session) -> Result<Vec<u64>> {
        let meta = &session.arts.meta;
        ensure!(self.model == meta.name, "checkpoint for model {}", self.model);
        ensure!(
            self.b == meta.b && self.s == meta.s && self.n_layers == meta.n_layers,
            "checkpoint geometry mismatch"
        );
        ensure!(
            self.step >= 0,
            "checkpoint step {} is negative",
            self.step
        );
        ensure!(
            self.indices.len() == meta.b && self.last_kl.len() == meta.b,
            "checkpoint vector geometry mismatch"
        );
        let st = &mut session.state;
        st.step = self.step;
        st.mu = self.mu.clone();
        st.rho = self.rho.clone();
        st.lsp = self.lsp.clone();
        st.m_mu = self.m_mu.clone();
        st.v_mu = self.v_mu.clone();
        st.m_rho = self.m_rho.clone();
        st.v_rho = self.v_rho.clone();
        st.m_lsp = self.m_lsp.clone();
        st.v_lsp = self.v_lsp.clone();
        session.betas.beta = self.beta.clone();
        session.frozen_mask = self.frozen_mask.clone();
        session.frozen_w = self.frozen_w.clone();
        session.last_kl = self.last_kl.clone();
        session.history = self.history.clone();
        session.fast_forward_streams(self.step as usize);
        Ok(self.indices.clone())
    }

    /// Serialize the snapshot body (no framing — see
    /// [`Checkpoint::to_container_bytes`] for the durable on-disk form).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = BitWriter::new();
        for &b in BODY_MAGIC {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(self.model.len() as u64);
        for &b in self.model.as_bytes() {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(self.b as u64);
        w.write_varint(self.s as u64);
        w.write_varint(self.n_layers as u64);
        w.write_bits(self.step as u32 as u64, 32);
        for v in [
            &self.mu, &self.rho, &self.lsp, &self.m_mu, &self.v_mu,
            &self.m_rho, &self.v_rho, &self.m_lsp, &self.v_lsp, &self.beta,
            &self.frozen_mask, &self.frozen_w,
        ] {
            write_f32s(&mut w, v);
        }
        w.write_varint(self.indices.len() as u64);
        for &i in &self.indices {
            w.write_varint(i);
        }
        write_f32s(&mut w, &self.last_kl);
        w.write_bits(self.kl_bits_sum.to_bits(), 64);
        w.write_varint(self.history.len() as u64);
        for m in &self.history {
            for v in [m.loss, m.ce, m.acc, m.mean_kl_nats] {
                w.write_bits(v.to_bits() as u64, 32);
            }
        }
        w.finish()
    }

    /// Parse a snapshot body. Malformed input fails fast with a plain
    /// error; the CRC framing in [`Checkpoint::from_container_bytes`] is
    /// what guarantees accidental corruption never reaches this parser
    /// undetected.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = BitReader::new(bytes);
        let mut magic = [0u8; 4];
        for m in magic.iter_mut() {
            *m = r.read_bits(8)? as u8;
        }
        if &magic != BODY_MAGIC {
            return err!("not a checkpoint file");
        }
        let name_len = r.read_varint()? as usize;
        ensure!(
            name_len < 4096 && name_len <= r.remaining_bits() / 8,
            "bad name length {name_len}"
        );
        let mut name = Vec::with_capacity(name_len);
        for _ in 0..name_len {
            name.push(r.read_bits(8)? as u8);
        }
        let model = String::from_utf8(name).map_err(|_| Error::msg("bad name"))?;
        let b = r.read_varint()? as usize;
        let s = r.read_varint()? as usize;
        let n_layers = r.read_varint()? as usize;
        let step = r.read_bits(32)? as u32 as i32;
        let mut vecs: Vec<Vec<f32>> = Vec::with_capacity(12);
        for _ in 0..12 {
            vecs.push(read_f32s(&mut r)?);
        }
        let n_idx = r.read_varint()? as usize;
        // each index varint is at least one byte on the wire
        ensure!(
            n_idx <= r.remaining_bits() / 8,
            "declared index count {n_idx} exceeds the {} bytes left",
            r.remaining_bits() / 8
        );
        let mut indices = Vec::with_capacity(n_idx);
        for _ in 0..n_idx {
            indices.push(r.read_varint()?);
        }
        let last_kl = read_f32s(&mut r)?;
        let kl_bits_sum = f64::from_bits(r.read_bits(64)?);
        let n_hist = r.read_varint()? as usize;
        ensure!(
            n_hist <= r.remaining_bits() / 128,
            "declared history length {n_hist} exceeds the {} entries left",
            r.remaining_bits() / 128
        );
        let mut history = Vec::with_capacity(n_hist);
        for _ in 0..n_hist {
            let loss = f32::from_bits(r.read_bits(32)? as u32);
            let ce = f32::from_bits(r.read_bits(32)? as u32);
            let acc = f32::from_bits(r.read_bits(32)? as u32);
            let mean_kl_nats = f32::from_bits(r.read_bits(32)? as u32);
            history.push(StepMetrics { loss, ce, acc, mean_kl_nats });
        }
        let mut it = vecs.into_iter();
        Ok(Checkpoint {
            model,
            b,
            s,
            n_layers,
            step,
            mu: it.next().unwrap(),
            rho: it.next().unwrap(),
            lsp: it.next().unwrap(),
            m_mu: it.next().unwrap(),
            v_mu: it.next().unwrap(),
            m_rho: it.next().unwrap(),
            v_rho: it.next().unwrap(),
            m_lsp: it.next().unwrap(),
            v_lsp: it.next().unwrap(),
            beta: it.next().unwrap(),
            frozen_mask: it.next().unwrap(),
            frozen_w: it.next().unwrap(),
            indices,
            last_kl,
            kl_bits_sum,
            history,
        })
    }

    /// The full `MCK2` container: CRC-protected header + snapshot body.
    pub fn to_container_bytes(&self, fingerprint: u64) -> Vec<u8> {
        let payload = self.to_bytes();
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&fingerprint.to_be_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_be_bytes());
        out.extend_from_slice(&crc32(&payload).to_be_bytes());
        let header_crc = crc32(&out);
        out.extend_from_slice(&header_crc.to_be_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse an `MCK2` container, verifying both CRCs before the body is
    /// trusted. Returns the snapshot and the stored config fingerprint
    /// (checked against the running config by [`Checkpoint::load_verified`];
    /// progress inspection à la `miracle info` reads it unchecked).
    pub fn from_container_bytes(bytes: &[u8]) -> CkptResult<(Checkpoint, u64)> {
        if bytes.len() < 4 {
            return Err(CkptError::Truncated);
        }
        let mut magic = [0u8; 4];
        magic.copy_from_slice(&bytes[..4]);
        if &magic != MAGIC {
            return Err(CkptError::NotCheckpoint { found: magic });
        }
        if bytes.len() < HEADER_LEN {
            return Err(CkptError::Truncated);
        }
        let stored_hc = u32::from_be_bytes(bytes[24..28].try_into().unwrap());
        let computed_hc = crc32(&bytes[..24]);
        if stored_hc != computed_hc {
            return Err(CkptError::HeaderCrc {
                stored: stored_hc,
                computed: computed_hc,
            });
        }
        let fingerprint = u64::from_be_bytes(bytes[4..12].try_into().unwrap());
        let payload_len = u64::from_be_bytes(bytes[12..20].try_into().unwrap());
        let stored_pc = u32::from_be_bytes(bytes[20..24].try_into().unwrap());
        let actual = (bytes.len() - HEADER_LEN) as u64;
        if payload_len > actual {
            return Err(CkptError::Truncated);
        }
        if payload_len < actual {
            return Err(CkptError::TrailingGarbage {
                extra_bytes: actual - payload_len,
            });
        }
        let payload = &bytes[HEADER_LEN..];
        let computed_pc = crc32(payload);
        if stored_pc != computed_pc {
            return Err(CkptError::PayloadCrc {
                stored: stored_pc,
                computed: computed_pc,
            });
        }
        // both CRCs hold, so a body parse failure means a crafted file, not
        // accidental corruption — still a structured one-line error
        let ck = Checkpoint::from_bytes(payload).map_err(|e| {
            let m = e.to_string();
            if m.contains("exhausted") {
                CkptError::Truncated
            } else {
                CkptError::Malformed(m)
            }
        })?;
        Ok((ck, fingerprint))
    }

    /// Torn-write-proof durable save: write `PATH.tmp`, fsync, atomically
    /// rename onto `PATH`, then fsync the parent directory (best effort) so
    /// the rename itself survives a power cut. A concurrent or later reader
    /// observes either the previous complete checkpoint or this one — never
    /// a prefix of a half-written file.
    pub fn save(&self, path: &str, fingerprint: u64) -> CkptResult<()> {
        use std::io::Write;
        fn io_err(path: &str, e: std::io::Error) -> CkptError {
            CkptError::Io { path: path.to_string(), detail: e.to_string() }
        }
        let _sp = crate::obs::span("checkpoint_write");
        let bytes = self.to_container_bytes(fingerprint);
        let tmp = format!("{path}.tmp");
        let mut f = std::fs::File::create(&tmp).map_err(|e| io_err(&tmp, e))?;
        f.write_all(&bytes).map_err(|e| io_err(&tmp, e))?;
        f.sync_all().map_err(|e| io_err(&tmp, e))?;
        drop(f);
        std::fs::rename(&tmp, path).map_err(|e| io_err(path, e))?;
        if let Some(dir) = std::path::Path::new(path).parent() {
            let dir = if dir.as_os_str().is_empty() {
                std::path::Path::new(".")
            } else {
                dir
            };
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        crate::obs::metrics().checkpoint_writes.inc();
        crate::obs_event!(crate::obs::Level::Info, "checkpoint_write",
            "path" => path,
            "step" => self.step,
            "encoded_blocks" => self.encoded_blocks(),
            "bytes" => bytes.len());
        Ok(())
    }

    /// Load a container, returning the snapshot and its stored fingerprint.
    pub fn load(path: &str) -> CkptResult<(Checkpoint, u64)> {
        let bytes = std::fs::read(path).map_err(|e| CkptError::Io {
            path: path.to_string(),
            detail: e.to_string(),
        })?;
        Checkpoint::from_container_bytes(&bytes)
    }

    /// Load and reject config-fingerprint mismatches — the resume path's
    /// entry point: a checkpoint may only continue the run that wrote it.
    pub fn load_verified(path: &str, expected: u64) -> CkptResult<Checkpoint> {
        let (ck, stored) = Checkpoint::load(path)?;
        if stored != expected {
            return Err(CkptError::Fingerprint { stored, expected });
        }
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample() -> Checkpoint {
        Checkpoint {
            model: "tiny_mlp".into(),
            b: 22,
            s: 8,
            n_layers: 2,
            step: 1234,
            mu: (0..176).map(|i| i as f32 * 0.1).collect(),
            rho: vec![-3.0; 176],
            lsp: vec![-1.0, -2.0],
            m_mu: vec![0.5; 176],
            v_mu: vec![0.25; 176],
            m_rho: vec![0.0; 176],
            v_rho: vec![0.0; 176],
            m_lsp: vec![0.1, 0.2],
            v_lsp: vec![0.3, 0.4],
            beta: vec![1e-4; 22],
            frozen_mask: vec![0.0; 22],
            frozen_w: vec![0.0; 176],
            indices: (0..22).map(|i| if i < 5 { i * 3 } else { u64::MAX }).collect(),
            last_kl: (0..22).map(|i| 0.5 + i as f32 * 0.01).collect(),
            kl_bits_sum: 42.125,
            history: vec![
                StepMetrics { loss: 1.0, ce: 0.8, acc: 0.5, mean_kl_nats: 2.0 },
                StepMetrics { loss: 0.9, ce: 0.7, acc: 0.6, mean_kl_nats: 1.9 },
            ],
        }
    }

    #[test]
    fn round_trip() {
        let c = sample();
        let c2 = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn container_round_trip_preserves_fingerprint() {
        let c = sample();
        let bytes = c.to_container_bytes(0xDEAD_BEEF_F00D_CAFE);
        let (c2, fp) = Checkpoint::from_container_bytes(&bytes).unwrap();
        assert_eq!(c, c2);
        assert_eq!(fp, 0xDEAD_BEEF_F00D_CAFE);
    }

    #[test]
    fn encoded_blocks_counts_transmitted_indices() {
        assert_eq!(sample().encoded_blocks(), 5);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::from_bytes(b"nope").is_err());
        let mut bytes = sample().to_bytes();
        bytes[1] ^= 0xff;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
        assert_eq!(
            Checkpoint::from_container_bytes(b"nope + more bytes here"),
            Err(CkptError::NotCheckpoint { found: *b"nope" })
        );
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() / 2]).is_err());
        let container = sample().to_container_bytes(1);
        assert_eq!(
            Checkpoint::from_container_bytes(&container[..container.len() - 1]),
            Err(CkptError::Truncated)
        );
    }

    #[test]
    fn container_crcs_catch_corruption() {
        let base = sample().to_container_bytes(7);
        // header byte (fingerprint field)
        let mut h = base.clone();
        h[5] ^= 0x01;
        assert!(matches!(
            Checkpoint::from_container_bytes(&h),
            Err(CkptError::HeaderCrc { .. })
        ));
        // payload byte
        let mut p = base.clone();
        let last = p.len() - 1;
        p[last] ^= 0x80;
        assert!(matches!(
            Checkpoint::from_container_bytes(&p),
            Err(CkptError::PayloadCrc { .. })
        ));
        // appended garbage
        let mut t = base.clone();
        t.extend_from_slice(&[0u8; 3]);
        assert_eq!(
            Checkpoint::from_container_bytes(&t),
            Err(CkptError::TrailingGarbage { extra_bytes: 3 })
        );
    }

    #[test]
    fn errors_are_one_line() {
        let faults: Vec<CkptError> = vec![
            CkptError::Io { path: "x".into(), detail: "denied".into() },
            CkptError::NotCheckpoint { found: *b"MRC2" },
            CkptError::Truncated,
            CkptError::HeaderCrc { stored: 1, computed: 2 },
            CkptError::PayloadCrc { stored: 3, computed: 4 },
            CkptError::Fingerprint { stored: 5, expected: 6 },
            CkptError::TrailingGarbage { extra_bytes: 9 },
            CkptError::Malformed("bad".into()),
        ];
        for e in faults {
            let msg = e.to_string();
            assert!(!msg.contains('\n'), "multi-line: {msg}");
            assert!(!msg.is_empty());
        }
    }

    #[test]
    fn hostile_vector_length_refused_before_allocation() {
        // overwrite the first f32-vector length varint (right after the
        // fixed-width step field) with ~2^28: must fail fast, not OOM
        let c = sample();
        let bytes = c.to_bytes();
        // locate the step field's end: magic + name varint + name + 3 geometry
        // varints (all single-byte here) + 4-byte step
        let off = 4 + 1 + c.model.len() + 3 + 4;
        let mut hostile = bytes.clone();
        hostile.splice(off..off + 1, [0xFF, 0xFF, 0xFF, 0x7F]);
        let t = std::time::Instant::now();
        assert!(Checkpoint::from_bytes(&hostile).is_err());
        assert!(t.elapsed().as_secs_f64() < 1.0);
    }

    #[test]
    fn durable_save_leaves_no_tmp_file() {
        let dir = std::env::temp_dir().join("miracle_ckpt_save_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mck").to_str().unwrap().to_string();
        let c = sample();
        c.save(&path, 99).unwrap();
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let loaded = Checkpoint::load_verified(&path, 99).unwrap();
        assert_eq!(c, loaded);
        assert_eq!(
            Checkpoint::load_verified(&path, 100),
            Err(CkptError::Fingerprint { stored: 99, expected: 100 })
        );
        let _ = std::fs::remove_file(&path);
    }
}
