//! Variational state: parameter initialization + Adam slots, in block layout.

use crate::prng::Pcg64;
use crate::runtime::ModelMeta;

use super::Layout;

/// Host-side training state in block layout [B, S] (matches the AOT graphs).
#[derive(Debug, Clone)]
pub struct VarState {
    pub mu: Vec<f32>,
    pub rho: Vec<f32>, // log sigma_q
    pub lsp: Vec<f32>, // log sigma_p, one per layer
    pub m_mu: Vec<f32>,
    pub v_mu: Vec<f32>,
    pub m_rho: Vec<f32>,
    pub v_rho: Vec<f32>,
    pub m_lsp: Vec<f32>,
    pub v_lsp: Vec<f32>,
    pub step: i32,
}

/// Initialization hyper-parameters.
#[derive(Debug, Clone)]
pub struct InitCfg {
    /// initial q stddev (paper trains it; this is the starting point)
    pub sigma_q0: f32,
    /// initial p stddev per layer
    pub sigma_p0: f32,
    /// He-style fan-in scaling for means
    pub mean_scale: f32,
}

impl Default for InitCfg {
    fn default() -> InitCfg {
        InitCfg { sigma_q0: 0.02, sigma_p0: 0.1, mean_scale: 1.0 }
    }
}

impl VarState {
    /// He-initialized means per layer (scaled by fan-in), flat -> slots ->
    /// block layout. Hash-shared slots receive the *last* position's draw,
    /// which is fine — they are iid anyway.
    pub fn init(meta: &ModelMeta, layout: &Layout, cfg: &InitCfg, seed: u64) -> VarState {
        let n_pad = meta.b * meta.s;
        let mut rng = Pcg64::seed(seed ^ 0x1A17);
        let mut mu = vec![0f32; n_pad];
        // walk positions layer by layer so fan-in scaling is per layer
        let mut pos = 0usize;
        for (l, &count) in meta.layer_counts.iter().enumerate() {
            // rough fan-in: count / sqrt of layer size heuristic. We don't
            // know W vs b split here; He over the whole layer is adequate
            // for these small nets.
            let fan_in = (count as f32).sqrt();
            let std = cfg.mean_scale * (2.0f32).sqrt() / fan_in.max(1.0);
            let _ = l;
            for _ in 0..count {
                let bpos = layout.assemble_map[pos] as usize;
                mu[bpos] = rng.next_normal() as f32 * std;
                pos += 1;
            }
        }
        VarState {
            mu,
            rho: vec![cfg.sigma_q0.ln(); n_pad],
            lsp: vec![cfg.sigma_p0.ln(); meta.n_layers],
            m_mu: vec![0.0; n_pad],
            v_mu: vec![0.0; n_pad],
            m_rho: vec![0.0; n_pad],
            v_rho: vec![0.0; n_pad],
            m_lsp: vec![0.0; meta.n_layers],
            v_lsp: vec![0.0; meta.n_layers],
            step: 0,
        }
    }

    /// Extract block row `b` of (mu, rho).
    pub fn block(&self, b: usize, s: usize) -> (&[f32], &[f32]) {
        (&self.mu[b * s..(b + 1) * s], &self.rho[b * s..(b + 1) * s])
    }

    /// Initialize means from a pretrained *dense* flat weight vector (the
    /// paper initializes VGG means from a pretrained model). Positions that
    /// hash to the same slot are averaged — the least-squares assignment of
    /// shared slots to pretrained weights.
    pub fn init_means_from_dense(&mut self, layout: &Layout, w_full: &[f32]) {
        assert_eq!(w_full.len(), layout.n_total);
        let n_pad = self.mu.len();
        let mut sums = vec![0f64; n_pad];
        let mut counts = vec![0u32; n_pad];
        for (pos, &bpos) in layout.assemble_map.iter().enumerate() {
            sums[bpos as usize] += w_full[pos] as f64;
            counts[bpos as usize] += 1;
        }
        for i in 0..n_pad {
            if counts[i] > 0 {
                self.mu[i] = (sums[i] / counts[i] as f64) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            b: 5,
            s: 4,
            k_chunk: 16,
            n_total: 18,
            n_slots: 18,
            n_layers: 2,
            layer_slots: vec![10, 8],
            layer_counts: vec![10, 8],
            batch: 4,
            eval_batch: 4,
            classes: 2,
            input_shape: vec![3],
        }
    }

    #[test]
    fn init_shapes() {
        let m = meta();
        let layout = Layout::generate(&m, 3);
        let st = VarState::init(&m, &layout, &InitCfg::default(), 1);
        assert_eq!(st.mu.len(), 20);
        assert_eq!(st.lsp.len(), 2);
        assert_eq!(st.step, 0);
    }

    #[test]
    fn init_deterministic() {
        let m = meta();
        let layout = Layout::generate(&m, 3);
        let a = VarState::init(&m, &layout, &InitCfg::default(), 1);
        let b = VarState::init(&m, &layout, &InitCfg::default(), 1);
        assert_eq!(a.mu, b.mu);
        let c = VarState::init(&m, &layout, &InitCfg::default(), 2);
        assert_ne!(a.mu, c.mu);
    }

    #[test]
    fn init_from_dense_averages_hash_collisions() {
        let m = ModelMeta {
            layer_slots: vec![5, 8], // first layer hashed 10 -> 5
            ..meta()
        };
        let layout = Layout::generate(&m, 11);
        let mut st = VarState::init(&m, &layout, &InitCfg::default(), 1);
        let w_full: Vec<f32> = (0..m.n_total).map(|i| i as f32).collect();
        st.init_means_from_dense(&layout, &w_full);
        // every slot's mean equals the average of the positions mapping there
        let mut sums = std::collections::BTreeMap::new();
        for (pos, &bpos) in layout.assemble_map.iter().enumerate() {
            let e = sums.entry(bpos).or_insert((0f32, 0u32));
            e.0 += pos as f32;
            e.1 += 1;
        }
        for (&bpos, &(sum, count)) in &sums {
            assert!((st.mu[bpos as usize] - sum / count as f32).abs() < 1e-4);
        }
        // un-hashed second layer: exact copy
        for pos in 10..18 {
            let bpos = layout.assemble_map[pos] as usize;
            assert_eq!(st.mu[bpos], pos as f32);
        }
    }

    #[test]
    fn real_slots_get_nonzero_means() {
        let m = meta();
        let layout = Layout::generate(&m, 3);
        let st = VarState::init(&m, &layout, &InitCfg::default(), 1);
        let touched = st.mu.iter().filter(|&&v| v != 0.0).count();
        assert!(touched >= m.n_slots.min(18) - 2); // collisions may zero-overlap rarely
    }
}
