//! Parameter layout: hashing trick + random block partition (Algorithm 2
//! line 2 and §3.3).
//!
//! The flat parameter vector (layers concatenated, `W` then `b` per layer) is
//! mapped to *trainable slots* by the hashing trick (Chen et al., 2015):
//! layer `l`'s positions hash into `layer_slots[l]` buckets. Slots are then
//! scattered into `B` blocks of `S` by a seed-derived random permutation.
//! Rust composes the two maps into one gather (`assemble_map`) consumed by
//! every backend entry point; the same seed therefore reconstructs the
//! layout on the decoder side — only `layout_seed` travels in the `.mrc`
//! header.

pub mod arch;
pub mod init;

use crate::prng::{mix64, Pcg64};
use crate::runtime::ModelMeta;

/// Runtime-generated layout maps (all deterministic in `seed`).
#[derive(Debug, Clone)]
pub struct Layout {
    pub seed: u64,
    /// flat parameter position -> index into block-layout slots [B*S]
    pub assemble_map: Vec<i32>,
    /// block-layout index -> layer id (padding -> 0)
    pub layer_map: Vec<i32>,
    /// block-layout index -> 1.0 real / 0.0 padding
    pub slot_mask: Vec<f32>,
    pub b: usize,
    pub s: usize,
    pub n_total: usize,
    pub n_slots: usize,
}

impl Layout {
    /// Build the layout for a model (hash maps + block permutation).
    pub fn generate(meta: &ModelMeta, seed: u64) -> Layout {
        let n_pad = meta.b * meta.s;
        // position -> slot (hashing trick, per layer)
        let mut pos_to_slot = vec![0u32; meta.n_total];
        let mut slot_layer = vec![0u32; n_pad];
        let mut pos = 0usize;
        let mut slot_base = 0usize;
        for (l, (&count, &m)) in meta
            .layer_counts
            .iter()
            .zip(&meta.layer_slots)
            .enumerate()
        {
            for i in 0..count {
                let bucket = if m == count {
                    i // no hashing for this layer
                } else {
                    (mix64(seed ^ ((l as u64) << 40) ^ i as u64) % m as u64) as usize
                };
                pos_to_slot[pos] = (slot_base + bucket) as u32;
                pos += 1;
            }
            for b in 0..m {
                slot_layer[slot_base + b] = l as u32;
            }
            slot_base += m;
        }
        debug_assert_eq!(pos, meta.n_total);
        debug_assert_eq!(slot_base, meta.n_slots);

        // slot -> block position (random permutation; Algorithm 2 line 2)
        let mut rng = Pcg64::seed(seed ^ 0xB10C5EED);
        let perm = rng.permutation(n_pad);

        let assemble_map = pos_to_slot
            .iter()
            .map(|&s| perm[s as usize] as i32)
            .collect();
        let mut layer_map = vec![0i32; n_pad];
        let mut slot_mask = vec![0f32; n_pad];
        for (slot, &bpos) in perm.iter().enumerate() {
            if slot < meta.n_slots {
                layer_map[bpos as usize] = slot_layer[slot] as i32;
                slot_mask[bpos as usize] = 1.0;
            }
        }
        Layout {
            seed,
            assemble_map,
            layer_map,
            slot_mask,
            b: meta.b,
            s: meta.s,
            n_total: meta.n_total,
            n_slots: meta.n_slots,
        }
    }

    /// Per-element log-sigma_p vector for block `b`, given the per-layer
    /// table (feeds the `score_block`/`decode_block` backend entries).
    pub fn block_lsp(&self, b: usize, lsp_layers: &[f32]) -> Vec<f32> {
        (0..self.s)
            .map(|j| lsp_layers[self.layer_map[b * self.s + j] as usize])
            .collect()
    }

    /// Mask row for block `b`.
    pub fn block_mask(&self, b: usize) -> &[f32] {
        &self.slot_mask[b * self.s..(b + 1) * self.s]
    }

    /// Number of real (non-padding) slots in block `b`.
    pub fn block_real_slots(&self, b: usize) -> usize {
        self.block_mask(b).iter().filter(|&&m| m > 0.0).count()
    }

    /// Assemble a flat parameter vector from block-layout values.
    pub fn assemble(&self, blocks_flat: &[f32]) -> Vec<f32> {
        self.assemble_map
            .iter()
            .map(|&i| blocks_flat[i as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            b: 6,
            s: 4,
            k_chunk: 16,
            n_total: 30,
            n_slots: 20,
            n_layers: 2,
            layer_slots: vec![12, 8],
            layer_counts: vec![22, 8],
            batch: 4,
            eval_batch: 4,
            classes: 2,
            input_shape: vec![3],
        }
    }

    #[test]
    fn maps_are_consistent() {
        let m = meta();
        let l = Layout::generate(&m, 123);
        assert_eq!(l.assemble_map.len(), m.n_total);
        assert_eq!(l.layer_map.len(), m.b * m.s);
        // every assemble target is a real slot
        for &t in &l.assemble_map {
            assert!(l.slot_mask[t as usize] > 0.0);
        }
        // mask count == n_slots
        let real: usize = l.slot_mask.iter().filter(|&&v| v > 0.0).count();
        assert_eq!(real, m.n_slots);
        // layer ids in range
        assert!(l.layer_map.iter().all(|&x| (0..2).contains(&x)));
    }

    #[test]
    fn deterministic_in_seed() {
        let m = meta();
        let a = Layout::generate(&m, 1);
        let b = Layout::generate(&m, 1);
        assert_eq!(a.assemble_map, b.assemble_map);
        let c = Layout::generate(&m, 2);
        assert_ne!(a.assemble_map, c.assemble_map);
    }

    #[test]
    fn layer2_positions_map_into_layer2_slots() {
        let m = meta();
        let l = Layout::generate(&m, 7);
        // last 8 positions are layer 1 (no hashing: 8 slots for 8 params)
        for pos in 22..30 {
            let bpos = l.assemble_map[pos] as usize;
            assert_eq!(l.layer_map[bpos], 1);
        }
        // layer 1 (un-hashed) positions map to *distinct* slots
        let mut seen = std::collections::BTreeSet::new();
        for pos in 22..30 {
            assert!(seen.insert(l.assemble_map[pos]));
        }
    }

    #[test]
    fn hashed_layer_shares_slots() {
        let m = meta();
        let l = Layout::generate(&m, 9);
        // 22 positions into 12 buckets -> must collide
        let distinct: std::collections::BTreeSet<i32> =
            l.assemble_map[..22].iter().cloned().collect();
        assert!(distinct.len() <= 12);
    }

    #[test]
    fn assemble_gathers() {
        let m = meta();
        let l = Layout::generate(&m, 3);
        let blocks: Vec<f32> = (0..m.b * m.s).map(|i| i as f32).collect();
        let full = l.assemble(&blocks);
        assert_eq!(full.len(), m.n_total);
        for (pos, &v) in full.iter().enumerate() {
            assert_eq!(v, l.assemble_map[pos] as f32);
        }
    }

    #[test]
    fn block_lsp_uses_layer_table() {
        let m = meta();
        let l = Layout::generate(&m, 4);
        let lsp = vec![-1.0f32, -2.0];
        for b in 0..m.b {
            let v = l.block_lsp(b, &lsp);
            for (j, &x) in v.iter().enumerate() {
                assert_eq!(x, lsp[l.layer_map[b * m.s + j] as usize]);
            }
        }
    }
}
