//! Built-in model configurations for the pure-Rust [`NativeBackend`].
//!
//! This is the Rust mirror of `python/compile/configs.py`: an architecture
//! plus block geometry fully determines every entry-point shape, so the
//! native backend can build its manifest ([`crate::runtime::Entry`] specs)
//! without any Python or AOT artifacts on the box. Both sides agree on the
//! flat parameter layout: layers in forward order, each contributing `W`
//! (row-major `[fan_in, fan_out]`) then `b`.
//!
//! The native backend executes **dense (MLP) architectures only**; inputs
//! with multi-dimensional per-example shapes (e.g. the `conv_synth` images)
//! are treated as flattened feature vectors. See
//! `docs/adr/001-backend-abstraction.md` for what this does and does not
//! guarantee relative to the PJRT graphs.
//!
//! [`NativeBackend`]: crate::runtime::native::NativeBackend

use crate::runtime::ModelMeta;
use crate::util::Result;
use crate::{ensure, err};

/// One dense layer: `W [fan_in, fan_out]` then `b [fan_out]` in the flat
/// parameter vector, starting at `offset`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DenseLayer {
    pub fan_in: usize,
    pub fan_out: usize,
    pub offset: usize,
}

impl DenseLayer {
    /// Number of parameters (`W` + `b`).
    pub fn count(&self) -> usize {
        self.fan_in * self.fan_out + self.fan_out
    }

    /// Flat offset of the bias vector.
    pub fn bias_offset(&self) -> usize {
        self.offset + self.fan_in * self.fan_out
    }
}

/// A fully-specified MLP configuration (architecture + block geometry).
#[derive(Debug, Clone)]
pub struct NetCfg {
    pub name: String,
    /// per-example input shape (flattened by the native forward pass)
    pub input_shape: Vec<usize>,
    pub classes: usize,
    /// trainable slots per layer after the hashing trick (== counts when
    /// the layer is dense/un-hashed)
    pub layer_slots: Vec<usize>,
    pub b: usize,
    pub s: usize,
    pub k_chunk: usize,
    pub batch: usize,
    pub eval_batch: usize,
    /// derived: dense layers in forward order with flat offsets
    pub layers: Vec<DenseLayer>,
}

impl NetCfg {
    /// Build an MLP config. `layer_slots = None` means dense (no hashing).
    pub fn mlp(
        name: &str,
        input_shape: Vec<usize>,
        hidden: &[usize],
        classes: usize,
        layer_slots: Option<Vec<usize>>,
        b: usize,
        s: usize,
        k_chunk: usize,
        batch: usize,
        eval_batch: usize,
    ) -> NetCfg {
        let input_dim: usize = input_shape.iter().product();
        let mut dims = vec![input_dim];
        dims.extend_from_slice(hidden);
        dims.push(classes);
        let mut layers = Vec::new();
        let mut offset = 0usize;
        for w in dims.windows(2) {
            let layer = DenseLayer { fan_in: w[0], fan_out: w[1], offset };
            offset += layer.count();
            layers.push(layer);
        }
        let layer_slots = layer_slots
            .unwrap_or_else(|| layers.iter().map(|l| l.count()).collect());
        NetCfg {
            name: name.to_string(),
            input_shape,
            classes,
            layer_slots,
            b,
            s,
            k_chunk,
            batch,
            eval_batch,
            layers,
        }
    }

    pub fn n_total(&self) -> usize {
        self.layers.iter().map(|l| l.count()).sum()
    }

    pub fn n_slots(&self) -> usize {
        self.layer_slots.iter().sum()
    }

    /// Flattened per-example feature count.
    pub fn feature_dim(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Runtime metadata (what a PJRT manifest would carry).
    pub fn meta(&self) -> ModelMeta {
        ModelMeta {
            name: self.name.clone(),
            b: self.b,
            s: self.s,
            k_chunk: self.k_chunk,
            n_total: self.n_total(),
            n_slots: self.n_slots(),
            n_layers: self.layers.len(),
            layer_slots: self.layer_slots.clone(),
            layer_counts: self.layers.iter().map(|l| l.count()).collect(),
            batch: self.batch,
            eval_batch: self.eval_batch,
            classes: self.classes,
            input_shape: self.input_shape.clone(),
        }
    }

    /// The invariants `python/compile/configs.py::validate` enforces.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.layer_slots.len() == self.layers.len(),
            "{}: layer_slots has {} entries, arch has {} layers",
            self.name,
            self.layer_slots.len(),
            self.layers.len()
        );
        for (layer, &m) in self.layers.iter().zip(&self.layer_slots) {
            ensure!(
                m > 0 && m <= layer.count(),
                "{}: layer slots {m} outside (0, {}]",
                self.name,
                layer.count()
            );
        }
        ensure!(
            self.b * self.s >= self.n_slots(),
            "{}: B*S={} < total slots {}",
            self.name,
            self.b * self.s,
            self.n_slots()
        );
        if self.k_chunk == 0 || self.k_chunk & (self.k_chunk - 1) != 0 {
            return err!("{}: k_chunk must be a power of two", self.name);
        }
        Ok(())
    }
}

/// Look up a built-in config by name. `*_dense` variants disable the hashing
/// trick (slots == raw parameter counts) for the baseline-compression runs.
pub fn builtin(name: &str) -> Option<NetCfg> {
    let cfg = match name {
        // 16-dim Gaussian-prototype task, 4 classes; already dense, so the
        // `_dense` alias maps to the same geometry.
        "tiny_mlp" | "tiny_mlp_dense" => NetCfg::mlp(
            name,
            vec![16],
            &[8],
            4,
            None,
            22,
            8,
            64,
            32,
            64,
        ),
        // LeNet-300-100-style MLP on synthetic 28x28 digits (flattened to
        // 784), hashed ~3.8x: 52650 raw parameters -> 13898 slots.
        "lenet_synth" => NetCfg::mlp(
            name,
            vec![784],
            &[64, 32],
            10,
            Some(vec![12544, 1024, 330]),
            435,
            32,
            256,
            32,
            128,
        ),
        "lenet_synth_dense" => NetCfg::mlp(
            name,
            vec![784],
            &[64, 32],
            10,
            None,
            1646,
            32,
            256,
            32,
            128,
        ),
        // Synthetic 16x16x3 texture task; the native backend runs it as an
        // MLP over the flattened 768-dim pixels (hashed ~3.8x).
        "conv_synth" => NetCfg::mlp(
            name,
            vec![16, 16, 3],
            &[48, 24],
            10,
            Some(vec![9216, 588, 250]),
            315,
            32,
            256,
            32,
            128,
        ),
        "conv_synth_dense" => NetCfg::mlp(
            name,
            vec![16, 16, 3],
            &[48, 24],
            10,
            None,
            1199,
            32,
            256,
            32,
            128,
        ),
        _ => return None,
    };
    Some(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_mlp_matches_seed_geometry() {
        let cfg = builtin("tiny_mlp").unwrap();
        cfg.validate().unwrap();
        // 16->8->4 MLP: (16*8+8) + (8*4+4) = 136 + 36 = 172
        assert_eq!(cfg.n_total(), 172);
        assert_eq!(cfg.n_slots(), 172);
        assert_eq!(cfg.layers[0].offset, 0);
        assert_eq!(cfg.layers[1].offset, 136);
        assert_eq!(cfg.layers[1].bias_offset(), 136 + 32);
        let meta = cfg.meta();
        assert_eq!(meta.layer_counts, vec![136, 36]);
        assert_eq!(meta.b * meta.s, 176);
        assert_eq!(meta.input_shape, vec![16]);
    }

    #[test]
    fn all_builtins_validate() {
        for name in [
            "tiny_mlp",
            "tiny_mlp_dense",
            "lenet_synth",
            "lenet_synth_dense",
            "conv_synth",
            "conv_synth_dense",
        ] {
            let cfg = builtin(name).unwrap();
            cfg.validate()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            // block geometry always covers the slot count
            assert!(cfg.b * cfg.s >= cfg.n_slots(), "{name}");
        }
    }

    #[test]
    fn hashed_configs_shrink_slots() {
        let h = builtin("lenet_synth").unwrap();
        let d = builtin("lenet_synth_dense").unwrap();
        assert_eq!(h.n_total(), d.n_total());
        assert!(h.n_slots() * 3 < d.n_slots());
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(builtin("vgg_real").is_none());
    }

    #[test]
    fn conv_synth_flattens_input() {
        let cfg = builtin("conv_synth").unwrap();
        assert_eq!(cfg.feature_dim(), 768);
        assert_eq!(cfg.layers[0].fan_in, 768);
        assert_eq!(cfg.meta().input_shape, vec![16, 16, 3]);
    }
}
