//! `miracle` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! * `compress`  — run Algorithm 2 on a synthetic benchmark and write `.mrc`
//! * `eval`      — decode an `.mrc` and report test error
//! * `info`      — print the header + size accounting of an `.mrc`
//! * `serve`     — run the batched inference server over an `.mrc`
//! * `pareto`    — sweep `C_loc` and emit the (size, error) series as JSON
//!
//! Everything runs on the pure-Rust native backend by default — no Python,
//! no artifacts. Set `MIRACLE_BACKEND=xla` (with a `--features xla` build
//! plus `make artifacts`) for the PJRT path.
//!
//! Examples:
//! ```text
//! miracle compress --model tiny_mlp --c-loc-bits 10 --i0 200 --out /tmp/m.mrc
//! miracle eval --mrc /tmp/m.mrc
//! miracle serve --mrc /tmp/m.mrc --clients 4 --requests 64
//! ```

use miracle::codec::{MrcError, MrcFile};
use miracle::coordinator::{self, Checkpoint, MiracleCfg, NonFinitePolicy, RunOptions};
use miracle::data;
use miracle::metrics::fmt_size;
use miracle::runtime::{self, Runtime};
use miracle::server::{
    spawn_clients, spawn_mtime_watcher, ReloadRequest, Request, Response, Server,
    ServerCfg, ServerFaults, ServeError, ShedPolicy,
};
use miracle::util::args::Args;
use miracle::util::breaker::BreakerCfg;
use miracle::util::faultline::ChaosSchedule;
use miracle::util::retry::RetryPolicy;
use miracle::util::{faultline, simd, Error, Result};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "usage: miracle <compress|eval|info|serve|pareto> [options]\n\
         \n\
         subcommands:\n\
         \x20 compress     run Algorithm 2 on a benchmark model, write .mrc\n\
         \x20 eval         decode an .mrc and report test error\n\
         \x20 info         print header + size accounting of an .mrc\n\
         \x20 serve        batched inference server over an .mrc\n\
         \x20 pareto       sweep C_loc, emit the (size, error) series as JSON\n\
         \x20 fuzz-decode  (CI) deterministic corruption fuzzing of decode\n\
         \x20 chaos-serve  (CI) deterministic chaos drive of the serve loop\n\
         \n\
         telemetry (accepted by every subcommand; no flag = no overhead):\n\
         \x20 --events-out PATH     structured JSON-lines event log\n\
         \x20 --events-level LVL    debug|info|warn (default info)\n\
         \x20 --metrics-out PATH    live metrics snapshot, atomically rewritten\n\
         \x20 --metrics-every N     snapshot every N batches/steps (default 32)\n\
         \x20 --trace-out PATH      Chrome trace-event JSON (chrome://tracing)"
    );
}

/// Bring up the process-wide telemetry sinks from the shared CLI flags
/// (see `docs/observability.md`). Reading the flags here marks them used
/// for every subcommand; with none present this configures nothing and
/// instrumentation stays zero-cost.
fn init_obs(cmd: &str, args: &Args) -> Result<()> {
    use miracle::obs::{self, Level, ObsCfg, Value};
    let cfg = ObsCfg {
        events_out: args.opt_str("events-out").map(str::to_string),
        events_level: Level::parse(&args.str("events-level", "info"))?,
        metrics_out: args.opt_str("metrics-out").map(str::to_string),
        metrics_every: args.u64("metrics-every", 32)?,
        trace_out: args.opt_str("trace-out").map(str::to_string),
    };
    if !cfg.any_sink() {
        return Ok(());
    }
    obs::init(
        &cfg,
        &[
            ("cmd", Value::from(cmd)),
            ("pid", Value::from(std::process::id() as u64)),
        ],
    )
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse_from(argv, &["lazy", "half", "resume"])?;
    // telemetry first, so every later decision (including the SIMD
    // dispatch just below) lands in the event log
    init_obs(&cmd, &args)?;
    // --simd {auto|scalar|avx2|neon}: pin the kernel dispatch path before
    // any runtime or kernel runs (CLI wins over the MIRACLE_SIMD env var;
    // both are strict — a typo or an unavailable path is a hard error)
    if let Some(v) = args.opt_str("simd") {
        simd::force(simd::parse(v)?)?;
    }
    let result = match cmd.as_str() {
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(&args),
        "serve" => cmd_serve(&args),
        "pareto" => cmd_pareto(&args),
        // hidden: deterministic corruption fuzzing of the decode path (CI)
        "fuzz-decode" => cmd_fuzz_decode(&args),
        // hidden: deterministic chaos drive of the serve loop (CI)
        "chaos-serve" => cmd_chaos_serve(&args),
        other => {
            eprintln!("unknown command '{other}' (compress|eval|info|serve|pareto)");
            std::process::exit(2);
        }
    };
    // final metrics snapshot, event flush, trace-array close — idempotent,
    // and a no-op when no sink was configured
    miracle::obs::finish();
    result
}

/// Sweep C_loc and emit the (size, error) series as JSON — the scriptable
/// Figure-1 driver.
fn cmd_pareto(args: &Args) -> Result<()> {
    use miracle::util::json::Json;
    let model = args.str("model", "tiny_mlp");
    let budgets: Vec<u8> = args
        .str("budgets", "3,4,6,10")
        .split(',')
        .map(|s| s.trim().parse::<u8>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| miracle::util::Error::msg(format!("bad --budgets: {e}")))?;
    let i0 = args.usize("i0", 1500)?;
    let i_int = args.usize("i", 1)?;
    let n_train = args.usize("train-size", 2048)?;
    let n_test = args.usize("test-size", 1024)?;
    let out = args.opt_str("out").map(str::to_string);
    args.finish()?;

    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, &model)?;
    let (train, test) = datasets_for(&model, n_train, n_test, 1234);
    let mut points = Vec::new();
    for &bits in &budgets {
        let cfg = MiracleCfg {
            c_loc_bits: bits,
            i0,
            i_intermediate: i_int,
            lr: if model == "tiny_mlp" { 5e-3 } else { 2e-3 },
            beta0: 1e-4,
            eps_beta: 0.01,
            data_scale: train.len() as f32,
            ..Default::default()
        };
        let r = coordinator::compress(&arts, &train, &test, &cfg)?;
        eprintln!(
            "C_loc={bits}b: {} bits, {:.2}% error",
            r.total_bits,
            r.test_error * 100.0
        );
        points.push(Json::obj(vec![
            ("c_loc_bits", Json::num(bits as f64)),
            ("size_bits", Json::num(r.total_bits as f64)),
            ("ratio", Json::num(
                (arts.meta.n_total * 32) as f64 / r.total_bits as f64,
            )),
            ("test_error", Json::num(r.test_error)),
            ("mean_block_kl_bits", Json::num(r.mean_block_kl_bits)),
        ]));
    }
    let doc = Json::obj(vec![
        ("model", Json::str(&model)),
        ("n_weights", Json::num(arts.meta.n_total as f64)),
        ("points", Json::Arr(points)),
    ]);
    let text = doc.to_pretty();
    match out {
        Some(path) => {
            std::fs::write(&path, &text)?;
            println!("wrote {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// Build the (train, test) synthetic datasets appropriate for a model.
pub fn datasets_for(
    model: &str,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (data::Dataset, data::Dataset) {
    if model.starts_with("conv") {
        (
            data::synth_cifar(n_train, 16, 16, seed),
            data::synth_cifar(n_test, 16, 16, seed ^ 0x7E57),
        )
    } else if model.starts_with("lenet") {
        (
            data::synth_mnist(n_train, seed),
            data::synth_mnist(n_test, seed ^ 0x7E57),
        )
    } else {
        // tiny_mlp: 16-dim Gaussian prototype task, 4 classes
        (
            data::synth_protos(n_train, 16, 4, seed),
            data::synth_protos(n_test, 16, 4, seed ^ 0x7E57),
        )
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    let model = args.str("model", "tiny_mlp");
    let out = args.str("out", "model.mrc");
    let history_csv = args.opt_str("history").map(str::to_string);
    let n_train = args.usize("train-size", 2048)?;
    let n_test = args.usize("test-size", 1024)?;
    let cfg = MiracleCfg {
        c_loc_bits: args.usize("c-loc-bits", 12)? as u8,
        i0: args.usize("i0", 300)?,
        i_intermediate: args.usize("i", 1)?,
        lr: args.f64("lr", 1e-3)? as f32,
        beta0: args.f64("beta0", 1e-8)? as f32,
        eps_beta: args.f64("eps-beta", 5e-5)? as f32,
        data_scale: args.f64("data-scale", n_train as f64)? as f32,
        layout_seed: args.u64("layout-seed", 0x4D31_7261)?,
        protocol_seed: args.usize("protocol-seed", 7)? as i32,
        train_seed: args.u64("train-seed", 42)?,
        threads: args.usize("threads", 0)?,
    };
    let opts = RunOptions {
        checkpoint: args.opt_str("checkpoint").map(str::to_string),
        every_blocks: args.usize("checkpoint-every", 64)?,
        resume: args.flag("resume"),
        on_nonfinite: match args.str("on-nonfinite", "abort").as_str() {
            "abort" => NonFinitePolicy::Abort,
            "rewind" => NonFinitePolicy::Rewind,
            other => {
                return Err(Error::msg(format!(
                    "--on-nonfinite must be abort|rewind, got '{other}'"
                )))
            }
        },
        ..Default::default()
    };
    args.finish()?;

    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, &model)?;
    let (train, test) = datasets_for(&model, n_train, n_test, 1234);
    let t = miracle::util::Timer::start();
    let result = coordinator::compress_with(&arts, &train, &test, &cfg, &opts)?;
    result.mrc.save(&out)?;
    let n_weights = arts.meta.n_total;
    println!("model:           {model}");
    println!("blocks:          {} x {} bits", result.mrc.b, cfg.c_loc_bits);
    println!(
        "compressed size: {} ({} bits)",
        fmt_size(result.total_bits as f64 / 8.0),
        result.total_bits
    );
    println!("uncompressed:    {}", fmt_size(n_weights as f64 * 4.0));
    println!(
        "ratio:           {:.0}x",
        (n_weights * 32) as f64 / result.total_bits as f64
    );
    println!("test error:      {:.2}%", result.test_error * 100.0);
    println!(
        "mean block KL:   {:.2} bits (goal {})",
        result.mean_block_kl_bits, cfg.c_loc_bits
    );
    println!(
        "train/encode:    {:.1}s / {:.1}s (total {:.1}s)",
        result.train_secs,
        result.encode_secs,
        t.secs()
    );
    println!(
        "simd/threads:    {} / {}",
        simd::active(),
        miracle::util::pool::current_threads()
    );
    println!("wrote {out}");
    if let Some(path) = history_csv {
        let mut t = miracle::metrics::Table::new(
            "training history",
            &["step", "loss", "ce", "train_acc", "mean_kl_nats"],
        );
        for (i, m) in result.history.iter().enumerate() {
            t.row(vec![
                i.to_string(),
                format!("{}", m.loss),
                format!("{}", m.ce),
                format!("{}", m.acc),
                format!("{}", m.mean_kl_nats),
            ]);
        }
        t.save_csv(&path)?;
        println!("history -> {path}");
    }
    Ok(())
}

/// Load an `.mrc`, routing structured codec errors into a one-line
/// diagnosis that names the offending file (I/O errors already carry the
/// path; parse/integrity errors get it prefixed here).
fn load_mrc(path: &str) -> Result<MrcFile> {
    MrcFile::load(path).map_err(|e| match e {
        e @ MrcError::Io { .. } => Error::msg(e.to_string()),
        e => Error::msg(format!("{path}: {e}")),
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    let path = args.require("mrc")?;
    let n_test = args.usize("test-size", 1024)?;
    let _threads =
        miracle::util::pool::override_threads(args.usize("threads", 0)?);
    args.finish()?;
    let mrc = load_mrc(&path)?;
    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, &mrc.model)?;
    let (_, test) = datasets_for(&mrc.model, 1, n_test, 1234);
    let w = coordinator::decode_model(&arts, &mrc)?;
    let layout = miracle::model::Layout::generate(&arts.meta, mrc.layout_seed);
    let err = coordinator::eval_error(&arts, &layout.assemble_map, &w, &test)?;
    println!(
        "{path}: test error {:.2}% over {} examples",
        err * 100.0,
        test.len()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let path = args.require("mrc")?;
    args.finish()?;
    let bytes = std::fs::read(&path)
        .map_err(|e| Error::msg(format!("read {path}: {e}")))?;
    let version = MrcFile::version_of(&bytes)
        .map_err(|e| Error::msg(format!("{path}: {e}")))?;
    let mrc = MrcFile::from_bytes(&bytes)
        .map_err(|e| Error::msg(format!("{path}: {e}")))?;
    println!("model:        {}", mrc.model);
    println!(
        "format:       v{version} {}",
        if version >= 2 {
            "(header + payload CRC32 verified)"
        } else {
            "(legacy, no integrity checks)"
        }
    );
    println!("blocks:       {} x {} slots", mrc.b, mrc.s);
    println!(
        "C_loc:        {} bits (K = {})",
        mrc.c_loc_bits,
        1u64 << mrc.c_loc_bits
    );
    println!("payload:      {} bits", mrc.payload_bits());
    println!(
        "container:    {} bits ({} bits header overhead)",
        mrc.total_bits(),
        mrc.total_bits() - mrc.payload_bits()
    );
    println!(
        "sigma_p:      {:?}",
        mrc.lsp.iter().map(|l| l.exp()).collect::<Vec<_>>()
    );
    println!("layout seed:  {:#x}", mrc.layout_seed);
    println!("protocol:     {}", mrc.protocol_seed);
    println!("backend:      {:?}", mrc.backend);
    // host property, not a container field: decode bytes are SIMD-path
    // invariant, so this only affects fresh-encode speed on this machine
    println!("simd:         {}", simd::selected()?);
    // Sibling checkpoint (the `--checkpoint {mrc}.ckpt` convention): report
    // run progress, or the structured MCK2 error if the file is damaged.
    let ckpt_path = format!("{path}.ckpt");
    if std::path::Path::new(&ckpt_path).exists() {
        match Checkpoint::load(&ckpt_path) {
            Ok((ck, fp)) => {
                let b = ck.indices.len();
                let k = ck.encoded_blocks();
                println!(
                    "checkpoint:   {ckpt_path}: step {}, encoded {k}/{b} \
                     blocks{}, fingerprint {fp:#018x}",
                    ck.step,
                    if k == b { " (run complete)" } else { "" }
                );
            }
            Err(e) => println!("checkpoint:   {ckpt_path}: UNUSABLE — {e}"),
        }
    } else {
        println!("checkpoint:   none ({ckpt_path} not present)");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let path = args.require("mrc")?;
    let n_clients = args.usize("clients", 4)?;
    let per_client = args.usize("requests", 32)?;
    let max_batch = args.usize("max-batch", 64)?;
    let deadline_ms = args.u64("deadline-ms", 30_000)?;
    let queue_depth = args.usize("queue-depth", 1024)?;
    let shed: ShedPolicy = args.str("shed", "reject").parse()?;
    let reload_watch = args.opt_str("reload-watch").map(str::to_string);
    let lazy = args.flag("lazy");
    let heartbeat_ms = args.u64("heartbeat-ms", 0)?;
    let _threads =
        miracle::util::pool::override_threads(args.usize("threads", 0)?);
    args.finish()?;
    let mrc = load_mrc(&path)?;
    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, &mrc.model)?;
    let (_, test) = datasets_for(&mrc.model, 1, 256, 99);
    let feat = test.feature_dim();
    let examples: Vec<Vec<f32>> = (0..test.len())
        .map(|i| test.x[i * feat..(i + 1) * feat].to_vec())
        .collect();
    let cfg = ServerCfg {
        max_batch,
        lazy_decode: lazy,
        deadline: std::time::Duration::from_millis(deadline_ms),
        queue_depth,
        shed,
        heartbeat: std::time::Duration::from_millis(heartbeat_ms),
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg)?;
    if let Some(watch) = reload_watch {
        let (reload_rx, _watcher) = spawn_mtime_watcher(
            std::path::PathBuf::from(&watch),
            std::time::Duration::from_millis(200),
        );
        server.set_reload(reload_rx);
        println!("watching {watch} for hot reloads");
    }
    let (rx, clients) =
        spawn_clients(examples, n_clients, per_client, std::time::Duration::ZERO);
    let stats = server.run(rx)?;
    let _ = clients.join();
    println!(
        "accepted:    {} requests ({} served in {} batches, {} shed, {} errored)",
        stats.accepted, stats.served, stats.batches, stats.rejected, stats.errored
    );
    println!(
        "sheds:       {} overloaded, {} deadline, {} bad-request \
         (queue high-water {} / depth {})",
        stats.sheds.overloaded,
        stats.sheds.deadline,
        stats.sheds.bad_request,
        stats.queue_high_water,
        queue_depth
    );
    println!(
        "errors:      {} decode, {} exec, {} breaker-open \
         ({} retries absorbed, {} breaker trips)",
        stats.errors.decode,
        stats.errors.exec,
        stats.errors.breaker,
        stats.retries,
        stats.breaker_trips
    );
    if stats.reloads + stats.reloads_rejected > 0 {
        println!(
            "reloads:     {} applied, {} rejected (last-known-good kept)",
            stats.reloads, stats.reloads_rejected
        );
    }
    println!(
        "throughput:  {:.0} req/s",
        stats.served as f64 / stats.wall_secs
    );
    println!(
        "latency:     p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
        stats.latency.p50 * 1e3,
        stats.latency.p95 * 1e3,
        stats.latency.p99 * 1e3
    );
    println!("exec/batch:  {:.2}ms mean", stats.exec_time.mean * 1e3);
    println!("decode time: {:.2}s", stats.decode_secs);
    stats.check_invariant()
}

/// Hidden subcommand (CI): deterministic corruption fuzzing of the `.mrc`
/// decode path. Every mutated v2 container must either fail to parse with a
/// structured error or parse byte-identically — a parse that *succeeds but
/// differs* is silent corruption and exits 1. Legacy v1 containers carry no
/// integrity data, so their silent diffs are counted and reported instead
/// of failing. Any failure reproduces from `(--seed, iter)` alone.
fn cmd_fuzz_decode(args: &Args) -> Result<()> {
    let seed = args.u64("seed", 0xF00D)?;
    let iters = args.usize("iters", 500)?;
    let kind = args.str("kind", "mrc");
    match kind.as_str() {
        "mrc" => {
            let base_path = args.opt_str("mrc").map(str::to_string);
            args.finish()?;
            fuzz_mrc(seed, iters, base_path)
        }
        "ckpt" => {
            let base_path = args.opt_str("ckpt").map(str::to_string);
            args.finish()?;
            fuzz_ckpt(seed, iters, base_path)
        }
        other => Err(Error::msg(format!(
            "--kind must be mrc|ckpt, got '{other}'"
        ))),
    }
}

fn fuzz_mrc(seed: u64, iters: usize, base_path: Option<String>) -> Result<()> {
    let corpora: Vec<(String, Vec<u8>)> = match base_path {
        Some(p) => {
            let bytes = std::fs::read(&p)
                .map_err(|e| Error::msg(format!("read {p}: {e}")))?;
            vec![(p, bytes)]
        }
        None => {
            let mrc = synth_fuzz_mrc();
            vec![
                ("synthetic v2".into(), mrc.to_bytes()),
                ("synthetic v1 (legacy)".into(), mrc.to_bytes_v1()),
            ]
        }
    };

    for (label, base) in &corpora {
        let version = MrcFile::version_of(base)
            .map_err(|e| Error::msg(format!("{label}: {e}")))?;
        let reference = MrcFile::from_bytes(base)
            .map_err(|e| Error::msg(format!("{label}: base does not parse: {e}")))?;
        let protected = version >= 2;
        let (mut rejected, mut identical, mut silent) = (0usize, 0usize, 0usize);
        for (i, fault) in
            faultline::plan(seed, iters, base.len()).into_iter().enumerate()
        {
            let mutated = fault.apply(base);
            match MrcFile::from_bytes(&mutated) {
                Err(_) => rejected += 1,
                Ok(parsed) if parsed == reference => identical += 1,
                Ok(_) if protected => {
                    eprintln!(
                        "SILENT CORRUPTION in {label}: seed {seed} iter {i}: {}",
                        fault.describe()
                    );
                    std::process::exit(1);
                }
                Ok(_) => silent += 1,
            }
        }
        println!(
            "fuzz-decode {label} (v{version}): {iters} mutations -> \
             {rejected} rejected, {identical} parsed identically, {silent} silent diffs{}",
            if protected { " (0 tolerated)" } else { " (legacy, unprotected)" }
        );
    }
    Ok(())
}

/// MCK2 checkpoint fuzzing (`--kind ckpt`): every mutated container must
/// either fail with a structured [`miracle::coordinator::CkptError`] or
/// parse identically to the reference — a parse that succeeds but differs
/// would silently alter a resumed run, and exits 1. On top of the random
/// plan, the exhaustive mid-write crash plan (every truncation point, torn
/// tails) runs for containers up to 64 KiB.
fn fuzz_ckpt(seed: u64, iters: usize, base_path: Option<String>) -> Result<()> {
    const FP: u64 = 0x0F1A_6C0D_E5EE_D001;
    let (label, base) = match base_path {
        Some(p) => {
            let bytes = std::fs::read(&p)
                .map_err(|e| Error::msg(format!("read {p}: {e}")))?;
            (p, bytes)
        }
        None => (
            "synthetic MCK2".to_string(),
            synth_fuzz_ckpt().to_container_bytes(FP),
        ),
    };
    let (reference, ref_fp) = Checkpoint::from_container_bytes(&base)
        .map_err(|e| Error::msg(format!("{label}: base does not parse: {e}")))?;
    let mut faults = faultline::plan(seed, iters, base.len());
    let crash = if base.len() <= 64 * 1024 {
        let c = faultline::crash_plan(seed, base.len());
        faults.extend(c.iter().cloned());
        c.len()
    } else {
        eprintln!("note: {label} exceeds 64 KiB, crash plan skipped");
        0
    };
    let (mut rejected, mut identical) = (0usize, 0usize);
    for (i, fault) in faults.into_iter().enumerate() {
        let mutated = fault.apply(&base);
        match Checkpoint::from_container_bytes(&mutated) {
            Err(_) => rejected += 1,
            Ok((parsed, fp)) if parsed == reference && fp == ref_fp => {
                identical += 1
            }
            Ok(_) => {
                eprintln!(
                    "SILENT CORRUPTION in {label}: seed {seed} iter {i}: {}",
                    fault.describe()
                );
                std::process::exit(1);
            }
        }
    }
    println!(
        "fuzz-decode {label} (MCK2): {} mutations ({iters} random + {crash} \
         crash-plan) -> {rejected} rejected, {identical} parsed identically \
         (0 silent diffs tolerated)",
        iters + crash
    );
    Ok(())
}

/// Hidden subcommand (CI): deterministic chaos drive of the serve loop.
/// One process, four phases against a live server: (1) a pre-queued
/// overload burst that must shed exactly down to the bounded queue;
/// (2) steady traffic through intermittent, seed-scheduled exec faults and
/// latency spikes (absorbed by retries); (3) a hard outage window that must
/// trip the circuit breaker, fast-fail with `BreakerOpen`, then recover via
/// HalfOpen probes once the outage window passes; (4) reload under fire — a
/// corrupt container push that must be rejected (last-known-good keeps
/// serving) followed by a valid push that must swap in. Any violated
/// expectation exits 1; everything reproduces from `--seed` alone.
fn cmd_chaos_serve(args: &Args) -> Result<()> {
    use std::sync::mpsc::channel;
    use std::time::{Duration, Instant};

    let seed = args.u64("seed", 7)?;
    let iters = args.usize("iters", 200)?;
    let mrc_path = args.opt_str("mrc").map(str::to_string);
    args.finish()?;

    let mrc = match mrc_path {
        Some(p) => load_mrc(&p)?,
        None => synth_fuzz_mrc(),
    };
    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, &mrc.model)?;
    let (_, test) = datasets_for(&mrc.model, 1, 64, 99);
    let feat = test.feature_dim();
    let example: Vec<f32> = test.x[..feat].to_vec();

    // Chaos geometry. Ticks advance once per executed batch: the burst is
    // tick 0, the steady phase is ticks 1..=iters, so the outage window
    // lands exactly where phase 3's driver starts hammering.
    const DEPTH: usize = 4;
    const BURST: usize = 20;
    let outage_start = 1 + iters as u64;
    let cfg = ServerCfg {
        max_batch: DEPTH,
        queue_depth: DEPTH,
        shed: ShedPolicy::Reject,
        deadline: Duration::from_secs(5),
        reload_poll: Duration::from_millis(5),
        retry: RetryPolicy::default(),
        breaker: BreakerCfg {
            window: 8,
            min_samples: 4,
            trip_ratio: 0.5,
            cooldown: Duration::from_millis(40),
            probes: 2,
        },
        faults: ServerFaults {
            schedule: ChaosSchedule {
                seed,
                exec_fail_p: 0.10,
                outage: Some((outage_start, outage_start + 8)),
                spike_p: 0.05,
                spike: Duration::from_millis(2),
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg)?;
    let (reload_tx, reload_rx) = channel::<ReloadRequest>();
    server.set_reload(reload_rx);

    // Reload candidates: the first plan fault that breaks the container's
    // integrity check (rejected push), and a valid container whose indices
    // genuinely differ (applied push).
    let good_bytes = mrc.to_bytes();
    let corrupt_bytes = faultline::plan(seed, 64, good_bytes.len())
        .into_iter()
        .map(|f| f.apply(&good_bytes))
        .find(|m| MrcFile::from_bytes(m).is_err())
        .ok_or_else(|| Error::msg("no rejecting fault in 64 tries"))?;
    let swapped_bytes = {
        let mut next = mrc.clone();
        let k = 1u64 << next.c_loc_bits;
        next.indices[0] = (next.indices[0] + 1) % k;
        next.to_bytes()
    };

    // phase 1: the burst is fully enqueued BEFORE the loop starts, so
    // admission is deterministic: DEPTH admitted, BURST - DEPTH shed.
    let (tx, rx) = channel::<Request>();
    let mut burst_rx = Vec::new();
    for _ in 0..BURST {
        let (rtx, rrx) = channel();
        tx.send(Request {
            x: example.clone(),
            submitted: Instant::now(),
            reply: rtx,
        })
        .map_err(|_| Error::msg("burst send failed"))?;
        burst_rx.push(rrx);
    }

    struct DriverReport {
        sent: usize,
        ok: usize,
        lost: usize,
        burst_answers: usize,
        breaker_open_seen: bool,
        recovered: bool,
        reload_survived: bool,
    }

    // phases 2-4 run on a driver thread; the backend handle (not Send) and
    // therefore the serve loop stay on this thread
    let driver = {
        let tx = tx.clone();
        std::thread::spawn(move || -> DriverReport {
            let send_one = |x: &Vec<f32>| -> Option<Response> {
                let (rtx, rrx) = channel();
                tx.send(Request {
                    x: x.clone(),
                    submitted: Instant::now(),
                    reply: rtx,
                })
                .ok()?;
                rrx.recv_timeout(Duration::from_secs(10)).ok()
            };
            let mut rep = DriverReport {
                sent: 0,
                ok: 0,
                lost: 0,
                burst_answers: 0,
                breaker_open_seen: false,
                recovered: false,
                reload_survived: false,
            };
            // wait the burst out first: phase 2 must not race requests into
            // the burst batch's shed window, or the shed count would wobble
            rep.burst_answers = burst_rx
                .iter()
                .filter(|r| r.recv_timeout(Duration::from_secs(10)).is_ok())
                .count();
            fn tally(rep: &mut DriverReport, r: Option<Response>) -> bool {
                rep.sent += 1;
                match r {
                    Some(resp) => {
                        let ok = resp.is_ok();
                        if ok {
                            rep.ok += 1;
                        }
                        ok
                    }
                    None => {
                        rep.lost += 1;
                        false
                    }
                }
            }
            // phase 2: steady traffic through intermittent chaos
            for _ in 0..iters {
                tally(&mut rep, send_one(&example));
            }
            // phase 3: hammer into the outage until the breaker has both
            // tripped (BreakerOpen observed) and recovered (5 straight Ok)
            let mut consecutive_ok = 0usize;
            for _ in 0..2000 {
                if rep.breaker_open_seen && consecutive_ok >= 5 {
                    break;
                }
                let resp = send_one(&example);
                if let Some(Response::Err(ServeError::BreakerOpen {
                    retry_after,
                })) = &resp
                {
                    rep.breaker_open_seen = true;
                    // honor the hint instead of spinning on fast-fails
                    let wait = *retry_after + Duration::from_millis(1);
                    rep.sent += 1;
                    consecutive_ok = 0;
                    std::thread::sleep(wait);
                    continue;
                }
                if tally(&mut rep, resp) {
                    consecutive_ok += 1;
                } else {
                    consecutive_ok = 0;
                }
            }
            rep.recovered = rep.breaker_open_seen && consecutive_ok >= 5;
            // phase 4: reload under fire — corrupt push must be rejected
            // (serving continues), valid push must swap in
            let _ = reload_tx.send(ReloadRequest {
                bytes: corrupt_bytes,
                origin: "chaos:corrupt".into(),
            });
            std::thread::sleep(Duration::from_millis(50));
            let mut after_corrupt = 0usize;
            for _ in 0..3 {
                if tally(&mut rep, send_one(&example)) {
                    after_corrupt += 1;
                }
            }
            let _ = reload_tx.send(ReloadRequest {
                bytes: swapped_bytes,
                origin: "chaos:swap".into(),
            });
            std::thread::sleep(Duration::from_millis(50));
            let mut after_swap = 0usize;
            for _ in 0..5 {
                if tally(&mut rep, send_one(&example)) {
                    after_swap += 1;
                }
            }
            rep.reload_survived = after_corrupt >= 2 && after_swap >= 4;
            rep
        })
    };
    drop(tx);
    let stats = server.run(rx)?;
    let report = driver
        .join()
        .map_err(|_| Error::msg("chaos driver thread panicked"))?;

    let mut violations: Vec<String> = Vec::new();
    if let Err(e) = stats.check_invariant() {
        violations.push(format!("stats invariant: {e}"));
    }
    let total_sent = BURST + report.sent;
    if stats.accepted != total_sent {
        violations.push(format!(
            "accepted {} != sent {total_sent} (a request vanished)",
            stats.accepted
        ));
    }
    if report.burst_answers != BURST {
        violations.push(format!(
            "burst: {}/{BURST} answered (replies lost)",
            report.burst_answers
        ));
    }
    if report.lost > 0 {
        violations.push(format!("{} driver requests got no reply", report.lost));
    }
    if stats.sheds.overloaded != BURST - DEPTH {
        violations.push(format!(
            "expected exactly {} overload sheds from the burst, saw {}",
            BURST - DEPTH,
            stats.sheds.overloaded
        ));
    }
    if stats.breaker_trips == 0 || !report.breaker_open_seen {
        violations.push(format!(
            "breaker never tripped (trips {}, open seen {})",
            stats.breaker_trips, report.breaker_open_seen
        ));
    }
    if !report.recovered {
        violations.push("breaker never recovered to 5 straight Ok".into());
    }
    if stats.reloads != 1 || stats.reloads_rejected != 1 {
        violations.push(format!(
            "reloads: {} applied / {} rejected (want 1 / 1)",
            stats.reloads, stats.reloads_rejected
        ));
    }
    if !report.reload_survived {
        violations.push("requests around the reloads failed".into());
    }
    // Telemetry reconcile: with `--events-out`, every resilience counter in
    // the ledger must have an exactly matching event count — the log is
    // only trustworthy if it never drops or double-counts an incident.
    // (Requires the default `--events-level info`; sheds log at info.)
    if let Some(path) = miracle::obs::events_path() {
        miracle::obs::finish(); // flush before reading our own log
        match reconcile_events(path, &stats) {
            Ok(n) => println!(
                "chaos-serve: event log reconciled ({n} counters match {path})"
            ),
            Err(e) => violations.push(format!("event log reconcile: {e}")),
        }
    }

    println!(
        "chaos-serve seed {seed}: {} accepted -> {} served / {} shed \
         ({} overloaded) / {} errored ({} exec, {} breaker-open); \
         {} retries, {} breaker trips, reloads {}+{} rejected, \
         queue high-water {}",
        stats.accepted,
        stats.served,
        stats.rejected,
        stats.sheds.overloaded,
        stats.errored,
        stats.errors.exec,
        stats.errors.breaker,
        stats.retries,
        stats.breaker_trips,
        stats.reloads,
        stats.reloads_rejected,
        stats.queue_high_water
    );
    if !violations.is_empty() {
        for v in &violations {
            eprintln!("CHAOS VIOLATION: {v}");
        }
        std::process::exit(1);
    }
    println!("chaos-serve: all resilience expectations held");
    Ok(())
}

/// Count events in a JSON-lines log and check the ones with an exact-match
/// contract against the serve ledger. Returns how many counters matched.
fn reconcile_events(
    path: &str,
    stats: &miracle::server::ServeStats,
) -> Result<usize> {
    use miracle::util::json::Json;
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::msg(format!("read {path}: {e}")))?;
    let mut counts = std::collections::BTreeMap::<String, usize>::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| Error::msg(format!("{path}:{}: {e}", i + 1)))?;
        let ev = j.get("ev")?.as_str()?;
        *counts.entry(ev.to_string()).or_default() += 1;
    }
    let want: [(&str, usize); 4] = [
        ("shed", stats.rejected),
        ("breaker_open", stats.breaker_trips as usize),
        ("reload_applied", stats.reloads),
        ("reload_rejected", stats.reloads_rejected),
    ];
    for (ev, expect) in want {
        let got = counts.get(ev).copied().unwrap_or(0);
        if got != expect {
            return Err(Error::msg(format!(
                "'{ev}' events: {got} logged, ledger says {expect}"
            )));
        }
    }
    Ok(want.len())
}

/// A fixed tiny_mlp-geometry MCK2 checkpoint for fuzzing without a runtime:
/// mid-run state, 7 of 22 blocks encoded.
fn synth_fuzz_ckpt() -> Checkpoint {
    let n = 22 * 8;
    Checkpoint {
        model: "tiny_mlp".into(),
        b: 22,
        s: 8,
        n_layers: 2,
        step: 120,
        mu: (0..n).map(|i| i as f32 * 0.01 - 0.5).collect(),
        rho: vec![-3.0; n],
        lsp: vec![-1.5, -2.25],
        m_mu: vec![0.01; n],
        v_mu: vec![0.02; n],
        m_rho: vec![0.03; n],
        v_rho: vec![0.04; n],
        m_lsp: vec![0.05; 2],
        v_lsp: vec![0.06; 2],
        beta: vec![1e-6; 22],
        frozen_mask: (0..n).map(|i| if i < 7 * 8 { 1.0 } else { 0.0 }).collect(),
        frozen_w: vec![0.125; n],
        indices: (0..22u64)
            .map(|i| if i < 7 { (i * 37 + 11) % 1024 } else { u64::MAX })
            .collect(),
        last_kl: vec![4.25; 22],
        kl_bits_sum: 70.5,
        history: vec![],
    }
}

/// A fixed tiny_mlp-geometry container for fuzzing without a runtime.
fn synth_fuzz_mrc() -> MrcFile {
    MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0x4D31_7261,
        protocol_seed: 7,
        backend: miracle::codec::BackendFamily::Native,
        b: 22,
        s: 8,
        k_chunk: 64,
        c_loc_bits: 10,
        lsp: vec![-1.5, -2.25],
        indices: (0..22u64).map(|i| (i * 37 + 11) % 1024).collect(),
    }
}
