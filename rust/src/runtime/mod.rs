//! Pluggable execution backends behind one manifest-validated boundary.
//!
//! The coordinator (L3) never talks to a compute substrate directly: every
//! numerical entry point (`train_step`, the batched candidate entries
//! `score_block` / `score_blocks` / `decode_block`, their chunk-level
//! ancestors `score_chunk` / `decode_chunk`, `eval_batch`, `eval_full`,
//! `sample_weights`) goes through
//! [`ModelArtifacts::invoke`] / [`ModelArtifacts::invoke_mixed`], which
//! validate argument shapes and dtypes against the model's manifest
//! ([`Entry`] specs) and then dispatch to a [`Backend`]:
//!
//! * [`native::NativeBackend`] — the default. Executes every entry point in
//!   pure Rust over [`crate::tensor`], with protocol randomness derived in
//!   [`crate::prng`]; zero Python, zero XLA, zero pre-generated artifacts.
//! * `pjrt` (behind the non-default `xla` cargo feature) — compiles AOT HLO
//!   text artifacts produced by `python/compile/aot.py` on a PJRT client and
//!   executes them on device.
//!
//! The two backends implement the same protocol but are **not** bit-identical
//! sources of randomness: a `.mrc` file decodes correctly only on the backend
//! family that encoded it. See `docs/adr/001-backend-abstraction.md`.

pub mod kernels;
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::tensor::Arg;
use crate::util::{Error, Result};
use crate::{ensure, err};

/// Wildcard extent in a [`Spec`] dimension: matches any size at validation
/// time. The batched candidate entries (`score_block`, `score_blocks`,
/// `decode_block`) need it because their leading dimension depends on the
/// session's coding budget `C_loc` (number of chunks / blocks per call),
/// which a static per-model manifest cannot know.
pub const DYN: usize = usize::MAX;

/// Render a spec shape for error messages (`?` marks dynamic dims).
pub fn fmt_shape(shape: &[usize]) -> String {
    let dims: Vec<String> = shape
        .iter()
        .map(|&d| if d == DYN { "?".to_string() } else { d.to_string() })
        .collect();
    format!("[{}]", dims.join(", "))
}

/// Input/output spec of one entry point, from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Spec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl Spec {
    pub fn f32(shape: Vec<usize>) -> Spec {
        Spec { shape, dtype: "f32".to_string() }
    }

    pub fn i32(shape: Vec<usize>) -> Spec {
        Spec { shape, dtype: "i32".to_string() }
    }

    /// 1-D f32 tensor of any length.
    pub fn f32_dyn() -> Spec {
        Spec::f32(vec![DYN])
    }

    /// 1-D i32 tensor of any length.
    pub fn i32_dyn() -> Spec {
        Spec::i32(vec![DYN])
    }

    /// Does a concrete tensor shape satisfy this spec ([`DYN`] dims match
    /// any extent)?
    pub fn matches(&self, shape: &[usize]) -> bool {
        self.shape.len() == shape.len()
            && self
                .shape
                .iter()
                .zip(shape)
                .all(|(&spec_d, &d)| spec_d == DYN || spec_d == d)
    }
}

/// Manifest entries of the batched candidate surface — `score_block`,
/// `score_blocks`, `decode_block` — shared by the native spec builder and
/// the PJRT synthesis path so the two backends' manifests cannot drift.
pub(crate) fn batched_entry_specs(s: usize) -> [Entry; 3] {
    let si = || Spec::i32(vec![]);
    let srow = || Spec::f32(vec![s]);
    [
        // (seed, block, n_chunks, mu, rho, lsp, mask) -> all chunk logits
        // of one block, [n_chunks * k_chunk]
        Entry::new(
            "score_block",
            vec![si(), si(), si(), srow(), srow(), srow(), srow()],
            vec![Spec::f32_dyn()],
        ),
        // (seed, blocks, n_chunks, mu, rho, lsp, mask) with per-block rows
        // flattened to [n_blocks * S] -> [n_blocks * n_chunks * k_chunk]
        Entry::new(
            "score_blocks",
            vec![
                si(),
                Spec::i32_dyn(),
                si(),
                Spec::f32_dyn(),
                Spec::f32_dyn(),
                Spec::f32_dyn(),
                Spec::f32_dyn(),
            ],
            vec![Spec::f32_dyn()],
        ),
        // (seed, block, index, lsp) -> the single transmitted candidate row
        Entry::new(
            "decode_block",
            vec![si(), si(), si(), srow()],
            vec![srow()],
        ),
    ]
}

/// One manifest entry point: name + typed input/output specs, plus
/// invocation accounting.
pub struct Entry {
    pub name: String,
    pub inputs: Vec<Spec>,
    pub outputs: Vec<Spec>,
    pub invocations: RefCell<u64>,
    pub total_secs: RefCell<f64>,
}

impl Entry {
    pub fn new(name: &str, inputs: Vec<Spec>, outputs: Vec<Spec>) -> Entry {
        Entry {
            name: name.to_string(),
            inputs,
            outputs,
            invocations: RefCell::new(0),
            total_secs: RefCell::new(0.0),
        }
    }
}

/// Static facts about a model config, mirrored from its manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub b: usize,
    pub s: usize,
    pub k_chunk: usize,
    pub n_total: usize,
    pub n_slots: usize,
    pub n_layers: usize,
    pub layer_slots: Vec<usize>,
    pub layer_counts: Vec<usize>,
    pub batch: usize,
    pub eval_batch: usize,
    pub classes: usize,
    /// per-example input shape, derived from the eval_batch entry spec
    pub input_shape: Vec<usize>,
}

/// An uploaded tensor, resident wherever the backend computes. Obtained from
/// [`ModelArtifacts::upload`]; reusable across [`ModelArtifacts::invoke_mixed`]
/// calls to skip re-transfer of static data (layout maps, per-block
/// constants). The shared validation layer trusts these; the native backend
/// still re-checks shapes cheaply at execute time before indexing raw
/// slices.
pub enum DeviceBuf {
    /// Host-resident copy (the native backend computes in place).
    Host(Arg),
    /// PJRT device buffer.
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtBuffer),
}

/// Argument to [`ModelArtifacts::invoke_mixed`]: freshly-validated host data
/// or a cached device buffer (trusted — validated at upload sites).
#[derive(Clone, Copy)]
pub enum Input<'a> {
    Host(&'a Arg),
    Dev(&'a DeviceBuf),
}

/// An execution substrate for manifest entry points. Implementations only
/// execute; argument validation against the manifest happens once in
/// [`ModelArtifacts`], so every backend enforces identical shape/dtype rules.
pub trait Backend {
    /// Short identifier ("native", "pjrt") for logs and error messages.
    fn kind(&self) -> &'static str;

    /// Protocol family recorded in `.mrc` headers — compile-enforced so a
    /// new backend cannot forget to declare its candidate-stream identity.
    fn family(&self) -> crate::codec::BackendFamily;

    /// Transfer a host tensor to the backend's working residence.
    fn upload(&self, arg: &Arg) -> Result<DeviceBuf>;

    /// Execute `entry` with pre-validated inputs; returns host tensors.
    fn run(&self, entry: &Entry, ins: &[Input]) -> Result<Vec<Arg>>;
}

/// A loaded model: manifest metadata + entry specs + the backend executing
/// them. This is the only handle the coordinator, server, baselines, benches
/// and tests hold.
pub struct ModelArtifacts {
    pub meta: ModelMeta,
    entries: BTreeMap<String, Entry>,
    backend: Box<dyn Backend>,
}

impl ModelArtifacts {
    pub fn new(
        meta: ModelMeta,
        entries: BTreeMap<String, Entry>,
        backend: Box<dyn Backend>,
    ) -> ModelArtifacts {
        ModelArtifacts { meta, entries, backend }
    }

    /// Which backend executes this model ("native", "pjrt").
    pub fn backend_kind(&self) -> &'static str {
        self.backend.kind()
    }

    /// The backend's protocol family (for `.mrc` headers and validation).
    pub fn backend_family(&self) -> crate::codec::BackendFamily {
        self.backend.family()
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::msg(format!("no artifact entry '{name}'")))
    }

    /// Upload a host tensor once; reuse the returned buffer across calls.
    pub fn upload(&self, arg: &Arg) -> Result<DeviceBuf> {
        self.backend.upload(arg)
    }

    /// Execute with a mix of host args (validated now) and pre-uploaded
    /// buffers (trusted — validated at upload sites).
    pub fn invoke_mixed(&self, name: &str, ins: &[Input]) -> Result<Vec<Arg>> {
        let entry = self.entry(name)?;
        ensure!(
            ins.len() == entry.inputs.len(),
            "{name}: {} args given, {} expected",
            ins.len(),
            entry.inputs.len()
        );
        for (i, input) in ins.iter().enumerate() {
            if let Input::Host(a) = input {
                let spec = &entry.inputs[i];
                ensure!(
                    spec.matches(a.shape()) && a.dtype() == spec.dtype,
                    "{name}: arg {i} is {}{:?}, expected {}{}",
                    a.dtype(),
                    a.shape(),
                    spec.dtype,
                    fmt_shape(&spec.shape)
                );
            }
        }
        let t = crate::util::Timer::start();
        let outs = self.backend.run(entry, ins)?;
        *entry.invocations.borrow_mut() += 1;
        *entry.total_secs.borrow_mut() += t.secs();
        ensure!(
            outs.len() == entry.outputs.len(),
            "{name}: {} outputs, {} expected",
            outs.len(),
            entry.outputs.len()
        );
        Ok(outs)
    }

    /// Execute an entry with full shape/dtype validation of every argument.
    pub fn invoke(&self, name: &str, args: &[Arg]) -> Result<Vec<Arg>> {
        let ins: Vec<Input> = args.iter().map(Input::Host).collect();
        self.invoke_mixed(name, &ins)
    }

    /// (entry, invocations, total seconds) — perf accounting.
    pub fn invocation_stats(&self) -> Vec<(String, u64, f64)> {
        self.entries
            .values()
            .map(|e| {
                (
                    e.name.clone(),
                    *e.invocations.borrow(),
                    *e.total_secs.borrow(),
                )
            })
            .collect()
    }
}

/// Which backend family a [`Runtime`] hands out.
enum BackendKind {
    Native,
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtClient),
}

/// Backend selector. One per process; `cpu()` picks the native backend
/// unless `MIRACLE_BACKEND=xla` requests the PJRT path (which requires
/// building with `--features xla`).
pub struct Runtime {
    kind: BackendKind,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        // Resolve the kernel dispatch path up front so an invalid
        // MIRACLE_SIMD fails here, loudly, instead of silently running the
        // scalar fallback (same strictness as MIRACLE_BACKEND below).
        let _ = crate::util::simd::selected()?;
        match std::env::var("MIRACLE_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("native") => {
                Ok(Runtime { kind: BackendKind::Native })
            }
            Ok("xla") | Ok("pjrt") => Runtime::pjrt(),
            // reject typos loudly — a silent native fallback would let
            // e.g. MIRACLE_BACKEND=XLA benchmark the wrong backend
            Ok(other) => err!(
                "unknown MIRACLE_BACKEND '{other}' (expected 'native' or 'xla')"
            ),
        }
    }

    #[cfg(feature = "xla")]
    fn pjrt() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { kind: BackendKind::Pjrt(client) })
    }

    #[cfg(not(feature = "xla"))]
    fn pjrt() -> Result<Runtime> {
        err!(
            "MIRACLE_BACKEND=xla requested, but this binary was built \
             without the `xla` feature (cargo build --features xla)"
        )
    }
}

/// Locate the AOT artifacts root: $MIRACLE_ARTIFACTS or ./artifacts.
/// Only meaningful for the PJRT backend.
pub fn artifacts_root() -> PathBuf {
    std::env::var("MIRACLE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_matches_static_and_dynamic_dims() {
        let fixed = Spec::f32(vec![4, 8]);
        assert!(fixed.matches(&[4, 8]));
        assert!(!fixed.matches(&[4, 9]));
        assert!(!fixed.matches(&[4]));
        let dynamic = Spec::f32_dyn();
        assert!(dynamic.matches(&[1]));
        assert!(dynamic.matches(&[100_000]));
        assert!(!dynamic.matches(&[]));
        assert!(!dynamic.matches(&[1, 1]));
        let mixed = Spec { shape: vec![DYN, 8], dtype: "f32".to_string() };
        assert!(mixed.matches(&[3, 8]));
        assert!(!mixed.matches(&[3, 7]));
    }

    #[test]
    fn fmt_shape_marks_dynamic_dims() {
        assert_eq!(fmt_shape(&[2, DYN]), "[2, ?]");
        assert_eq!(fmt_shape(&[]), "[]");
    }
}

/// Load a model by config name on the runtime's backend.
pub fn load(rt: &Runtime, model: &str) -> Result<ModelArtifacts> {
    match &rt.kind {
        BackendKind::Native => match crate::model::arch::builtin(model) {
            Some(cfg) => native::NativeBackend::load(cfg),
            None => err!(
                "no built-in native config named '{model}' \
                 (see model::arch::builtin for the registry); the PJRT \
                 artifact path needs MIRACLE_BACKEND=xla + --features xla"
            ),
        },
        #[cfg(feature = "xla")]
        BackendKind::Pjrt(client) => {
            let dir = artifacts_root().join(model);
            if !dir.join("manifest.json").exists() {
                return err!(
                    "no artifacts for '{model}' at {dir:?} — run `make artifacts` first"
                );
            }
            pjrt::load_dir(client, &dir)
        }
    }
}
