//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! `make artifacts` (python, build-time) writes one directory per model
//! config containing `<entry>.hlo.txt` files plus `manifest.json`. This
//! module compiles every entry on the PJRT CPU client once and exposes a
//! typed `invoke` with shape/dtype validation against the manifest — the only
//! boundary between the rust hot path and XLA.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::tensor::Arg;
use crate::util::json::Json;
use crate::util::{Error, Result};
use crate::{ensure, err, info};

/// Input/output spec of one artifact entry, from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Spec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl Spec {
    fn from_json(j: &Json) -> Result<Spec> {
        Ok(Spec {
            shape: j.get("shape")?.usize_arr()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One compiled entry point.
pub struct Entry {
    pub name: String,
    pub inputs: Vec<Spec>,
    pub outputs: Vec<Spec>,
    exe: xla::PjRtLoadedExecutable,
    pub invocations: RefCell<u64>,
    pub total_secs: RefCell<f64>,
}

/// Static facts about a compiled model config, mirrored from the manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub b: usize,
    pub s: usize,
    pub k_chunk: usize,
    pub n_total: usize,
    pub n_slots: usize,
    pub n_layers: usize,
    pub layer_slots: Vec<usize>,
    pub layer_counts: Vec<usize>,
    pub batch: usize,
    pub eval_batch: usize,
    pub classes: usize,
    /// per-example input shape, derived from the eval_batch entry spec
    pub input_shape: Vec<usize>,
}

/// A loaded artifact directory: compiled executables + metadata.
pub struct ModelArtifacts {
    pub meta: ModelMeta,
    pub dir: PathBuf,
    entries: BTreeMap<String, Entry>,
    client: xla::PjRtClient,
}

/// The PJRT client wrapper. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    /// Load and compile every entry of `artifacts/<model>/`.
    pub fn load_model(&self, dir: &Path) -> Result<ModelArtifacts> {
        let manifest_path = dir.join("manifest.json");
        let manifest = Json::from_file(manifest_path.to_str().unwrap())
            .map_err(|e| e.context(format!("loading {manifest_path:?}")))?;
        let meta = Self::parse_meta(&manifest)?;
        let mut entries = BTreeMap::new();
        for (name, e) in manifest.get("entries")?.as_obj()? {
            let file = dir.join(e.get("file")?.as_str()?);
            let t = crate::util::Timer::start();
            let proto = xla::HloModuleProto::from_text_file(
                file.to_str()
                    .ok_or_else(|| Error::msg("non-utf8 artifact path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(Spec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(Spec::from_json)
                .collect::<Result<Vec<_>>>()?;
            info!("compiled {}/{name} in {:.2}s", meta.name, t.secs());
            entries.insert(
                name.clone(),
                Entry {
                    name: name.clone(),
                    inputs,
                    outputs,
                    exe,
                    invocations: RefCell::new(0),
                    total_secs: RefCell::new(0.0),
                },
            );
        }
        Ok(ModelArtifacts {
            meta,
            dir: dir.to_path_buf(),
            entries,
            client: self.client.clone(),
        })
    }

    fn parse_meta(m: &Json) -> Result<ModelMeta> {
        let eval_inputs = m
            .get("entries")?
            .get("eval_batch")?
            .get("inputs")?
            .as_arr()?;
        ensure!(eval_inputs.len() == 3, "eval_batch should have 3 inputs");
        let x_shape = Spec::from_json(&eval_inputs[2])?.shape;
        Ok(ModelMeta {
            name: m.get("config")?.as_str()?.to_string(),
            b: m.get("B")?.as_usize()?,
            s: m.get("S")?.as_usize()?,
            k_chunk: m.get("k_chunk")?.as_usize()?,
            n_total: m.get("n_total")?.as_usize()?,
            n_slots: m.get("n_slots")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            layer_slots: m.get("layer_slots")?.usize_arr()?,
            layer_counts: m.get("layer_counts")?.usize_arr()?,
            batch: m.get("batch")?.as_usize()?,
            eval_batch: m.get("eval_batch")?.as_usize()?,
            classes: m.get("classes")?.as_usize()?,
            input_shape: x_shape[1..].to_vec(),
        })
    }
}

/// Argument to `invoke_mixed`: freshly-uploaded host data or a cached
/// device buffer (static maps, per-block constants).
pub enum Input<'a> {
    Host(&'a Arg),
    Dev(&'a xla::PjRtBuffer),
}

impl ModelArtifacts {
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::msg(format!("no artifact entry '{name}'")))
    }

    /// Upload a host tensor once; reuse the returned buffer across calls.
    pub fn upload(&self, arg: &Arg) -> Result<xla::PjRtBuffer> {
        arg.to_buffer(&self.client, None)
    }

    /// Execute with a mix of host args (validated + uploaded now) and
    /// pre-uploaded device buffers (trusted — validated at upload sites).
    pub fn invoke_mixed(&self, name: &str, ins: &[Input]) -> Result<Vec<xla::Literal>> {
        let entry = self.entry(name)?;
        ensure!(
            ins.len() == entry.inputs.len(),
            "{name}: {} args given, {} expected",
            ins.len(),
            entry.inputs.len()
        );
        let t = crate::util::Timer::start();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(ins.len());
        for (i, input) in ins.iter().enumerate() {
            match input {
                Input::Host(a) => {
                    let spec = &entry.inputs[i];
                    ensure!(
                        a.shape() == &spec.shape[..] && a.dtype() == spec.dtype,
                        "{name}: arg {i} is {}{:?}, expected {}{:?}",
                        a.dtype(),
                        a.shape(),
                        spec.dtype,
                        spec.shape
                    );
                    owned.push(a.to_buffer(&self.client, None)?);
                }
                Input::Dev(_) => {}
            }
        }
        let mut oi = 0usize;
        for input in ins {
            match input {
                Input::Host(_) => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
                Input::Dev(b) => refs.push(b),
            }
        }
        let result = entry.exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        *entry.invocations.borrow_mut() += 1;
        *entry.total_secs.borrow_mut() += t.secs();
        ensure!(
            outs.len() == entry.outputs.len(),
            "{name}: {} outputs, {} expected",
            outs.len(),
            entry.outputs.len()
        );
        Ok(outs)
    }

    /// Execute an entry with shape/dtype validation; returns output literals.
    pub fn invoke(&self, name: &str, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        let entry = self.entry(name)?;
        ensure!(
            args.len() == entry.inputs.len(),
            "{name}: {} args given, {} expected",
            args.len(),
            entry.inputs.len()
        );
        for (i, (arg, spec)) in args.iter().zip(&entry.inputs).enumerate() {
            ensure!(
                arg.shape() == &spec.shape[..] && arg.dtype() == spec.dtype,
                "{name}: arg {i} is {}{:?}, expected {}{:?}",
                arg.dtype(),
                arg.shape(),
                spec.dtype,
                spec.shape
            );
        }
        // Explicit host->device transfer so every buffer is rust-owned and
        // freed by Drop (the C-side `execute(literals)` path leaks its
        // internal arg buffers — measured ~1.7 MB/step on train_step).
        let t = crate::util::Timer::start();
        let buffers: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|a| a.to_buffer(&self.client, None))
            .collect::<Result<Vec<_>>>()?;
        let result = entry.exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        *entry.invocations.borrow_mut() += 1;
        *entry.total_secs.borrow_mut() += t.secs();
        ensure!(
            outs.len() == entry.outputs.len(),
            "{name}: {} outputs, {} expected",
            outs.len(),
            entry.outputs.len()
        );
        Ok(outs)
    }

    /// (invocations, total seconds) per entry — perf accounting.
    pub fn invocation_stats(&self) -> Vec<(String, u64, f64)> {
        self.entries
            .values()
            .map(|e| {
                (
                    e.name.clone(),
                    *e.invocations.borrow(),
                    *e.total_secs.borrow(),
                )
            })
            .collect()
    }
}

/// Locate the artifacts root: $MIRACLE_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("MIRACLE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Convenience: load a model by config name from the artifacts root.
pub fn load(rt: &Runtime, model: &str) -> Result<ModelArtifacts> {
    let dir = artifacts_root().join(model);
    if !dir.join("manifest.json").exists() {
        return err!(
            "no artifacts for '{model}' at {dir:?} — run `make artifacts` first"
        );
    }
    rt.load_model(&dir)
}
