//! Dispatched candidate-scoring kernels — the fused sample+score inner
//! loop of the encode hot path, factored out of `runtime/native.rs` so the
//! scalar reference and the vector variants live side by side.
//!
//! The math (docs on [`score_consts`]): per candidate row of normals `z`,
//! the importance logit is
//! `Σ_j half_mask[j]·(z_j² − zq_j²) + base` with
//! `zq_j = (exp_lsp[j]·z_j − mu[j])·neg_exp_rho[j]`.
//!
//! [`score_rows_scalar`] is THE reference implementation: one f32 term per
//! coordinate accumulated sequentially into an f64. The AVX2/FMA and NEON
//! variants compute the same terms 8/4 lanes at a time, widen each lane
//! group to f64 and accumulate in two vector accumulators — fused
//! multiplies plus reassociated addition, so logits may drift a few ulps
//! from the reference. That drift only affects *fresh* encodes (candidate
//! selection); decode replays a transmitted index and never calls these
//! kernels, so `.mrc` bytes stay path-independent (contract + tolerance in
//! `docs/perf.md`, enforced by `rust/tests/simd_parity.rs`).
//!
//! Safety policy: `#[deny(unsafe_op_in_unsafe_fn)]`; vector arithmetic uses
//! safe `#[target_feature]` functions, so `unsafe` appears only at the
//! feature-gated dispatch call (CPU support proven by
//! [`crate::util::simd::detect`]) and around pointer loads, each with a
//! SAFETY comment.

#![deny(unsafe_op_in_unsafe_fn)]

use crate::util::simd::{self, SimdPath};

/// Per-block constants of the importance logit, hoisted out of the
/// K-candidate loop: `log q - log p` per coordinate is
/// `0.5 * mask * (z^2 - zq^2) + mask * (lsp - rho)` with
/// `zq = (exp(lsp) * z - mu) * exp(-rho)` (the `0.5 * log(2 pi)` terms
/// cancel; the masked `lsp - rho` part is candidate-independent and
/// pre-summed into `base`).
pub struct ScoreConsts {
    pub exp_lsp: Vec<f32>,
    pub neg_exp_rho: Vec<f32>,
    pub mu: Vec<f32>,
    pub half_mask: Vec<f32>,
    pub base: f64,
}

impl ScoreConsts {
    /// Block width S (coordinates per candidate row).
    pub fn s(&self) -> usize {
        self.mu.len()
    }
}

/// Hoist one block's scoring constants (see [`ScoreConsts`]).
pub fn score_consts(
    mu: &[f32],
    rho: &[f32],
    lsp: &[f32],
    mask: &[f32],
) -> ScoreConsts {
    let s = mu.len();
    let mut exp_lsp = Vec::with_capacity(s);
    let mut neg_exp_rho = Vec::with_capacity(s);
    let mut half_mask = Vec::with_capacity(s);
    let mut base = 0f64;
    for j in 0..s {
        exp_lsp.push(lsp[j].exp());
        neg_exp_rho.push((-rho[j]).exp());
        half_mask.push(0.5 * mask[j]);
        base += (mask[j] * (lsp[j] - rho[j])) as f64;
    }
    ScoreConsts {
        exp_lsp,
        neg_exp_rho,
        mu: mu.to_vec(),
        half_mask,
        base,
    }
}

/// Reference scoring: `zs` holds `out.len()` rows of S normals; one logit
/// per row. Every other variant is measured against this one.
pub fn score_rows_scalar(c: &ScoreConsts, zs: &[f32], out: &mut [f32]) {
    let s = c.s();
    debug_assert_eq!(zs.len(), out.len() * s);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &zs[r * s..(r + 1) * s];
        let mut acc = 0f64;
        for j in 0..s {
            let z = row[j];
            let zq = (c.exp_lsp[j] * z - c.mu[j]) * c.neg_exp_rho[j];
            acc += (c.half_mask[j] * (z * z - zq * zq)) as f64;
        }
        *o = (acc + c.base) as f32;
    }
}

/// Dispatched scoring on an explicit path (parity tests); production code
/// uses [`score_rows`].
pub fn score_rows_with(
    path: SimdPath,
    c: &ScoreConsts,
    zs: &[f32],
    out: &mut [f32],
) {
    match path {
        SimdPath::Scalar => score_rows_scalar(c, zs, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `SimdPath::Avx2` is only ever produced after
        // `is_x86_feature_detected!` confirmed AVX2+FMA (util/simd.rs), so
        // the target-feature call contract holds.
        SimdPath::Avx2 => unsafe { x86::score_rows_avx2(c, zs, out) },
        #[cfg(target_arch = "aarch64")]
        // NEON is baseline on aarch64 — statically enabled, safe call.
        SimdPath::Neon => neon::score_rows_neon(c, zs, out),
        // cross-arch variants that cannot occur here (parse/detect never
        // yield them on this target) fall back to the reference
        _ => score_rows_scalar(c, zs, out),
    }
}

/// [`score_rows_with`] on the process-wide dispatch path.
pub fn score_rows(c: &ScoreConsts, zs: &[f32], out: &mut [f32]) {
    score_rows_with(simd::active(), c, zs, out)
}

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

#[cfg(test)]
mod tests {
    use super::*;

    fn seeded_case(s: usize, k: usize) -> (ScoreConsts, Vec<f32>) {
        let mut rng = crate::prng::Pcg64::seed(0x5C0E);
        let draw = |rng: &mut crate::prng::Pcg64, lo: f32, hi: f32| {
            lo + (hi - lo) * rng.next_f32()
        };
        let mu: Vec<f32> = (0..s).map(|_| draw(&mut rng, -0.5, 0.5)).collect();
        let rho: Vec<f32> = (0..s).map(|_| draw(&mut rng, -2.0, -0.5)).collect();
        let lsp: Vec<f32> = (0..s).map(|_| draw(&mut rng, -1.5, -0.5)).collect();
        // realistic masks: mostly live, some padding zeros
        let mask: Vec<f32> =
            (0..s).map(|j| if j % 7 == 3 { 0.0 } else { 1.0 }).collect();
        let zs = crate::prng::normals_f32(&mut rng, k * s);
        (score_consts(&mu, &rho, &lsp, &mask), zs)
    }

    fn argmax(v: &[f32]) -> usize {
        let mut best = 0;
        for (i, &x) in v.iter().enumerate() {
            if x > v[best] {
                best = i;
            }
        }
        best
    }

    #[test]
    fn dispatched_matches_scalar_within_tolerance() {
        // odd S exercises every vector tail; tolerance per docs/perf.md
        for s in [1usize, 4, 7, 8, 9, 16, 31, 64] {
            let k = 33;
            let (c, zs) = seeded_case(s, k);
            let mut want = vec![0f32; k];
            score_rows_scalar(&c, &zs, &mut want);
            let mut got = vec![0f32; k];
            score_rows_with(simd::detect(), &c, &zs, &mut got);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                let tol = 1e-5 * (1.0 + a.abs());
                assert!((a - b).abs() <= tol, "s={s} row {i}: {a} vs {b}");
            }
            assert_eq!(argmax(&want), argmax(&got), "argmax flipped at s={s}");
        }
    }

    #[test]
    fn scalar_path_is_exact_on_q_equals_p() {
        // q == p (mu=0, rho=lsp, full mask): every logit must be exactly 0
        let s = 8;
        let mu = vec![0f32; s];
        let rho = vec![-0.5f32; s];
        let lsp = vec![-0.5f32; s];
        let mask = vec![1f32; s];
        let c = score_consts(&mu, &rho, &lsp, &mask);
        let mut rng = crate::prng::Pcg64::seed(1);
        let zs = crate::prng::normals_f32(&mut rng, 4 * s);
        let mut out = vec![1f32; 4];
        score_rows_scalar(&c, &zs, &mut out);
        for &l in &out {
            assert!(l.abs() < 1e-5, "logit {l}");
        }
    }
}
