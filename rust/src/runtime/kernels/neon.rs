//! NEON variant of the candidate-scoring kernel: 4 f32 terms per
//! iteration, widened to two 2-lane f64 accumulators. NEON is part of the
//! aarch64 baseline ISA, so these functions are statically feature-enabled
//! and safe to call; `unsafe` remains only on the pointer loads.

use core::arch::aarch64::*;

use super::ScoreConsts;

/// See [`super::score_rows_scalar`] for the definition being vectorized.
pub fn score_rows_neon(c: &ScoreConsts, zs: &[f32], out: &mut [f32]) {
    let s = c.s();
    debug_assert_eq!(zs.len(), out.len() * s);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &zs[r * s..(r + 1) * s];
        let mut acc0 = vdupq_n_f64(0.0);
        let mut acc1 = vdupq_n_f64(0.0);
        let mut j = 0usize;
        while j + 4 <= s {
            // SAFETY: `j + 4 <= s` bounds every 4-lane load within `row`
            // and the four length-S constant vectors.
            let (z, el, mu, ner, hm) = unsafe {
                (
                    vld1q_f32(row.as_ptr().add(j)),
                    vld1q_f32(c.exp_lsp.as_ptr().add(j)),
                    vld1q_f32(c.mu.as_ptr().add(j)),
                    vld1q_f32(c.neg_exp_rho.as_ptr().add(j)),
                    vld1q_f32(c.half_mask.as_ptr().add(j)),
                )
            };
            // zq = (exp_lsp·z − mu)·neg_exp_rho, via -mu + exp_lsp·z
            let zq = vmulq_f32(vfmaq_f32(vnegq_f32(mu), el, z), ner);
            // term = half_mask·(z² − zq²)
            let diff = vfmsq_f32(vmulq_f32(z, z), zq, zq);
            let term = vmulq_f32(hm, diff);
            acc0 = vaddq_f64(acc0, vcvt_f64_f32(vget_low_f32(term)));
            acc1 = vaddq_f64(acc1, vcvt_high_f64_f32(term));
            j += 4;
        }
        let mut acc = vaddvq_f64(acc0) + vaddvq_f64(acc1);
        while j < s {
            let z = row[j];
            let zq = (c.exp_lsp[j] * z - c.mu[j]) * c.neg_exp_rho[j];
            acc += (c.half_mask[j] * (z * z - zq * zq)) as f64;
            j += 1;
        }
        *o = (acc + c.base) as f32;
    }
}
