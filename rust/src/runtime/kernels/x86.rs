//! AVX2/FMA variant of the candidate-scoring kernel: 8 f32 terms per
//! iteration, widened to two 4-lane f64 accumulators (the f64 accumulation
//! of the scalar reference is preserved; only the per-term f32 arithmetic
//! is fused/reassociated — the documented ulp-drift source).

use core::arch::x86_64::*;

use super::ScoreConsts;

/// Horizontal sum of a 4-lane f64 accumulator.
#[target_feature(enable = "avx2,fma")]
#[inline]
fn hsum_pd(v: __m256d) -> f64 {
    let lo = _mm256_castpd256_pd128(v);
    let hi = _mm256_extractf128_pd::<1>(v);
    let sum2 = _mm_add_pd(lo, hi);
    let swapped = _mm_unpackhi_pd(sum2, sum2);
    _mm_cvtsd_f64(_mm_add_sd(sum2, swapped))
}

/// See [`super::score_rows_scalar`] for the definition being vectorized.
#[target_feature(enable = "avx2,fma")]
pub fn score_rows_avx2(c: &ScoreConsts, zs: &[f32], out: &mut [f32]) {
    let s = c.s();
    debug_assert_eq!(zs.len(), out.len() * s);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &zs[r * s..(r + 1) * s];
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut j = 0usize;
        while j + 8 <= s {
            // SAFETY: `j + 8 <= s` bounds every 8-lane load within `row`
            // and the four length-S constant vectors.
            let (z, el, mu, ner, hm) = unsafe {
                (
                    _mm256_loadu_ps(row.as_ptr().add(j)),
                    _mm256_loadu_ps(c.exp_lsp.as_ptr().add(j)),
                    _mm256_loadu_ps(c.mu.as_ptr().add(j)),
                    _mm256_loadu_ps(c.neg_exp_rho.as_ptr().add(j)),
                    _mm256_loadu_ps(c.half_mask.as_ptr().add(j)),
                )
            };
            // zq = (exp_lsp·z − mu)·neg_exp_rho
            let zq = _mm256_mul_ps(_mm256_fmsub_ps(el, z, mu), ner);
            // term = half_mask·(z² − zq²)
            let diff = _mm256_fmsub_ps(z, z, _mm256_mul_ps(zq, zq));
            let term = _mm256_mul_ps(hm, diff);
            acc_lo = _mm256_add_pd(
                acc_lo,
                _mm256_cvtps_pd(_mm256_castps256_ps128(term)),
            );
            acc_hi = _mm256_add_pd(
                acc_hi,
                _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(term)),
            );
            j += 8;
        }
        let mut acc = hsum_pd(_mm256_add_pd(acc_lo, acc_hi));
        while j < s {
            let z = row[j];
            let zq = (c.exp_lsp[j] * z - c.mu[j]) * c.neg_exp_rho[j];
            acc += (c.half_mask[j] * (z * z - zq * zq)) as f64;
            j += 1;
        }
        *o = (acc + c.base) as f32;
    }
}
