//! Pure-Rust reference backend: executes every manifest entry point on the
//! host, with no Python, no XLA and no pre-generated artifacts.
//!
//! The implementations mirror the jax graphs in `python/compile/model.py`
//! term by term (same objective, same closed-form KL gradients, same Adam
//! constants) so the coordinator's Algorithm 2 control flow is identical on
//! both backends. The differences are confined to randomness: protocol
//! randomness (candidate generation) and reparameterization noise come from
//! the seed-tree derivations in [`crate::prng`] instead of jax's threefry.
//! Encoder and decoder share [`crate::prng::candidate_stream`], so the
//! shared-randomness contract of Algorithm 1 holds by construction — but a
//! `.mrc` encoded natively does not decode on the PJRT backend (and vice
//! versa). See `docs/adr/001-backend-abstraction.md`.
//!
//! Hot-path layout (details and measurements in `docs/perf.md`):
//! * `score_block` / `score_blocks` score all candidate chunks of one/many
//!   blocks per invocation, fanning chunks across the scoped-thread pool
//!   ([`crate::util::pool`]). Per-coordinate constants (`exp_lsp`,
//!   `neg_exp_rho`, the masked `lsp - rho` base term) are hoisted once per
//!   block, normals are bulk-generated into per-worker scratch buffers
//!   (u64 draws through the SIMD-dispatched [`crate::prng::bulk`] kernel),
//!   scoring runs on the dispatched [`super::kernels`] variants, and chunk
//!   outputs land in disjoint slices — bit-identical at any thread count
//!   because each chunk's randomness is independently addressable in the
//!   seed tree. SIMD path selection is `MIRACLE_SIMD`/`--simd`
//!   ([`crate::util::simd`]); decode bytes are path-invariant by
//!   construction.
//! * `decode_block` decodes exactly the transmitted candidate row by
//!   skipping earlier draws transcendental-free
//!   ([`crate::prng::Pcg64::skip_normals`]) instead of materializing a
//!   whole `k_chunk x S` chunk.
//! * forward/backward matmuls run over a transposed-weight layout with
//!   column tiling ([`crate::tensor::linalg`]); `eval_batch`/`eval_full`
//!   additionally fan independent batch rows across the pool.
//!
//! Architecture support is dense MLPs only ([`crate::model::arch`]);
//! multi-dimensional inputs are treated as flattened feature vectors.

use std::collections::BTreeMap;

use crate::model::arch::{DenseLayer, NetCfg};
use crate::prng;
use crate::tensor::linalg;
use crate::tensor::{Arg, TensorF32};
use crate::util::{pool, Result};
use crate::{ensure, err};

use super::kernels::{self, score_consts};
use super::{Backend, DeviceBuf, Entry, Input, ModelArtifacts, ModelMeta, Spec};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Below this many multiply-accumulates an eval fan-out costs more in
/// thread spawns than it saves (tiny_mlp stays sequential, lenet-scale
/// batches parallelize).
const PARALLEL_EVAL_MIN_MACS: usize = 200_000;

/// The default execution backend: pure-Rust kernels over [`crate::tensor`].
pub struct NativeBackend {
    cfg: NetCfg,
}

impl NativeBackend {
    /// Build a loaded model from a built-in config (the native analogue of
    /// compiling an artifact directory).
    pub fn load(cfg: NetCfg) -> Result<ModelArtifacts> {
        cfg.validate()?;
        let meta = cfg.meta();
        let entries = entry_specs(&meta);
        Ok(ModelArtifacts::new(
            meta,
            entries,
            Box::new(NativeBackend { cfg }),
        ))
    }
}

/// The native manifest: same entry names and shapes the AOT path would load
/// from `manifest.json`, derived from the config. The batched candidate
/// entries use [`super::DYN`] dims where the extent depends on the session's
/// coding budget rather than the model.
fn entry_specs(meta: &ModelMeta) -> BTreeMap<String, Entry> {
    let bs = || Spec::f32(vec![meta.b, meta.s]);
    let lay = || Spec::f32(vec![meta.n_layers]);
    let srow = || Spec::f32(vec![meta.s]);
    let sf = || Spec::f32(vec![]);
    let si = || Spec::i32(vec![]);
    let mut x_shape = vec![meta.batch];
    x_shape.extend_from_slice(&meta.input_shape);
    let mut xe_shape = vec![meta.eval_batch];
    xe_shape.extend_from_slice(&meta.input_shape);

    let entries = [
        Entry::new(
            "train_step",
            vec![
                bs(),
                bs(),
                lay(),
                bs(),
                bs(),
                bs(),
                bs(),
                lay(),
                lay(),
                si(),
                Spec::f32(x_shape.clone()),
                Spec::i32(vec![meta.batch]),
                Spec::f32(vec![meta.b]),
                Spec::f32(vec![meta.b]),
                bs(),
                si(),
                Spec::i32(vec![meta.n_total]),
                Spec::i32(vec![meta.b, meta.s]),
                bs(),
                sf(),
                sf(),
                sf(),
            ],
            vec![
                bs(),
                bs(),
                lay(),
                bs(),
                bs(),
                bs(),
                bs(),
                lay(),
                lay(),
                sf(),
                sf(),
                sf(),
                Spec::f32(vec![meta.b]),
            ],
        ),
        Entry::new(
            "score_chunk",
            vec![si(), si(), si(), srow(), srow(), srow(), srow()],
            vec![Spec::f32(vec![meta.k_chunk])],
        ),
        Entry::new(
            "decode_chunk",
            vec![si(), si(), si(), srow()],
            vec![Spec::f32(vec![meta.k_chunk, meta.s])],
        ),
        Entry::new(
            "eval_batch",
            vec![
                bs(),
                Spec::i32(vec![meta.n_total]),
                Spec::f32(xe_shape.clone()),
            ],
            vec![Spec::f32(vec![meta.eval_batch, meta.classes])],
        ),
        Entry::new(
            "eval_full",
            vec![Spec::f32(vec![meta.n_total]), Spec::f32(xe_shape)],
            vec![Spec::f32(vec![meta.eval_batch, meta.classes])],
        ),
        Entry::new(
            "sample_weights",
            vec![bs(), bs(), Spec::f32(vec![meta.b]), bs(), si()],
            vec![bs()],
        ),
    ];
    let mut map: BTreeMap<String, Entry> = entries
        .into_iter()
        .map(|e| (e.name.clone(), e))
        .collect();
    // batched candidate surface, shared with the PJRT synthesis path
    for e in super::batched_entry_specs(meta.s) {
        map.insert(e.name.clone(), e);
    }
    map
}

/// Resolve every input to a host tensor (native buffers are host-resident).
fn collect<'a>(ins: &'a [Input<'a>]) -> Result<Vec<&'a Arg>> {
    ins.iter()
        .map(|input| match input {
            Input::Host(a) => Ok(*a),
            Input::Dev(buf) => match buf {
                DeviceBuf::Host(a) => Ok(a),
                #[cfg(feature = "xla")]
                DeviceBuf::Pjrt(_) => Err(crate::util::Error::msg(
                    "PJRT device buffer passed to the native backend",
                )),
            },
        })
        .collect()
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn family(&self) -> crate::codec::BackendFamily {
        crate::codec::BackendFamily::Native
    }

    fn upload(&self, arg: &Arg) -> Result<DeviceBuf> {
        Ok(DeviceBuf::Host(arg.clone()))
    }

    fn run(&self, entry: &Entry, ins: &[Input]) -> Result<Vec<Arg>> {
        let args = collect(ins)?;
        // The shared layer validates Host args only; Dev buffers are
        // trusted there, but the native kernels index raw slices, so a
        // wrong-shaped cached buffer would panic instead of erroring.
        // Re-check every resolved argument here (cheap vs the kernels).
        for (i, (a, spec)) in args.iter().zip(&entry.inputs).enumerate() {
            ensure!(
                spec.matches(a.shape()) && a.dtype() == spec.dtype,
                "{}: resolved arg {i} is {}{:?}, expected {}{}",
                entry.name,
                a.dtype(),
                a.shape(),
                spec.dtype,
                super::fmt_shape(&spec.shape)
            );
        }
        match entry.name.as_str() {
            "train_step" => self.train_step(&args),
            "score_chunk" => self.score_chunk(&args),
            "score_block" => self.score_block(&args),
            "score_blocks" => self.score_blocks(&args),
            "decode_chunk" => self.decode_chunk(&args),
            "decode_block" => self.decode_block(&args),
            "eval_batch" => self.eval_batch(&args),
            "eval_full" => self.eval_full(&args),
            "sample_weights" => self.sample_weights(&args),
            other => err!("native backend has no entry '{other}'"),
        }
    }
}

fn f32_arg(shape: Vec<usize>, data: Vec<f32>) -> Result<Arg> {
    Ok(Arg::F32(TensorF32::new(shape, data)?))
}

/// Fused sample + score of one chunk's candidates into `out` (one logit per
/// candidate). `scratch` holds the chunk's bulk-generated normals and is
/// reused across every chunk the same worker processes. The normals come
/// from the dispatched bulk generator (bit-identical on every SIMD path);
/// the logits from the dispatched score kernel
/// ([`kernels::score_rows`] — scalar-reference semantics, ulp-documented
/// vector variants).
fn score_chunk_into(
    rng: &mut prng::Pcg64,
    c: &kernels::ScoreConsts,
    scratch: &mut Vec<f32>,
    out: &mut [f32],
) {
    let need = out.len() * c.s();
    if scratch.len() < need {
        // grow once per worker; no per-chunk zeroing — fill_normals_f32
        // overwrites every slot
        scratch.resize(need, 0.0);
    }
    let scratch = &mut scratch[..need];
    rng.fill_normals_f32(scratch);
    kernels::score_rows(c, scratch, out);
}

impl NativeBackend {
    /// One Adam update of the beta-annealed objective (Eq. 3), mirroring
    /// `make_train_step`: reparameterized forward, softmax CE, closed-form
    /// block KL and its analytic gradients, frozen/padding masking.
    fn train_step(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let meta_b = self.cfg.b;
        let s = self.cfg.s;
        let n_pad = meta_b * s;
        let n_layers = self.cfg.layers.len();
        let n = self.cfg.batch;
        let classes = self.cfg.classes;

        let mu = a[0].f32s()?;
        let rho = a[1].f32s()?;
        let lsp = a[2].f32s()?;
        let m_mu = a[3].f32s()?;
        let v_mu = a[4].f32s()?;
        let m_rho = a[5].f32s()?;
        let v_rho = a[6].f32s()?;
        let m_lsp = a[7].f32s()?;
        let v_lsp = a[8].f32s()?;
        let step = a[9].i32s()?[0];
        let x = a[10].f32s()?;
        let y = a[11].i32s()?;
        let beta = a[12].f32s()?;
        let fm = a[13].f32s()?;
        let fw = a[14].f32s()?;
        let seed = a[15].i32s()?[0];
        let amap = a[16].i32s()?;
        let lmap = a[17].i32s()?;
        let smask = a[18].f32s()?;
        let data_scale = a[19].f32s()?[0];
        let lsp_train = a[20].f32s()?[0];
        let lr = a[21].f32s()?[0];

        // eps ~ N(0, I) over [B, S] — the PRNGKey(seed) analogue, shared
        // with sample_weights.
        let mut eps_rng = prng::eps_stream(seed);
        let eps = prng::normals_f32(&mut eps_rng, n_pad);

        // per-block KL(q||p) and effective (pinned + masked) parameters
        let mut kl_b = vec![0f32; meta_b];
        let mut eps_eff = vec![0f32; n_pad];
        let mut w_blocks = vec![0f32; n_pad];
        let exp_rho: Vec<f32> = rho.iter().map(|r| r.exp()).collect();
        for idx in 0..n_pad {
            let blk = idx / s;
            let lsp_e = lsp[lmap[idx] as usize];
            let var_ratio = (2.0 * (rho[idx] - lsp_e)).exp();
            let mu_term = {
                let t = mu[idx] * (-lsp_e).exp();
                t * t
            };
            let elem = lsp_e - rho[idx] + 0.5 * (var_ratio + mu_term) - 0.5;
            kl_b[blk] += smask[idx] * elem;
            let fmb = fm[blk];
            let mu_eff = fmb * fw[idx] + (1.0 - fmb) * mu[idx];
            eps_eff[idx] = (1.0 - fmb) * eps[idx] * smask[idx];
            w_blocks[idx] = mu_eff + exp_rho[idx] * eps_eff[idx];
        }

        // assemble flat weights, forward, CE + accuracy
        let w_full: Vec<f32> = amap
            .iter()
            .map(|&p| w_blocks[p as usize])
            .collect();
        let acts = forward(&self.cfg.layers, &w_full, x, n);
        let logits = acts.last().expect("forward returns >=1 activation");
        let (ce, acc, dlogits) = softmax_ce(logits, y, n, classes, data_scale);

        // backprop to flat weights, scatter to block layout
        let dw = backward(&self.cfg.layers, &w_full, x, &acts, dlogits, n);
        let mut g_mu = vec![0f32; n_pad];
        let mut g_rho = vec![0f32; n_pad];
        for (pos, &bpos) in amap.iter().enumerate() {
            let bpos = bpos as usize;
            let g = dw[pos];
            g_mu[bpos] += g * (1.0 - fm[bpos / s]);
            g_rho[bpos] += g * exp_rho[bpos] * eps_eff[bpos];
        }

        // analytic KL gradients (cotangent beta_b * (1 - fm_b) per block)
        let mut g_lsp = vec![0f32; n_layers];
        for idx in 0..n_pad {
            let blk = idx / s;
            let gb = beta[blk] * (1.0 - fm[blk]);
            if gb == 0.0 {
                continue;
            }
            let li = lmap[idx] as usize;
            let lsp_e = lsp[li];
            let inv_vp = (-2.0 * lsp_e).exp();
            let var_ratio = (2.0 * (rho[idx] - lsp_e)).exp();
            let mask = smask[idx];
            g_mu[idx] += mask * mu[idx] * inv_vp * gb;
            g_rho[idx] += mask * (var_ratio - 1.0) * gb;
            g_lsp[li] +=
                mask * (1.0 - var_ratio - mu[idx] * mu[idx] * inv_vp) * gb;
        }

        // masked Adam update (bias-corrected, jax constants)
        let t = step as f32;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        // frozen blocks and padding must not move
        let mut live = vec![0f32; n_pad];
        for i in 0..n_pad {
            live[i] = (1.0 - fm[i / s]) * smask[i];
            g_mu[i] *= live[i];
            g_rho[i] *= live[i];
        }
        for g in g_lsp.iter_mut() {
            *g *= lsp_train;
        }
        let lsp_live = vec![lsp_train; n_layers];
        let (mu2, m_mu2, v_mu2) =
            adam(mu, &g_mu, m_mu, v_mu, &live, lr, bc1, bc2);
        let (rho2, m_rho2, v_rho2) =
            adam(rho, &g_rho, m_rho, v_rho, &live, lr, bc1, bc2);
        let (lsp2, m_lsp2, v_lsp2) =
            adam(lsp, &g_lsp, m_lsp, v_lsp, &lsp_live, lr, bc1, bc2);

        let kl_pen: f64 = kl_b
            .iter()
            .enumerate()
            .map(|(b, &k)| (beta[b] * (1.0 - fm[b]) * k) as f64)
            .sum();
        let loss = (data_scale as f64 * ce as f64 + kl_pen) as f32;

        let bs = vec![meta_b, s];
        let lshape = vec![n_layers];
        Ok(vec![
            f32_arg(bs.clone(), mu2)?,
            f32_arg(bs.clone(), rho2)?,
            f32_arg(lshape.clone(), lsp2)?,
            f32_arg(bs.clone(), m_mu2)?,
            f32_arg(bs.clone(), v_mu2)?,
            f32_arg(bs.clone(), m_rho2)?,
            f32_arg(bs.clone(), v_rho2)?,
            f32_arg(lshape.clone(), m_lsp2)?,
            f32_arg(lshape, v_lsp2)?,
            Arg::F32(TensorF32::scalar(loss)),
            Arg::F32(TensorF32::scalar(ce)),
            Arg::F32(TensorF32::scalar(acc)),
            f32_arg(vec![meta_b], kl_b)?,
        ])
    }

    /// Importance logits `log q(w_k) - log p(w_k)` for one candidate chunk
    /// (Algorithm 1 line 4) — kept for PJRT-manifest parity; the encoder
    /// calls the batched `score_block` instead.
    fn score_chunk(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let seed = a[0].i32s()?[0];
        let block = a[1].i32s()?[0];
        let chunk = a[2].i32s()?[0];
        let consts =
            score_consts(a[3].f32s()?, a[4].f32s()?, a[5].f32s()?, a[6].f32s()?);
        let k_chunk = self.cfg.k_chunk;
        let mut out = vec![0f32; k_chunk];
        let mut rng = prng::candidate_stream(seed, block, chunk);
        // per-thread scratch, sized once — repeated score_chunk calls (the
        // chunked PJRT-parity path) must not reallocate the normals buffer
        // on every invocation
        thread_local! {
            static SCRATCH: std::cell::RefCell<Vec<f32>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        SCRATCH.with(|scr| {
            score_chunk_into(&mut rng, &consts, &mut scr.borrow_mut(), &mut out)
        });
        Ok(vec![f32_arg(vec![k_chunk], out)?])
    }

    /// All chunk logits of one block in a single invocation, chunks fanned
    /// across the worker pool — the encode hot-spot.
    fn score_block(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let seed = a[0].i32s()?[0];
        let block = a[1].i32s()?[0];
        let n_chunks = a[2].i32s()?[0];
        ensure!(
            n_chunks > 0,
            "score_block: n_chunks must be positive, got {n_chunks}"
        );
        let n_chunks = n_chunks as usize;
        let consts =
            score_consts(a[3].f32s()?, a[4].f32s()?, a[5].f32s()?, a[6].f32s()?);
        let k_chunk = self.cfg.k_chunk;
        let mut out = vec![0f32; n_chunks * k_chunk];
        pool::parallel_runs_mut(&mut out, k_chunk, |first_chunk, span| {
            // sized once per worker, reused across all its chunks
            let mut scratch = vec![0f32; k_chunk * consts.s()];
            for (i, chunk_out) in span.chunks_mut(k_chunk).enumerate() {
                let mut rng = prng::candidate_stream(
                    seed,
                    block,
                    (first_chunk + i) as i32,
                );
                score_chunk_into(&mut rng, &consts, &mut scratch, chunk_out);
            }
        })?;
        Ok(vec![f32_arg(vec![n_chunks * k_chunk], out)?])
    }

    /// All chunk logits of *several* blocks in one invocation — the
    /// session-level encode fan-out. The task list is (block, chunk) pairs;
    /// output order is block-major then chunk-major, so the caller's
    /// per-block reduction order is independent of the thread count.
    fn score_blocks(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let seed = a[0].i32s()?[0];
        let blocks = a[1].i32s()?;
        let n_chunks = a[2].i32s()?[0];
        ensure!(
            n_chunks > 0,
            "score_blocks: n_chunks must be positive, got {n_chunks}"
        );
        let n_chunks = n_chunks as usize;
        let nb = blocks.len();
        ensure!(nb > 0, "score_blocks: empty block list");
        let s = self.cfg.s;
        let mu = a[3].f32s()?;
        let rho = a[4].f32s()?;
        let lsp = a[5].f32s()?;
        let mask = a[6].f32s()?;
        for (name, v) in [("mu", mu), ("rho", rho), ("lsp", lsp), ("mask", mask)]
        {
            ensure!(
                v.len() == nb * s,
                "score_blocks: {name} has {} values, expected {nb} blocks x S={s}",
                v.len()
            );
        }
        let consts: Vec<kernels::ScoreConsts> = (0..nb)
            .map(|i| {
                let r = i * s..(i + 1) * s;
                score_consts(&mu[r.clone()], &rho[r.clone()], &lsp[r.clone()], &mask[r])
            })
            .collect();
        let k_chunk = self.cfg.k_chunk;
        let mut out = vec![0f32; nb * n_chunks * k_chunk];
        pool::parallel_runs_mut(&mut out, k_chunk, |first, span| {
            // sized once per worker, reused across all its chunks
            let mut scratch = vec![0f32; k_chunk * s];
            for (i, chunk_out) in span.chunks_mut(k_chunk).enumerate() {
                let g = first + i;
                let (bi, ch) = (g / n_chunks, g % n_chunks);
                let mut rng =
                    prng::candidate_stream(seed, blocks[bi], ch as i32);
                score_chunk_into(&mut rng, &consts[bi], &mut scratch, chunk_out);
            }
        })?;
        Ok(vec![f32_arg(vec![nb * n_chunks * k_chunk], out)?])
    }

    /// Candidate weights `sigma_p * z` for one chunk — the decoder replays
    /// the exact generator the encoder scored (shared randomness).
    fn decode_chunk(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let seed = a[0].i32s()?[0];
        let block = a[1].i32s()?[0];
        let chunk = a[2].i32s()?[0];
        let lsp_b = a[3].f32s()?;
        let s = self.cfg.s;
        let k_chunk = self.cfg.k_chunk;
        let exp_lsp: Vec<f32> = lsp_b.iter().map(|l| l.exp()).collect();
        let mut out = vec![0f32; k_chunk * s];
        let mut rng = prng::candidate_stream(seed, block, chunk);
        rng.fill_normals_f32(&mut out);
        for r in 0..k_chunk {
            let row = &mut out[r * s..(r + 1) * s];
            for (j, v) in row.iter_mut().enumerate() {
                *v *= exp_lsp[j];
            }
        }
        Ok(vec![f32_arg(vec![k_chunk, s], out)?])
    }

    /// The single transmitted candidate row of a block: replay the
    /// containing chunk's stream, skipping the `row * S` earlier draws
    /// without computing them. Bit-identical to `decode_chunk` + row
    /// selection, at a fraction of the work and with no `k_chunk x S`
    /// allocation — the decode/serving hot path.
    fn decode_block(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let seed = a[0].i32s()?[0];
        let block = a[1].i32s()?[0];
        let index = a[2].i32s()?[0];
        ensure!(
            index >= 0,
            "decode_block: negative candidate index {index}"
        );
        let lsp_b = a[3].f32s()?;
        let s = self.cfg.s;
        let (chunk, row) =
            crate::codec::chunk_and_row(index as u64, self.cfg.k_chunk);
        let mut rng = prng::candidate_stream(seed, block, chunk as i32);
        rng.skip_normals(row * s);
        let mut out = vec![0f32; s];
        rng.fill_normals_f32(&mut out);
        for (j, v) in out.iter_mut().enumerate() {
            *v *= lsp_b[j].exp();
        }
        Ok(vec![f32_arg(vec![s], out)?])
    }

    /// Logits from explicit block-layout weights (the serving path).
    fn eval_batch(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let w_blocks = a[0].f32s()?;
        let amap = a[1].i32s()?;
        let x = a[2].f32s()?;
        let w_full: Vec<f32> = amap
            .iter()
            .map(|&p| w_blocks[p as usize])
            .collect();
        self.logits_out(&w_full, x)
    }

    /// Logits from a raw flat weight vector (baseline path).
    fn eval_full(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let w_full = a[0].f32s()?;
        let x = a[1].f32s()?;
        self.logits_out(w_full, x)
    }

    fn logits_out(&self, w_full: &[f32], x: &[f32]) -> Result<Vec<Arg>> {
        let n = self.cfg.eval_batch;
        let classes = self.cfg.classes;
        let feat = self.cfg.feature_dim();
        // validated up front with a clear message: a malformed batch must
        // not reach the raw-slice indexing inside the forward pass
        ensure!(
            x.len() == n * feat,
            "eval: input batch has {} values, expected eval_batch {n} x \
             feature_dim {feat} = {}",
            x.len(),
            n * feat
        );
        ensure!(
            w_full.len() == self.cfg.n_total(),
            "eval: weight vector has {} values, expected {}",
            w_full.len(),
            self.cfg.n_total()
        );
        let macs: usize =
            self.cfg.layers.iter().map(|l| l.fan_in * l.fan_out).sum();
        let layers = &self.cfg.layers;
        // transpose every layer once; all row tiles share the packed form
        let packed = pack_weights(layers, w_full);
        let mut logits = vec![0f32; n * classes];
        // each worker runs the full layer stack for its contiguous row
        // range; rows are independent, so the result is bit-identical to
        // the sequential pass at every thread count
        let tile = |first_row: usize, span: &mut [f32]| {
            let rows = span.len() / classes;
            let acts = forward_packed(
                layers,
                &packed,
                w_full,
                &x[first_row * feat..(first_row + rows) * feat],
                rows,
            );
            let last = acts.last().expect("forward returns >=1 activation");
            span.copy_from_slice(last);
        };
        if n * macs >= PARALLEL_EVAL_MIN_MACS && pool::current_threads() > 1 {
            pool::parallel_runs_mut(&mut logits, classes, tile)?;
        } else {
            tile(0, &mut logits);
        }
        f32_arg(vec![n, classes], logits).map(|a| vec![a])
    }

    /// One block-layout weight draw from q, frozen blocks pinned.
    fn sample_weights(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let mu = a[0].f32s()?;
        let rho = a[1].f32s()?;
        let fm = a[2].f32s()?;
        let fw = a[3].f32s()?;
        let seed = a[4].i32s()?[0];
        let s = self.cfg.s;
        let n_pad = self.cfg.b * s;
        let mut rng = prng::eps_stream(seed);
        let eps = prng::normals_f32(&mut rng, n_pad);
        let mut out = Vec::with_capacity(n_pad);
        for idx in 0..n_pad {
            let fmb = fm[idx / s];
            let sampled = mu[idx] + rho[idx].exp() * eps[idx];
            out.push(fmb * fw[idx] + (1.0 - fmb) * sampled);
        }
        f32_arg(vec![self.cfg.b, s], out).map(|a| vec![a])
    }
}

/// One bias-corrected Adam update with a per-parameter update mask (frozen
/// blocks / padding / lsp_train gating); returns (p', m', v').
#[allow(clippy::too_many_arguments)]
fn adam(
    p: &[f32],
    g: &[f32],
    m: &[f32],
    v: &[f32],
    mask: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut p2 = Vec::with_capacity(p.len());
    let mut m2v = Vec::with_capacity(p.len());
    let mut v2v = Vec::with_capacity(p.len());
    for i in 0..p.len() {
        let m2 = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
        let v2 = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
        let upd = lr * (m2 / bc1) / ((v2 / bc2).sqrt() + ADAM_EPS);
        p2.push(p[i] - upd * mask[i]);
        m2v.push(m2);
        v2v.push(v2);
    }
    (p2, m2v, v2v)
}

/// Transposed (`[fan_out, fan_in]`) copy of every layer's weights — built
/// once per weight set so repeated/parallel forward passes share it.
fn pack_weights(layers: &[DenseLayer], w: &[f32]) -> Vec<Vec<f32>> {
    layers
        .iter()
        .map(|l| {
            let mut wt = Vec::new();
            linalg::transpose_into(
                &w[l.offset..l.offset + l.fan_in * l.fan_out],
                l.fan_in,
                l.fan_out,
                &mut wt,
            );
            wt
        })
        .collect()
}

/// Forward pass: returns one activation vector per layer (`acts[i]` is the
/// output of layer `i`, ReLU applied to all but the last; `acts.last()` is
/// the logits). Convenience wrapper packing the weights itself; callers
/// that run several passes over one weight set (parallel eval tiles) pack
/// once and use [`forward_packed`].
fn forward(
    layers: &[DenseLayer],
    w: &[f32],
    x: &[f32],
    n: usize,
) -> Vec<Vec<f32>> {
    let packed = pack_weights(layers, w);
    forward_packed(layers, &packed, w, x, n)
}

/// [`forward`] over pre-transposed weights: the batched product runs as
/// contiguous dot products with column tiling
/// ([`crate::tensor::linalg::matmul_bias_wt`]); the input batch is read in
/// place, never copied (`w` is still needed for the bias rows).
fn forward_packed(
    layers: &[DenseLayer],
    packed: &[Vec<f32>],
    w: &[f32],
    x: &[f32],
    n: usize,
) -> Vec<Vec<f32>> {
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(layers.len());
    for (li, l) in layers.iter().enumerate() {
        let (fi, fo) = (l.fan_in, l.fan_out);
        let bias = &w[l.bias_offset()..l.bias_offset() + fo];
        let mut out = vec![0f32; n * fo];
        {
            let input: &[f32] = if li == 0 { x } else { &acts[li - 1] };
            linalg::matmul_bias_wt(input, &packed[li], bias, &mut out, n, fi, fo);
        }
        if li + 1 != layers.len() {
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        acts.push(out);
    }
    acts
}

/// Backprop of `dlogits` through the MLP (`acts` as returned by
/// [`forward`], `x` the same input batch); returns the flat weight
/// gradient. ReLU masks use the post-activations (`act > 0 ⟺ pre > 0`);
/// the input-gradient pass runs as contiguous dot products against the
/// row-major weight rows.
fn backward(
    layers: &[DenseLayer],
    w: &[f32],
    x: &[f32],
    acts: &[Vec<f32>],
    dlogits: Vec<f32>,
    n: usize,
) -> Vec<f32> {
    let mut dw = vec![0f32; w.len()];
    let mut d = dlogits;
    for li in (0..layers.len()).rev() {
        let l = &layers[li];
        let (fi, fo) = (l.fan_in, l.fan_out);
        let h_in: &[f32] = if li == 0 { x } else { &acts[li - 1] };
        for r in 0..n {
            let drow = &d[r * fo..(r + 1) * fo];
            let hrow = &h_in[r * fi..(r + 1) * fi];
            for (i, &hv) in hrow.iter().enumerate() {
                if hv != 0.0 {
                    let dwrow =
                        &mut dw[l.offset + i * fo..l.offset + (i + 1) * fo];
                    for j in 0..fo {
                        dwrow[j] += hv * drow[j];
                    }
                }
            }
            let dbias = &mut dw[l.bias_offset()..l.bias_offset() + fo];
            for j in 0..fo {
                dbias[j] += drow[j];
            }
        }
        if li > 0 {
            let mut dprev = vec![0f32; n * fi];
            for r in 0..n {
                let drow = &d[r * fo..(r + 1) * fo];
                let hrow = &h_in[r * fi..(r + 1) * fi];
                let prow = &mut dprev[r * fi..(r + 1) * fi];
                for i in 0..fi {
                    // ReLU gate on the *input* activation of this layer
                    if hrow[i] > 0.0 {
                        let wrow =
                            &w[l.offset + i * fo..l.offset + (i + 1) * fo];
                        prow[i] = linalg::dot(drow, wrow);
                    }
                }
            }
            d = dprev;
        }
    }
    dw
}

/// Stable softmax cross-entropy + accuracy; `dlogits` includes the
/// `data_scale / batch` factor so it is the cotangent of the scaled loss.
fn softmax_ce(
    logits: &[f32],
    y: &[i32],
    n: usize,
    classes: usize,
    data_scale: f32,
) -> (f32, f32, Vec<f32>) {
    let mut ce_sum = 0f64;
    let mut correct = 0usize;
    let mut dlogits = vec![0f32; n * classes];
    let scale = data_scale / n as f32;
    for r in 0..n {
        let row = &logits[r * classes..(r + 1) * classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&v| ((v - max) as f64).exp()).sum();
        let lse = max as f64 + sum.ln();
        let yi = y[r] as usize;
        ce_sum += lse - row[yi] as f64;
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
            let p = (((v as f64) - lse).exp()) as f32;
            dlogits[r * classes + j] = p * scale;
        }
        dlogits[r * classes + yi] -= scale;
        if best == yi {
            correct += 1;
        }
    }
    let ce = (ce_sum / n as f64) as f32;
    let acc = correct as f32 / n as f32;
    (ce, acc, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::arch::builtin;
    use crate::tensor::TensorI32;

    fn tiny() -> ModelArtifacts {
        NativeBackend::load(builtin("tiny_mlp").unwrap()).unwrap()
    }

    fn scalar(v: i32) -> Arg {
        Arg::I32(TensorI32::scalar(v))
    }

    fn row(arts: &ModelArtifacts, v: f32) -> Arg {
        let s = arts.meta.s;
        Arg::F32(TensorF32::new(vec![s], vec![v; s]).unwrap())
    }

    #[test]
    fn manifest_has_all_entries() {
        let arts = tiny();
        for name in [
            "train_step",
            "score_chunk",
            "score_block",
            "score_blocks",
            "decode_chunk",
            "decode_block",
            "eval_batch",
            "eval_full",
            "sample_weights",
        ] {
            let e = arts.entry(name).unwrap();
            assert!(!e.inputs.is_empty());
            assert!(!e.outputs.is_empty());
        }
        assert_eq!(arts.backend_kind(), "native");
    }

    #[test]
    fn decode_chunk_is_deterministic_and_seed_sensitive() {
        let arts = tiny();
        let s = arts.meta.s;
        let lsp = Arg::F32(TensorF32::new(vec![s], vec![-1.0; s]).unwrap());
        let run = |seed: i32| {
            arts.invoke(
                "decode_chunk",
                &[scalar(seed), scalar(3), scalar(1), lsp.clone()],
            )
            .unwrap()[0]
                .f32s()
                .unwrap()
                .to_vec()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn score_matches_decode_candidates() {
        // score_chunk's logits must be computed on exactly the candidates
        // decode_chunk returns (shared randomness within the backend)
        let arts = tiny();
        let s = arts.meta.s;
        let lsp = row(&arts, -0.5);
        let outs = arts
            .invoke(
                "decode_chunk",
                &[scalar(5), scalar(0), scalar(0), lsp.clone()],
            )
            .unwrap();
        let cands = outs[0].as_f32().unwrap().clone();
        let outs = arts
            .invoke(
                "score_chunk",
                &[
                    scalar(5),
                    scalar(0),
                    scalar(0),
                    row(&arts, 0.0),
                    row(&arts, -0.5),
                    lsp,
                    row(&arts, 1.0),
                ],
            )
            .unwrap();
        let logits = outs[0].f32s().unwrap().to_vec();
        // with q == p (mu=0, rho=lsp), every importance logit is exactly 0
        assert_eq!(cands.shape, vec![arts.meta.k_chunk, s]);
        for &l in &logits {
            assert!(l.abs() < 1e-5, "logit {l}");
        }
    }

    #[test]
    fn score_block_matches_chunked_scoring() {
        let arts = tiny();
        let n_chunks = 4usize;
        let args_tail = [
            row(&arts, 0.1),
            row(&arts, -0.7),
            row(&arts, -0.5),
            row(&arts, 1.0),
        ];
        let outs = arts
            .invoke(
                "score_block",
                &[
                    scalar(9),
                    scalar(2),
                    scalar(n_chunks as i32),
                    args_tail[0].clone(),
                    args_tail[1].clone(),
                    args_tail[2].clone(),
                    args_tail[3].clone(),
                ],
            )
            .unwrap();
        let batched = outs[0].f32s().unwrap().to_vec();
        assert_eq!(batched.len(), n_chunks * arts.meta.k_chunk);
        let mut chunked = Vec::new();
        for c in 0..n_chunks {
            let outs = arts
                .invoke(
                    "score_chunk",
                    &[
                        scalar(9),
                        scalar(2),
                        scalar(c as i32),
                        args_tail[0].clone(),
                        args_tail[1].clone(),
                        args_tail[2].clone(),
                        args_tail[3].clone(),
                    ],
                )
                .unwrap();
            chunked.extend_from_slice(outs[0].f32s().unwrap());
        }
        assert_eq!(batched, chunked);
    }

    #[test]
    fn score_blocks_matches_per_block_scoring() {
        let arts = tiny();
        let s = arts.meta.s;
        let n_chunks = 3usize;
        let blocks = [1i32, 4, 0];
        let per_block: Vec<[Arg; 4]> = blocks
            .iter()
            .map(|&b| {
                let base = 0.01 * b as f32;
                [
                    row(&arts, base),
                    row(&arts, -0.6 - base),
                    row(&arts, -0.4),
                    row(&arts, 1.0),
                ]
            })
            .collect();
        let cat = |k: usize| -> Arg {
            let mut v = Vec::with_capacity(blocks.len() * s);
            for args in &per_block {
                v.extend_from_slice(args[k].f32s().unwrap());
            }
            Arg::F32(TensorF32::new(vec![blocks.len() * s], v).unwrap())
        };
        let outs = arts
            .invoke(
                "score_blocks",
                &[
                    scalar(11),
                    Arg::I32(
                        TensorI32::new(vec![blocks.len()], blocks.to_vec())
                            .unwrap(),
                    ),
                    scalar(n_chunks as i32),
                    cat(0),
                    cat(1),
                    cat(2),
                    cat(3),
                ],
            )
            .unwrap();
        let batched = outs[0].f32s().unwrap().to_vec();
        let per = n_chunks * arts.meta.k_chunk;
        assert_eq!(batched.len(), blocks.len() * per);
        for (bi, (&b, args)) in blocks.iter().zip(&per_block).enumerate() {
            let outs = arts
                .invoke(
                    "score_block",
                    &[
                        scalar(11),
                        scalar(b),
                        scalar(n_chunks as i32),
                        args[0].clone(),
                        args[1].clone(),
                        args[2].clone(),
                        args[3].clone(),
                    ],
                )
                .unwrap();
            assert_eq!(
                &batched[bi * per..(bi + 1) * per],
                outs[0].f32s().unwrap(),
                "block {b}"
            );
        }
    }

    #[test]
    fn decode_block_matches_decode_chunk_row() {
        let arts = tiny();
        let s = arts.meta.s;
        let k_chunk = arts.meta.k_chunk;
        let lsp = row(&arts, -1.25);
        for index in [0usize, 1, k_chunk - 1, k_chunk, 3 * k_chunk + 17] {
            let (chunk, r) = crate::codec::chunk_and_row(index as u64, k_chunk);
            let outs = arts
                .invoke(
                    "decode_chunk",
                    &[scalar(7), scalar(3), scalar(chunk as i32), lsp.clone()],
                )
                .unwrap();
            let want = outs[0].as_f32().unwrap().row(r).to_vec();
            let outs = arts
                .invoke(
                    "decode_block",
                    &[scalar(7), scalar(3), scalar(index as i32), lsp.clone()],
                )
                .unwrap();
            let got = outs[0].f32s().unwrap().to_vec();
            assert_eq!(got.len(), s);
            assert_eq!(got, want, "index {index}");
        }
    }

    #[test]
    fn batched_entries_are_thread_count_invariant() {
        let arts = tiny();
        let invoke = || {
            arts.invoke(
                "score_block",
                &[
                    scalar(3),
                    scalar(1),
                    scalar(8),
                    row(&arts, 0.2),
                    row(&arts, -1.0),
                    row(&arts, -0.5),
                    row(&arts, 1.0),
                ],
            )
            .unwrap()[0]
                .f32s()
                .unwrap()
                .to_vec()
        };
        let base = pool::with_threads(1, invoke);
        for threads in [2usize, 8] {
            assert_eq!(pool::with_threads(threads, invoke), base, "{threads}");
        }
    }

    #[test]
    fn eval_rejects_malformed_input_with_clear_error() {
        // the manifest validation layer rejects this before the kernel; the
        // additional ensure! in logits_out is defense-in-depth for the only
        // remaining route (a manifest whose eval spec drifted from the
        // NetCfg geometry), which the public API cannot construct
        let arts = tiny();
        let n_total = arts.meta.n_total;
        let w = Arg::F32(TensorF32::new(vec![n_total], vec![0.01; n_total]).unwrap());
        let bad_x = Arg::F32(TensorF32::new(vec![3, 3], vec![0.0; 9]).unwrap());
        let err = arts.invoke("eval_full", &[w, bad_x]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("expected"), "{msg}");
    }

    #[test]
    fn softmax_ce_gradient_matches_finite_difference() {
        let logits = vec![0.3f32, -0.2, 0.9, 0.1, 0.0, -0.5];
        let y = vec![2i32, 0];
        let (ce, _, d) = softmax_ce(&logits, &y, 2, 3, 1.0);
        let eps = 1e-3f32;
        for i in 0..logits.len() {
            let mut lp = logits.clone();
            lp[i] += eps;
            let (ce_p, _, _) = softmax_ce(&lp, &y, 2, 3, 1.0);
            let fd = (ce_p - ce) / eps;
            assert!(
                (fd - d[i]).abs() < 1e-2,
                "grad[{i}]: fd {fd} vs analytic {}",
                d[i]
            );
        }
    }

    #[test]
    fn forward_backward_shapes() {
        let cfg = builtin("tiny_mlp").unwrap();
        let w = vec![0.01f32; cfg.n_total()];
        let x = vec![0.5f32; 4 * 16];
        let acts = forward(&cfg.layers, &w, &x, 4);
        assert_eq!(acts.len(), 2); // one activation per layer
        assert_eq!(acts[1].len(), 4 * 4); // logits [batch, classes]
        let dlogits = vec![0.1f32; 4 * 4];
        let dw = backward(&cfg.layers, &w, &x, &acts, dlogits, 4);
        assert_eq!(dw.len(), cfg.n_total());
        // bias gradients of the last layer are sums of dlogits columns
        let l = &cfg.layers[1];
        for j in 0..4 {
            assert!((dw[l.bias_offset() + j] - 0.4).abs() < 1e-5);
        }
    }
}
