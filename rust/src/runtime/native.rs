//! Pure-Rust reference backend: executes every manifest entry point on the
//! host, with no Python, no XLA and no pre-generated artifacts.
//!
//! The implementations mirror the jax graphs in `python/compile/model.py`
//! term by term (same objective, same closed-form KL gradients, same Adam
//! constants) so the coordinator's Algorithm 2 control flow is identical on
//! both backends. The differences are confined to randomness: protocol
//! randomness (candidate generation) and reparameterization noise come from
//! the seed-tree derivations in [`crate::prng`] instead of jax's threefry.
//! Encoder and decoder share [`crate::prng::candidate_stream`], so the
//! shared-randomness contract of Algorithm 1 holds by construction — but a
//! `.mrc` encoded natively does not decode on the PJRT backend (and vice
//! versa). See `docs/adr/001-backend-abstraction.md`.
//!
//! Architecture support is dense MLPs only ([`crate::model::arch`]);
//! multi-dimensional inputs are treated as flattened feature vectors.

use std::collections::BTreeMap;

use crate::model::arch::{DenseLayer, NetCfg};
use crate::prng;
use crate::tensor::{Arg, TensorF32};
use crate::util::Result;
use crate::{ensure, err};

use super::{Backend, DeviceBuf, Entry, Input, ModelArtifacts, ModelMeta, Spec};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// The default execution backend: pure-Rust kernels over [`crate::tensor`].
pub struct NativeBackend {
    cfg: NetCfg,
}

impl NativeBackend {
    /// Build a loaded model from a built-in config (the native analogue of
    /// compiling an artifact directory).
    pub fn load(cfg: NetCfg) -> Result<ModelArtifacts> {
        cfg.validate()?;
        let meta = cfg.meta();
        let entries = entry_specs(&meta);
        Ok(ModelArtifacts::new(
            meta,
            entries,
            Box::new(NativeBackend { cfg }),
        ))
    }
}

/// The native manifest: same entry names and shapes the AOT path would load
/// from `manifest.json`, derived from the config.
fn entry_specs(meta: &ModelMeta) -> BTreeMap<String, Entry> {
    let bs = || Spec::f32(vec![meta.b, meta.s]);
    let lay = || Spec::f32(vec![meta.n_layers]);
    let srow = || Spec::f32(vec![meta.s]);
    let sf = || Spec::f32(vec![]);
    let si = || Spec::i32(vec![]);
    let mut x_shape = vec![meta.batch];
    x_shape.extend_from_slice(&meta.input_shape);
    let mut xe_shape = vec![meta.eval_batch];
    xe_shape.extend_from_slice(&meta.input_shape);

    let entries = [
        Entry::new(
            "train_step",
            vec![
                bs(),
                bs(),
                lay(),
                bs(),
                bs(),
                bs(),
                bs(),
                lay(),
                lay(),
                si(),
                Spec::f32(x_shape.clone()),
                Spec::i32(vec![meta.batch]),
                Spec::f32(vec![meta.b]),
                Spec::f32(vec![meta.b]),
                bs(),
                si(),
                Spec::i32(vec![meta.n_total]),
                Spec::i32(vec![meta.b, meta.s]),
                bs(),
                sf(),
                sf(),
                sf(),
            ],
            vec![
                bs(),
                bs(),
                lay(),
                bs(),
                bs(),
                bs(),
                bs(),
                lay(),
                lay(),
                sf(),
                sf(),
                sf(),
                Spec::f32(vec![meta.b]),
            ],
        ),
        Entry::new(
            "score_chunk",
            vec![si(), si(), si(), srow(), srow(), srow(), srow()],
            vec![Spec::f32(vec![meta.k_chunk])],
        ),
        Entry::new(
            "decode_chunk",
            vec![si(), si(), si(), srow()],
            vec![Spec::f32(vec![meta.k_chunk, meta.s])],
        ),
        Entry::new(
            "eval_batch",
            vec![
                bs(),
                Spec::i32(vec![meta.n_total]),
                Spec::f32(xe_shape.clone()),
            ],
            vec![Spec::f32(vec![meta.eval_batch, meta.classes])],
        ),
        Entry::new(
            "eval_full",
            vec![Spec::f32(vec![meta.n_total]), Spec::f32(xe_shape)],
            vec![Spec::f32(vec![meta.eval_batch, meta.classes])],
        ),
        Entry::new(
            "sample_weights",
            vec![bs(), bs(), Spec::f32(vec![meta.b]), bs(), si()],
            vec![bs()],
        ),
    ];
    entries
        .into_iter()
        .map(|e| (e.name.clone(), e))
        .collect()
}

/// Resolve every input to a host tensor (native buffers are host-resident).
fn collect<'a>(ins: &'a [Input<'a>]) -> Result<Vec<&'a Arg>> {
    ins.iter()
        .map(|input| match input {
            Input::Host(a) => Ok(*a),
            Input::Dev(buf) => match buf {
                DeviceBuf::Host(a) => Ok(a),
                #[cfg(feature = "xla")]
                DeviceBuf::Pjrt(_) => Err(crate::util::Error::msg(
                    "PJRT device buffer passed to the native backend",
                )),
            },
        })
        .collect()
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn family(&self) -> crate::codec::BackendFamily {
        crate::codec::BackendFamily::Native
    }

    fn upload(&self, arg: &Arg) -> Result<DeviceBuf> {
        Ok(DeviceBuf::Host(arg.clone()))
    }

    fn run(&self, entry: &Entry, ins: &[Input]) -> Result<Vec<Arg>> {
        let args = collect(ins)?;
        // The shared layer validates Host args only; Dev buffers are
        // trusted there, but the native kernels index raw slices, so a
        // wrong-shaped cached buffer would panic instead of erroring.
        // Re-check every resolved argument here (cheap vs the kernels).
        for (i, (a, spec)) in args.iter().zip(&entry.inputs).enumerate() {
            ensure!(
                a.shape() == &spec.shape[..] && a.dtype() == spec.dtype,
                "{}: resolved arg {i} is {}{:?}, expected {}{:?}",
                entry.name,
                a.dtype(),
                a.shape(),
                spec.dtype,
                spec.shape
            );
        }
        match entry.name.as_str() {
            "train_step" => self.train_step(&args),
            "score_chunk" => self.score_chunk(&args),
            "decode_chunk" => self.decode_chunk(&args),
            "eval_batch" => self.eval_batch(&args),
            "eval_full" => self.eval_full(&args),
            "sample_weights" => self.sample_weights(&args),
            other => err!("native backend has no entry '{other}'"),
        }
    }
}

fn f32_arg(shape: Vec<usize>, data: Vec<f32>) -> Result<Arg> {
    Ok(Arg::F32(TensorF32::new(shape, data)?))
}

impl NativeBackend {
    /// One Adam update of the beta-annealed objective (Eq. 3), mirroring
    /// `make_train_step`: reparameterized forward, softmax CE, closed-form
    /// block KL and its analytic gradients, frozen/padding masking.
    fn train_step(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let meta_b = self.cfg.b;
        let s = self.cfg.s;
        let n_pad = meta_b * s;
        let n_layers = self.cfg.layers.len();
        let n = self.cfg.batch;
        let classes = self.cfg.classes;

        let mu = a[0].f32s()?;
        let rho = a[1].f32s()?;
        let lsp = a[2].f32s()?;
        let m_mu = a[3].f32s()?;
        let v_mu = a[4].f32s()?;
        let m_rho = a[5].f32s()?;
        let v_rho = a[6].f32s()?;
        let m_lsp = a[7].f32s()?;
        let v_lsp = a[8].f32s()?;
        let step = a[9].i32s()?[0];
        let x = a[10].f32s()?;
        let y = a[11].i32s()?;
        let beta = a[12].f32s()?;
        let fm = a[13].f32s()?;
        let fw = a[14].f32s()?;
        let seed = a[15].i32s()?[0];
        let amap = a[16].i32s()?;
        let lmap = a[17].i32s()?;
        let smask = a[18].f32s()?;
        let data_scale = a[19].f32s()?[0];
        let lsp_train = a[20].f32s()?[0];
        let lr = a[21].f32s()?[0];

        // eps ~ N(0, I) over [B, S] — the PRNGKey(seed) analogue, shared
        // with sample_weights.
        let mut eps_rng = prng::eps_stream(seed);
        let eps = prng::normals_f32(&mut eps_rng, n_pad);

        // per-block KL(q||p) and effective (pinned + masked) parameters
        let mut kl_b = vec![0f32; meta_b];
        let mut eps_eff = vec![0f32; n_pad];
        let mut w_blocks = vec![0f32; n_pad];
        let exp_rho: Vec<f32> = rho.iter().map(|r| r.exp()).collect();
        for idx in 0..n_pad {
            let blk = idx / s;
            let lsp_e = lsp[lmap[idx] as usize];
            let var_ratio = (2.0 * (rho[idx] - lsp_e)).exp();
            let mu_term = {
                let t = mu[idx] * (-lsp_e).exp();
                t * t
            };
            let elem = lsp_e - rho[idx] + 0.5 * (var_ratio + mu_term) - 0.5;
            kl_b[blk] += smask[idx] * elem;
            let fmb = fm[blk];
            let mu_eff = fmb * fw[idx] + (1.0 - fmb) * mu[idx];
            eps_eff[idx] = (1.0 - fmb) * eps[idx] * smask[idx];
            w_blocks[idx] = mu_eff + exp_rho[idx] * eps_eff[idx];
        }

        // assemble flat weights, forward, CE + accuracy
        let w_full: Vec<f32> = amap
            .iter()
            .map(|&p| w_blocks[p as usize])
            .collect();
        let acts = forward(&self.cfg.layers, &w_full, x, n);
        let logits = acts.last().expect("forward returns >=1 activation");
        let (ce, acc, dlogits) = softmax_ce(logits, y, n, classes, data_scale);

        // backprop to flat weights, scatter to block layout
        let dw = backward(&self.cfg.layers, &w_full, x, &acts, dlogits, n);
        let mut g_mu = vec![0f32; n_pad];
        let mut g_rho = vec![0f32; n_pad];
        for (pos, &bpos) in amap.iter().enumerate() {
            let bpos = bpos as usize;
            let g = dw[pos];
            g_mu[bpos] += g * (1.0 - fm[bpos / s]);
            g_rho[bpos] += g * exp_rho[bpos] * eps_eff[bpos];
        }

        // analytic KL gradients (cotangent beta_b * (1 - fm_b) per block)
        let mut g_lsp = vec![0f32; n_layers];
        for idx in 0..n_pad {
            let blk = idx / s;
            let gb = beta[blk] * (1.0 - fm[blk]);
            if gb == 0.0 {
                continue;
            }
            let li = lmap[idx] as usize;
            let lsp_e = lsp[li];
            let inv_vp = (-2.0 * lsp_e).exp();
            let var_ratio = (2.0 * (rho[idx] - lsp_e)).exp();
            let mask = smask[idx];
            g_mu[idx] += mask * mu[idx] * inv_vp * gb;
            g_rho[idx] += mask * (var_ratio - 1.0) * gb;
            g_lsp[li] +=
                mask * (1.0 - var_ratio - mu[idx] * mu[idx] * inv_vp) * gb;
        }

        // masked Adam update (bias-corrected, jax constants)
        let t = step as f32;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        // frozen blocks and padding must not move
        let mut live = vec![0f32; n_pad];
        for i in 0..n_pad {
            live[i] = (1.0 - fm[i / s]) * smask[i];
            g_mu[i] *= live[i];
            g_rho[i] *= live[i];
        }
        for g in g_lsp.iter_mut() {
            *g *= lsp_train;
        }
        let lsp_live = vec![lsp_train; n_layers];
        let (mu2, m_mu2, v_mu2) =
            adam(mu, &g_mu, m_mu, v_mu, &live, lr, bc1, bc2);
        let (rho2, m_rho2, v_rho2) =
            adam(rho, &g_rho, m_rho, v_rho, &live, lr, bc1, bc2);
        let (lsp2, m_lsp2, v_lsp2) =
            adam(lsp, &g_lsp, m_lsp, v_lsp, &lsp_live, lr, bc1, bc2);

        let kl_pen: f64 = kl_b
            .iter()
            .enumerate()
            .map(|(b, &k)| (beta[b] * (1.0 - fm[b]) * k) as f64)
            .sum();
        let loss = (data_scale as f64 * ce as f64 + kl_pen) as f32;

        let bs = vec![meta_b, s];
        let lshape = vec![n_layers];
        Ok(vec![
            f32_arg(bs.clone(), mu2)?,
            f32_arg(bs.clone(), rho2)?,
            f32_arg(lshape.clone(), lsp2)?,
            f32_arg(bs.clone(), m_mu2)?,
            f32_arg(bs.clone(), v_mu2)?,
            f32_arg(bs.clone(), m_rho2)?,
            f32_arg(bs.clone(), v_rho2)?,
            f32_arg(lshape.clone(), m_lsp2)?,
            f32_arg(lshape, v_lsp2)?,
            Arg::F32(TensorF32::scalar(loss)),
            Arg::F32(TensorF32::scalar(ce)),
            Arg::F32(TensorF32::scalar(acc)),
            f32_arg(vec![meta_b], kl_b)?,
        ])
    }

    /// Importance logits `log q(w_k) - log p(w_k)` for one candidate chunk
    /// (Algorithm 1 line 4; the Pallas hot-spot on the PJRT path).
    fn score_chunk(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let seed = a[0].i32s()?[0];
        let block = a[1].i32s()?[0];
        let chunk = a[2].i32s()?[0];
        let mu_b = a[3].f32s()?;
        let rho_b = a[4].f32s()?;
        let lsp_b = a[5].f32s()?;
        let mask_b = a[6].f32s()?;
        let s = self.cfg.s;
        let k_chunk = self.cfg.k_chunk;
        let exp_lsp: Vec<f32> = lsp_b.iter().map(|l| l.exp()).collect();
        let neg_exp_rho: Vec<f32> = rho_b.iter().map(|r| (-r).exp()).collect();
        let mut rng = prng::candidate_stream(seed, block, chunk);
        let mut logits = Vec::with_capacity(k_chunk);
        for _ in 0..k_chunk {
            let mut acc = 0f64;
            for j in 0..s {
                let z = rng.next_normal() as f32;
                let w = exp_lsp[j] * z;
                let zq = (w - mu_b[j]) * neg_exp_rho[j];
                // log q - log p; the 0.5*log(2*pi) terms cancel
                let term =
                    (-0.5 * zq * zq - rho_b[j]) - (-0.5 * z * z - lsp_b[j]);
                acc += (mask_b[j] * term) as f64;
            }
            logits.push(acc as f32);
        }
        Ok(vec![f32_arg(vec![k_chunk], logits)?])
    }

    /// Candidate weights `sigma_p * z` for one chunk — the decoder replays
    /// the exact generator the encoder scored (shared randomness).
    fn decode_chunk(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let seed = a[0].i32s()?[0];
        let block = a[1].i32s()?[0];
        let chunk = a[2].i32s()?[0];
        let lsp_b = a[3].f32s()?;
        let s = self.cfg.s;
        let k_chunk = self.cfg.k_chunk;
        let exp_lsp: Vec<f32> = lsp_b.iter().map(|l| l.exp()).collect();
        let mut rng = prng::candidate_stream(seed, block, chunk);
        let mut out = Vec::with_capacity(k_chunk * s);
        for _ in 0..k_chunk {
            for j in 0..s {
                let z = rng.next_normal() as f32;
                out.push(exp_lsp[j] * z);
            }
        }
        Ok(vec![f32_arg(vec![k_chunk, s], out)?])
    }

    /// Logits from explicit block-layout weights (the serving path).
    fn eval_batch(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let w_blocks = a[0].f32s()?;
        let amap = a[1].i32s()?;
        let x = a[2].f32s()?;
        let w_full: Vec<f32> = amap
            .iter()
            .map(|&p| w_blocks[p as usize])
            .collect();
        self.logits_out(&w_full, x)
    }

    /// Logits from a raw flat weight vector (baseline path).
    fn eval_full(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let w_full = a[0].f32s()?;
        let x = a[1].f32s()?;
        self.logits_out(w_full, x)
    }

    fn logits_out(&self, w_full: &[f32], x: &[f32]) -> Result<Vec<Arg>> {
        let n = self.cfg.eval_batch;
        let acts = forward(&self.cfg.layers, w_full, x, n);
        let logits = acts.into_iter().last().expect("nonempty acts");
        ensure!(
            logits.len() == n * self.cfg.classes,
            "native forward produced {} logits, expected {}",
            logits.len(),
            n * self.cfg.classes
        );
        f32_arg(vec![n, self.cfg.classes], logits).map(|a| vec![a])
    }

    /// One block-layout weight draw from q, frozen blocks pinned.
    fn sample_weights(&self, a: &[&Arg]) -> Result<Vec<Arg>> {
        let mu = a[0].f32s()?;
        let rho = a[1].f32s()?;
        let fm = a[2].f32s()?;
        let fw = a[3].f32s()?;
        let seed = a[4].i32s()?[0];
        let s = self.cfg.s;
        let n_pad = self.cfg.b * s;
        let mut rng = prng::eps_stream(seed);
        let eps = prng::normals_f32(&mut rng, n_pad);
        let mut out = Vec::with_capacity(n_pad);
        for idx in 0..n_pad {
            let fmb = fm[idx / s];
            let sampled = mu[idx] + rho[idx].exp() * eps[idx];
            out.push(fmb * fw[idx] + (1.0 - fmb) * sampled);
        }
        f32_arg(vec![self.cfg.b, s], out).map(|a| vec![a])
    }
}

/// One bias-corrected Adam update with a per-parameter update mask (frozen
/// blocks / padding / lsp_train gating); returns (p', m', v').
#[allow(clippy::too_many_arguments)]
fn adam(
    p: &[f32],
    g: &[f32],
    m: &[f32],
    v: &[f32],
    mask: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut p2 = Vec::with_capacity(p.len());
    let mut m2v = Vec::with_capacity(p.len());
    let mut v2v = Vec::with_capacity(p.len());
    for i in 0..p.len() {
        let m2 = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
        let v2 = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
        let upd = lr * (m2 / bc1) / ((v2 / bc2).sqrt() + ADAM_EPS);
        p2.push(p[i] - upd * mask[i]);
        m2v.push(m2);
        v2v.push(v2);
    }
    (p2, m2v, v2v)
}

/// Forward pass: returns one activation vector per layer (`acts[i]` is the
/// output of layer `i`, ReLU applied to all but the last; `acts.last()` is
/// the logits). The input batch is read in place, never copied.
fn forward(
    layers: &[DenseLayer],
    w: &[f32],
    x: &[f32],
    n: usize,
) -> Vec<Vec<f32>> {
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(layers.len());
    for (li, l) in layers.iter().enumerate() {
        let (fi, fo) = (l.fan_in, l.fan_out);
        let bias = &w[l.bias_offset()..l.bias_offset() + fo];
        let mut out = vec![0f32; n * fo];
        {
            let input: &[f32] = if li == 0 { x } else { &acts[li - 1] };
            for r in 0..n {
                let xrow = &input[r * fi..(r + 1) * fi];
                let orow = &mut out[r * fo..(r + 1) * fo];
                orow.copy_from_slice(bias);
                for (i, &xv) in xrow.iter().enumerate() {
                    if xv != 0.0 {
                        let wrow =
                            &w[l.offset + i * fo..l.offset + (i + 1) * fo];
                        for j in 0..fo {
                            orow[j] += xv * wrow[j];
                        }
                    }
                }
            }
        }
        if li + 1 != layers.len() {
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        acts.push(out);
    }
    acts
}

/// Backprop of `dlogits` through the MLP (`acts` as returned by
/// [`forward`], `x` the same input batch); returns the flat weight
/// gradient. ReLU masks use the post-activations (`act > 0 ⟺ pre > 0`).
fn backward(
    layers: &[DenseLayer],
    w: &[f32],
    x: &[f32],
    acts: &[Vec<f32>],
    dlogits: Vec<f32>,
    n: usize,
) -> Vec<f32> {
    let mut dw = vec![0f32; w.len()];
    let mut d = dlogits;
    for li in (0..layers.len()).rev() {
        let l = &layers[li];
        let (fi, fo) = (l.fan_in, l.fan_out);
        let h_in: &[f32] = if li == 0 { x } else { &acts[li - 1] };
        for r in 0..n {
            let drow = &d[r * fo..(r + 1) * fo];
            let hrow = &h_in[r * fi..(r + 1) * fi];
            for (i, &hv) in hrow.iter().enumerate() {
                if hv != 0.0 {
                    let dwrow =
                        &mut dw[l.offset + i * fo..l.offset + (i + 1) * fo];
                    for j in 0..fo {
                        dwrow[j] += hv * drow[j];
                    }
                }
            }
            let dbias = &mut dw[l.bias_offset()..l.bias_offset() + fo];
            for j in 0..fo {
                dbias[j] += drow[j];
            }
        }
        if li > 0 {
            let mut dprev = vec![0f32; n * fi];
            for r in 0..n {
                let drow = &d[r * fo..(r + 1) * fo];
                let hrow = &h_in[r * fi..(r + 1) * fi];
                let prow = &mut dprev[r * fi..(r + 1) * fi];
                for i in 0..fi {
                    // ReLU gate on the *input* activation of this layer
                    if hrow[i] > 0.0 {
                        let wrow =
                            &w[l.offset + i * fo..l.offset + (i + 1) * fo];
                        let mut acc = 0f32;
                        for j in 0..fo {
                            acc += drow[j] * wrow[j];
                        }
                        prow[i] = acc;
                    }
                }
            }
            d = dprev;
        }
    }
    dw
}

/// Stable softmax cross-entropy + accuracy; `dlogits` includes the
/// `data_scale / batch` factor so it is the cotangent of the scaled loss.
fn softmax_ce(
    logits: &[f32],
    y: &[i32],
    n: usize,
    classes: usize,
    data_scale: f32,
) -> (f32, f32, Vec<f32>) {
    let mut ce_sum = 0f64;
    let mut correct = 0usize;
    let mut dlogits = vec![0f32; n * classes];
    let scale = data_scale / n as f32;
    for r in 0..n {
        let row = &logits[r * classes..(r + 1) * classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&v| ((v - max) as f64).exp()).sum();
        let lse = max as f64 + sum.ln();
        let yi = y[r] as usize;
        ce_sum += lse - row[yi] as f64;
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
            let p = (((v as f64) - lse).exp()) as f32;
            dlogits[r * classes + j] = p * scale;
        }
        dlogits[r * classes + yi] -= scale;
        if best == yi {
            correct += 1;
        }
    }
    let ce = (ce_sum / n as f64) as f32;
    let acc = correct as f32 / n as f32;
    (ce, acc, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::arch::builtin;

    fn tiny() -> ModelArtifacts {
        NativeBackend::load(builtin("tiny_mlp").unwrap()).unwrap()
    }

    #[test]
    fn manifest_has_all_entries() {
        let arts = tiny();
        for name in [
            "train_step",
            "score_chunk",
            "decode_chunk",
            "eval_batch",
            "eval_full",
            "sample_weights",
        ] {
            let e = arts.entry(name).unwrap();
            assert!(!e.inputs.is_empty());
            assert!(!e.outputs.is_empty());
        }
        assert_eq!(arts.backend_kind(), "native");
    }

    #[test]
    fn decode_chunk_is_deterministic_and_seed_sensitive() {
        let arts = tiny();
        let s = arts.meta.s;
        let lsp = Arg::F32(TensorF32::new(vec![s], vec![-1.0; s]).unwrap());
        let scalar = |v: i32| Arg::I32(crate::tensor::TensorI32::scalar(v));
        let run = |seed: i32| {
            arts.invoke(
                "decode_chunk",
                &[scalar(seed), scalar(3), scalar(1), lsp.clone()],
            )
            .unwrap()[0]
                .f32s()
                .unwrap()
                .to_vec()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn score_matches_decode_candidates() {
        // score_chunk's logits must be computed on exactly the candidates
        // decode_chunk returns (shared randomness within the backend)
        let arts = tiny();
        let s = arts.meta.s;
        let scalar = |v: i32| Arg::I32(crate::tensor::TensorI32::scalar(v));
        let row = |v: f32| Arg::F32(TensorF32::new(vec![s], vec![v; s]).unwrap());
        let lsp = row(-0.5);
        let outs = arts
            .invoke(
                "decode_chunk",
                &[scalar(5), scalar(0), scalar(0), lsp.clone()],
            )
            .unwrap();
        let cands = outs[0].as_f32().unwrap().clone();
        let outs = arts
            .invoke(
                "score_chunk",
                &[
                    scalar(5),
                    scalar(0),
                    scalar(0),
                    row(0.0),
                    row(-0.5),
                    lsp,
                    row(1.0),
                ],
            )
            .unwrap();
        let logits = outs[0].f32s().unwrap().to_vec();
        // with q == p (mu=0, rho=lsp), every importance logit is exactly 0
        assert_eq!(cands.shape, vec![arts.meta.k_chunk, s]);
        for &l in &logits {
            assert!(l.abs() < 1e-5, "logit {l}");
        }
    }

    #[test]
    fn softmax_ce_gradient_matches_finite_difference() {
        let logits = vec![0.3f32, -0.2, 0.9, 0.1, 0.0, -0.5];
        let y = vec![2i32, 0];
        let (ce, _, d) = softmax_ce(&logits, &y, 2, 3, 1.0);
        let eps = 1e-3f32;
        for i in 0..logits.len() {
            let mut lp = logits.clone();
            lp[i] += eps;
            let (ce_p, _, _) = softmax_ce(&lp, &y, 2, 3, 1.0);
            let fd = (ce_p - ce) / eps;
            assert!(
                (fd - d[i]).abs() < 1e-2,
                "grad[{i}]: fd {fd} vs analytic {}",
                d[i]
            );
        }
    }

    #[test]
    fn forward_backward_shapes() {
        let cfg = builtin("tiny_mlp").unwrap();
        let w = vec![0.01f32; cfg.n_total()];
        let x = vec![0.5f32; 4 * 16];
        let acts = forward(&cfg.layers, &w, &x, 4);
        assert_eq!(acts.len(), 2); // one activation per layer
        assert_eq!(acts[1].len(), 4 * 4); // logits [batch, classes]
        let dlogits = vec![0.1f32; 4 * 4];
        let dw = backward(&cfg.layers, &w, &x, &acts, dlogits, 4);
        assert_eq!(dw.len(), cfg.n_total());
        // bias gradients of the last layer are sums of dlogits columns
        let l = &cfg.layers[1];
        for j in 0..4 {
            assert!((dw[l.bias_offset() + j] - 0.4).abs() < 1e-5);
        }
    }
}
