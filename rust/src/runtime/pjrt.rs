//! PJRT backend: loads AOT HLO-text artifacts and executes them on device.
//!
//! `make artifacts` (python, build-time) writes one directory per model
//! config containing `<entry>.hlo.txt` files plus `manifest.json`. This
//! backend compiles every entry on a PJRT client once; shape/dtype
//! validation against the manifest happens in the shared
//! [`ModelArtifacts`] layer, so this module only moves buffers and executes.
//!
//! The batched candidate entries (`score_block`, `score_blocks`,
//! `decode_block`) are *synthesized* when an artifact directory predates
//! them: the coordinator always talks to the batched surface, and this
//! backend decomposes a batched call into the chunk-level executables the
//! manifest does provide (`score_chunk` / `decode_chunk`). Chunk order is
//! preserved, so results are identical to the native decomposition.
//!
//! Compiled only with `--features xla`. The in-tree `xla` package is a
//! compile-time stub (see `rust/xla-stub`); patch in a real PJRT binding to
//! execute artifacts for real.

use std::collections::BTreeMap;
use std::path::Path;

use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::json::Json;
use crate::util::{Error, Result};
use crate::{ensure, err, info};

use super::{Backend, DeviceBuf, Entry, Input, ModelArtifacts, ModelMeta, Spec};

fn spec_from_json(j: &Json) -> Result<Spec> {
    Ok(Spec {
        shape: j.get("shape")?.usize_arr()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

/// The PJRT execution backend: one compiled executable per manifest entry,
/// plus block geometry for the synthesized batched entries.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    k_chunk: usize,
    s: usize,
}

/// Load and compile every entry of an artifact directory.
pub fn load_dir(client: &xla::PjRtClient, dir: &Path) -> Result<ModelArtifacts> {
    let manifest_path = dir.join("manifest.json");
    let manifest = Json::from_file(manifest_path.to_str().unwrap())
        .map_err(|e| e.context(format!("loading {manifest_path:?}")))?;
    let meta = parse_meta(&manifest)?;
    let mut entries = BTreeMap::new();
    let mut exes = BTreeMap::new();
    for (name, e) in manifest.get("entries")?.as_obj()? {
        let file = dir.join(e.get("file")?.as_str()?);
        let t = crate::util::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str()
                .ok_or_else(|| Error::msg("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let inputs = e
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(spec_from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = e
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(spec_from_json)
            .collect::<Result<Vec<_>>>()?;
        info!("compiled {}/{name} in {:.2}s", meta.name, t.secs());
        entries.insert(name.clone(), Entry::new(name, inputs, outputs));
        exes.insert(name.clone(), exe);
    }
    synthesize_batched_entries(&mut entries, &meta);
    Ok(ModelArtifacts::new(
        meta.clone(),
        entries,
        Box::new(PjrtBackend {
            client: client.clone(),
            exes,
            k_chunk: meta.k_chunk,
            s: meta.s,
        }),
    ))
}

/// Manifest entries for the batched candidate surface when the artifact
/// directory only ships the chunk-level executables (executed by
/// decomposition at run time, see `synth_*` below). The specs come from
/// the shared `runtime::batched_entry_specs`, so they cannot drift from
/// the native manifest.
fn synthesize_batched_entries(entries: &mut BTreeMap<String, Entry>, meta: &ModelMeta) {
    for e in super::batched_entry_specs(meta.s) {
        let base = if e.name == "decode_block" {
            "decode_chunk"
        } else {
            "score_chunk"
        };
        if entries.contains_key(base) && !entries.contains_key(&e.name) {
            entries.insert(e.name.clone(), e);
        }
    }
}

fn parse_meta(m: &Json) -> Result<ModelMeta> {
    let eval_inputs = m
        .get("entries")?
        .get("eval_batch")?
        .get("inputs")?
        .as_arr()?;
    ensure!(eval_inputs.len() == 3, "eval_batch should have 3 inputs");
    let x_shape = spec_from_json(&eval_inputs[2])?.shape;
    Ok(ModelMeta {
        name: m.get("config")?.as_str()?.to_string(),
        b: m.get("B")?.as_usize()?,
        s: m.get("S")?.as_usize()?,
        k_chunk: m.get("k_chunk")?.as_usize()?,
        n_total: m.get("n_total")?.as_usize()?,
        n_slots: m.get("n_slots")?.as_usize()?,
        n_layers: m.get("n_layers")?.as_usize()?,
        layer_slots: m.get("layer_slots")?.usize_arr()?,
        layer_counts: m.get("layer_counts")?.usize_arr()?,
        batch: m.get("batch")?.as_usize()?,
        eval_batch: m.get("eval_batch")?.as_usize()?,
        classes: m.get("classes")?.as_usize()?,
        input_shape: x_shape[1..].to_vec(),
    })
}

/// Read a host-resident i32 scalar argument of a synthesized batched call
/// (the decomposition needs its value on the host to drive the chunk loop).
fn host_i32_scalar(ins: &[Input], i: usize, entry: &str) -> Result<i32> {
    match ins.get(i) {
        Some(Input::Host(a)) => Ok(a.i32s()?[0]),
        Some(Input::Dev(_)) => err!(
            "{entry}: arg {i} must be host-resident for the synthesized \
             batched path"
        ),
        None => err!("{entry}: missing arg {i}"),
    }
}

/// The hoisted device buffer for arg `i` when one was uploaded, else the
/// caller's original input (already device-resident).
fn hoisted_input<'a>(
    hoisted: &'a [Option<DeviceBuf>],
    ins: &[Input<'a>],
    base: usize,
    i: usize,
) -> Input<'a> {
    match &hoisted[i - base] {
        Some(buf) => Input::Dev(buf),
        None => ins[i],
    }
}

/// Read a host-resident f32 row argument of a synthesized batched call.
fn host_f32s<'a>(ins: &'a [Input<'a>], i: usize, entry: &str) -> Result<&'a [f32]> {
    match ins.get(i) {
        Some(Input::Host(a)) => a.f32s(),
        Some(Input::Dev(_)) => err!(
            "{entry}: arg {i} must be host-resident for the synthesized \
             batched path"
        ),
        None => err!("{entry}: missing arg {i}"),
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn family(&self) -> crate::codec::BackendFamily {
        crate::codec::BackendFamily::Pjrt
    }

    fn upload(&self, arg: &Arg) -> Result<DeviceBuf> {
        Ok(DeviceBuf::Pjrt(arg.to_buffer(&self.client, None)?))
    }

    fn run(&self, entry: &Entry, ins: &[Input]) -> Result<Vec<Arg>> {
        if !self.exes.contains_key(&entry.name) {
            // batched entries synthesized over the chunk-level executables
            return match entry.name.as_str() {
                "score_block" => self.synth_score_block(ins),
                "score_blocks" => self.synth_score_blocks(ins),
                "decode_block" => self.synth_decode_block(ins),
                other => err!("no executable '{other}'"),
            };
        }
        self.exec(&entry.name, &entry.outputs, ins)
    }
}

impl PjrtBackend {
    fn exec(&self, name: &str, out_specs: &[Spec], ins: &[Input]) -> Result<Vec<Arg>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::msg(format!("no executable '{name}'")))?;
        // Explicit host->device transfer so every buffer is rust-owned and
        // freed by Drop (the C-side `execute(literals)` path leaks its
        // internal arg buffers — measured ~1.7 MB/step on train_step).
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        for input in ins {
            if let Input::Host(a) = input {
                owned.push(a.to_buffer(&self.client, None)?);
            }
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(ins.len());
        let mut oi = 0usize;
        for input in ins {
            match input {
                Input::Host(_) => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
                Input::Dev(DeviceBuf::Pjrt(b)) => refs.push(b),
                Input::Dev(DeviceBuf::Host(_)) => {
                    return err!(
                        "{name}: host-resident buffer passed to the PJRT backend"
                    );
                }
            }
        }
        let result = exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        ensure!(
            outs.len() == out_specs.len(),
            "{name}: {} outputs, {} expected",
            outs.len(),
            out_specs.len()
        );
        outs.iter()
            .zip(out_specs)
            .map(|(lit, spec)| match spec.dtype.as_str() {
                "i32" => Ok(Arg::I32(TensorI32::from_literal(lit)?)),
                _ => Ok(Arg::F32(TensorF32::from_literal(lit)?)),
            })
            .collect()
    }

    fn score_chunk_specs(&self) -> Vec<Spec> {
        vec![Spec::f32(vec![self.k_chunk])]
    }

    /// Upload the host-resident args at `range` once so the chunk loop
    /// reuses device buffers instead of re-transferring per chunk (the
    /// upload-once fast path the monolithic entries get for free).
    fn hoist_host_args(
        &self,
        ins: &[Input],
        range: std::ops::Range<usize>,
    ) -> Result<Vec<Option<DeviceBuf>>> {
        range
            .map(|i| match ins[i] {
                Input::Host(a) => Ok(Some(self.upload(a)?)),
                Input::Dev(_) => Ok(None),
            })
            .collect()
    }

    /// `score_block` = `score_chunk` over `n_chunks` consecutive chunks,
    /// concatenated in chunk order.
    fn synth_score_block(&self, ins: &[Input]) -> Result<Vec<Arg>> {
        ensure!(ins.len() == 7, "score_block: 7 args expected");
        let n_chunks = host_i32_scalar(ins, 2, "score_block")?;
        ensure!(
            n_chunks > 0,
            "score_block: n_chunks must be positive, got {n_chunks}"
        );
        let out_specs = self.score_chunk_specs();
        let rows = self.hoist_host_args(ins, 3..7)?;
        let mut logits = Vec::with_capacity(n_chunks as usize * self.k_chunk);
        for c in 0..n_chunks {
            let chunk = Arg::I32(TensorI32::scalar(c));
            let sub: Vec<Input> = vec![
                ins[0],
                ins[1],
                Input::Host(&chunk),
                hoisted_input(&rows, ins, 3, 3),
                hoisted_input(&rows, ins, 3, 4),
                hoisted_input(&rows, ins, 3, 5),
                hoisted_input(&rows, ins, 3, 6),
            ];
            let outs = self.exec("score_chunk", &out_specs, &sub)?;
            logits.extend_from_slice(outs[0].f32s()?);
        }
        let n = logits.len();
        Ok(vec![Arg::F32(TensorF32::new(vec![n], logits)?)])
    }

    /// `score_blocks` = `score_chunk` over every (block, chunk) pair,
    /// block-major then chunk-major — the order the encoder reduces in.
    fn synth_score_blocks(&self, ins: &[Input]) -> Result<Vec<Arg>> {
        ensure!(ins.len() == 7, "score_blocks: 7 args expected");
        let blocks: Vec<i32> = match ins.get(1) {
            Some(Input::Host(a)) => a.i32s()?.to_vec(),
            _ => {
                return err!(
                    "score_blocks: arg 1 must be host-resident for the \
                     synthesized batched path"
                )
            }
        };
        let n_chunks = host_i32_scalar(ins, 2, "score_blocks")?;
        ensure!(
            n_chunks > 0,
            "score_blocks: n_chunks must be positive, got {n_chunks}"
        );
        let nb = blocks.len();
        ensure!(nb > 0, "score_blocks: empty block list");
        let s = self.s;
        let rows = [
            host_f32s(ins, 3, "score_blocks")?,
            host_f32s(ins, 4, "score_blocks")?,
            host_f32s(ins, 5, "score_blocks")?,
            host_f32s(ins, 6, "score_blocks")?,
        ];
        for v in rows.iter() {
            ensure!(
                v.len() == nb * s,
                "score_blocks: row arg has {} values, expected {nb} blocks x S={s}",
                v.len()
            );
        }
        let out_specs = self.score_chunk_specs();
        let mut logits =
            Vec::with_capacity(nb * n_chunks as usize * self.k_chunk);
        for (bi, &b) in blocks.iter().enumerate() {
            let block_arg = Arg::I32(TensorI32::scalar(b));
            // upload this block's rows once; all its chunks reuse them
            let row_bufs: Vec<DeviceBuf> = rows
                .iter()
                .map(|v| {
                    self.upload(&Arg::F32(TensorF32::new(
                        vec![s],
                        v[bi * s..(bi + 1) * s].to_vec(),
                    )?))
                })
                .collect::<Result<Vec<DeviceBuf>>>()?;
            for c in 0..n_chunks {
                let chunk = Arg::I32(TensorI32::scalar(c));
                let sub: Vec<Input> = vec![
                    ins[0],
                    Input::Host(&block_arg),
                    Input::Host(&chunk),
                    Input::Dev(&row_bufs[0]),
                    Input::Dev(&row_bufs[1]),
                    Input::Dev(&row_bufs[2]),
                    Input::Dev(&row_bufs[3]),
                ];
                let outs = self.exec("score_chunk", &out_specs, &sub)?;
                logits.extend_from_slice(outs[0].f32s()?);
            }
        }
        let n = logits.len();
        Ok(vec![Arg::F32(TensorF32::new(vec![n], logits)?)])
    }

    /// `decode_block` = `decode_chunk` of the containing chunk + row
    /// selection on the host.
    fn synth_decode_block(&self, ins: &[Input]) -> Result<Vec<Arg>> {
        ensure!(ins.len() == 4, "decode_block: 4 args expected");
        let index = host_i32_scalar(ins, 2, "decode_block")?;
        ensure!(
            index >= 0,
            "decode_block: negative candidate index {index}"
        );
        let (chunk, row) =
            crate::codec::chunk_and_row(index as u64, self.k_chunk);
        let chunk_arg = Arg::I32(TensorI32::scalar(chunk as i32));
        let sub: Vec<Input> =
            vec![ins[0], ins[1], Input::Host(&chunk_arg), ins[3]];
        let outs = self.exec(
            "decode_chunk",
            &[Spec::f32(vec![self.k_chunk, self.s])],
            &sub,
        )?;
        let cand = outs[0].as_f32()?;
        ensure!(
            cand.shape == vec![self.k_chunk, self.s],
            "decode_chunk returned {:?}",
            cand.shape
        );
        Ok(vec![Arg::F32(TensorF32::new(
            vec![self.s],
            cand.row(row).to_vec(),
        )?)])
    }
}
