//! PJRT backend: loads AOT HLO-text artifacts and executes them on device.
//!
//! `make artifacts` (python, build-time) writes one directory per model
//! config containing `<entry>.hlo.txt` files plus `manifest.json`. This
//! backend compiles every entry on a PJRT client once; shape/dtype
//! validation against the manifest happens in the shared
//! [`ModelArtifacts`] layer, so this module only moves buffers and executes.
//!
//! Compiled only with `--features xla`. The in-tree `xla` package is a
//! compile-time stub (see `rust/xla-stub`); patch in a real PJRT binding to
//! execute artifacts for real.

use std::collections::BTreeMap;
use std::path::Path;

use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::json::Json;
use crate::util::{Error, Result};
use crate::{ensure, err, info};

use super::{Backend, DeviceBuf, Entry, Input, ModelArtifacts, ModelMeta, Spec};

fn spec_from_json(j: &Json) -> Result<Spec> {
    Ok(Spec {
        shape: j.get("shape")?.usize_arr()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

/// The PJRT execution backend: one compiled executable per manifest entry.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

/// Load and compile every entry of an artifact directory.
pub fn load_dir(client: &xla::PjRtClient, dir: &Path) -> Result<ModelArtifacts> {
    let manifest_path = dir.join("manifest.json");
    let manifest = Json::from_file(manifest_path.to_str().unwrap())
        .map_err(|e| e.context(format!("loading {manifest_path:?}")))?;
    let meta = parse_meta(&manifest)?;
    let mut entries = BTreeMap::new();
    let mut exes = BTreeMap::new();
    for (name, e) in manifest.get("entries")?.as_obj()? {
        let file = dir.join(e.get("file")?.as_str()?);
        let t = crate::util::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str()
                .ok_or_else(|| Error::msg("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let inputs = e
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(spec_from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = e
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(spec_from_json)
            .collect::<Result<Vec<_>>>()?;
        info!("compiled {}/{name} in {:.2}s", meta.name, t.secs());
        entries.insert(name.clone(), Entry::new(name, inputs, outputs));
        exes.insert(name.clone(), exe);
    }
    Ok(ModelArtifacts::new(
        meta,
        entries,
        Box::new(PjrtBackend { client: client.clone(), exes }),
    ))
}

fn parse_meta(m: &Json) -> Result<ModelMeta> {
    let eval_inputs = m
        .get("entries")?
        .get("eval_batch")?
        .get("inputs")?
        .as_arr()?;
    ensure!(eval_inputs.len() == 3, "eval_batch should have 3 inputs");
    let x_shape = spec_from_json(&eval_inputs[2])?.shape;
    Ok(ModelMeta {
        name: m.get("config")?.as_str()?.to_string(),
        b: m.get("B")?.as_usize()?,
        s: m.get("S")?.as_usize()?,
        k_chunk: m.get("k_chunk")?.as_usize()?,
        n_total: m.get("n_total")?.as_usize()?,
        n_slots: m.get("n_slots")?.as_usize()?,
        n_layers: m.get("n_layers")?.as_usize()?,
        layer_slots: m.get("layer_slots")?.usize_arr()?,
        layer_counts: m.get("layer_counts")?.usize_arr()?,
        batch: m.get("batch")?.as_usize()?,
        eval_batch: m.get("eval_batch")?.as_usize()?,
        classes: m.get("classes")?.as_usize()?,
        input_shape: x_shape[1..].to_vec(),
    })
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn family(&self) -> crate::codec::BackendFamily {
        crate::codec::BackendFamily::Pjrt
    }

    fn upload(&self, arg: &Arg) -> Result<DeviceBuf> {
        Ok(DeviceBuf::Pjrt(arg.to_buffer(&self.client, None)?))
    }

    fn run(&self, entry: &Entry, ins: &[Input]) -> Result<Vec<Arg>> {
        let exe = self
            .exes
            .get(&entry.name)
            .ok_or_else(|| Error::msg(format!("no executable '{}'", entry.name)))?;
        // Explicit host->device transfer so every buffer is rust-owned and
        // freed by Drop (the C-side `execute(literals)` path leaks its
        // internal arg buffers — measured ~1.7 MB/step on train_step).
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        for input in ins {
            if let Input::Host(a) = input {
                owned.push(a.to_buffer(&self.client, None)?);
            }
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(ins.len());
        let mut oi = 0usize;
        for input in ins {
            match input {
                Input::Host(_) => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
                Input::Dev(DeviceBuf::Pjrt(b)) => refs.push(b),
                Input::Dev(DeviceBuf::Host(_)) => {
                    return err!(
                        "{}: host-resident buffer passed to the PJRT backend",
                        entry.name
                    );
                }
            }
        }
        let result = exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        ensure!(
            outs.len() == entry.outputs.len(),
            "{}: {} outputs, {} expected",
            entry.name,
            outs.len(),
            entry.outputs.len()
        );
        outs.iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| match spec.dtype.as_str() {
                "i32" => Ok(Arg::I32(TensorI32::from_literal(lit)?)),
                _ => Ok(Arg::F32(TensorF32::from_literal(lit)?)),
            })
            .collect()
    }
}
