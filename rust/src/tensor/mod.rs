//! Minimal host tensors shuttled across the [`crate::runtime::Backend`]
//! boundary, plus the cache-blocked dense kernels ([`linalg`]) the native
//! backend computes with. The optional PJRT backend (`--features xla`)
//! converts tensors to device literals via the feature-gated methods at the
//! bottom.

pub mod linalg;

use crate::util::Result;
use crate::{ensure, err};

/// Row-major f32 host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<TensorF32> {
        let n: usize = shape.iter().product();
        ensure!(n == data.len(), "shape {shape:?} != data len {}", data.len());
        Ok(TensorF32 { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> TensorF32 {
        let n = shape.iter().product();
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> TensorF32 {
        let n = shape.iter().product();
        TensorF32 { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> TensorF32 {
        TensorF32 { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// 2-D accessor (row major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> Result<TensorF32> {
        let shape = literal_dims(lit)?;
        let data = lit.to_vec::<f32>()?;
        TensorF32::new(shape, data)
    }
}

/// Row-major i32 host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<TensorI32> {
        let n: usize = shape.iter().product();
        ensure!(n == data.len(), "shape {shape:?} != data len {}", data.len());
        Ok(TensorI32 { shape, data })
    }

    pub fn scalar(v: i32) -> TensorI32 {
        TensorI32 { shape: vec![], data: vec![v] }
    }

    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> Result<TensorI32> {
        let shape = literal_dims(lit)?;
        let data = lit.to_vec::<i32>()?;
        TensorI32::new(shape, data)
    }
}

#[cfg(feature = "xla")]
fn literal_dims(lit: &xla::Literal) -> Result<Vec<usize>> {
    match lit.shape()? {
        xla::Shape::Array(a) => Ok(a.dims().iter().map(|&d| d as usize).collect()),
        _ => err!("literal is not an array"),
    }
}

/// Typed argument for runtime invocation.
#[derive(Debug, Clone)]
pub enum Arg {
    F32(TensorF32),
    I32(TensorI32),
}

impl Arg {
    /// The f32 payload, or an error for an i32 tensor.
    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Arg::F32(t) => Ok(&t.data),
            Arg::I32(_) => err!("expected f32 tensor, got i32"),
        }
    }

    /// The i32 payload, or an error for an f32 tensor.
    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            Arg::I32(t) => Ok(&t.data),
            Arg::F32(_) => err!("expected i32 tensor, got f32"),
        }
    }

    /// The full f32 tensor (shape + data), for row access.
    pub fn as_f32(&self) -> Result<&TensorF32> {
        match self {
            Arg::F32(t) => Ok(t),
            Arg::I32(_) => err!("expected f32 tensor, got i32"),
        }
    }

    /// Consume into the f32 payload without copying (hot-path output path).
    pub fn into_f32s(self) -> Result<Vec<f32>> {
        match self {
            Arg::F32(t) => Ok(t.data),
            Arg::I32(_) => err!("expected f32 tensor, got i32"),
        }
    }

    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Arg::F32(t) => t.to_literal(),
            Arg::I32(t) => t.to_literal(),
        }
    }

    /// Direct host->device transfer (bypasses the Literal path, whose
    /// C-side conversion both leaks and mishandles scalar shapes).
    #[cfg(feature = "xla")]
    pub fn to_buffer(
        &self,
        client: &xla::PjRtClient,
        device: Option<&xla::PjRtDevice>,
    ) -> Result<xla::PjRtBuffer> {
        Ok(match self {
            Arg::F32(t) => client.buffer_from_host_buffer(&t.data, &t.shape, device)?,
            Arg::I32(t) => client.buffer_from_host_buffer(&t.data, &t.shape, device)?,
        })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Arg::F32(t) => &t.shape,
            Arg::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Arg::F32(_) => "f32",
            Arg::I32(_) => "i32",
        }
    }
}

impl From<TensorF32> for Arg {
    fn from(t: TensorF32) -> Arg {
        Arg::F32(t)
    }
}

impl From<TensorI32> for Arg {
    fn from(t: TensorI32) -> Arg {
        Arg::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(TensorF32::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorF32::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn row_access() {
        let t = TensorF32::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.at2(0, 2), 2.0);
    }

    #[test]
    fn scalar_shape() {
        let t = TensorF32::scalar(7.0);
        assert!(t.shape.is_empty());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn arg_accessors_are_typed() {
        let f = Arg::F32(TensorF32::scalar(1.5));
        assert_eq!(f.f32s().unwrap(), &[1.5]);
        assert!(f.i32s().is_err());
        assert_eq!(f.as_f32().unwrap().len(), 1);
        let i = Arg::I32(TensorI32::scalar(3));
        assert_eq!(i.i32s().unwrap(), &[3]);
        assert!(i.f32s().is_err());
        assert!(i.as_f32().is_err());
        assert_eq!(f.into_f32s().unwrap(), vec![1.5]);
        assert!(i.into_f32s().is_err());
    }
}
