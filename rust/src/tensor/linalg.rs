//! Cache-blocked dense kernels behind the native backend's forward/backward
//! passes.
//!
//! The flat parameter layout stores each dense layer's weights row-major as
//! `W [fan_in, fan_out]`. For the batched `x · W` product the better layout
//! is the transpose `Wᵀ [fan_out, fan_in]`: every output coordinate becomes
//! one dot product of two contiguous vectors. [`dot_scalar`] — the
//! reference implementation — uses four independent accumulators in a fixed
//! summation order, so its fp semantics are deterministic per call; the
//! AVX2/FMA and NEON variants behind [`dot`] use wider fused accumulators
//! and may differ by a few ulps (train/eval drift only — the `.mrc` decode
//! path never touches these kernels; policy in `docs/perf.md`, dispatch via
//! [`crate::util::simd`]). [`matmul_bias_wt`] additionally tiles over
//! output columns so a tile of `Wᵀ` rows stays cache-hot across the whole
//! batch instead of being re-streamed per example.

#![deny(unsafe_op_in_unsafe_fn)]

use crate::util::simd::{self, SimdPath};

/// Reference dot product: four independent accumulators, fixed summation
/// order — bit-identical on every call with the same inputs.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let mut i = 0usize;
    while i + 4 <= n {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Dot product on an explicit dispatch path (hoist [`simd::active`] out of
/// inner loops — see [`matmul_bias_wt`]).
#[inline]
pub fn dot_with(path: SimdPath, a: &[f32], b: &[f32]) -> f32 {
    match path {
        SimdPath::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `SimdPath::Avx2` is only ever produced after
        // `is_x86_feature_detected!` confirmed AVX2+FMA (util/simd.rs).
        SimdPath::Avx2 => unsafe { x86::dot_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        // NEON is baseline on aarch64 — statically enabled, safe call.
        SimdPath::Neon => neon::dot_neon(a, b),
        // cross-arch variants that cannot occur on this target
        _ => dot_scalar(a, b),
    }
}

/// Dot product on the process-wide dispatch path.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with(simd::active(), a, b)
}

/// Transpose a row-major `[rows, cols]` matrix into `dst` as `[cols, rows]`
/// (reuses `dst`'s allocation across calls).
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut Vec<f32>) {
    debug_assert_eq!(src.len(), rows * cols);
    dst.clear();
    dst.resize(rows * cols, 0.0);
    for i in 0..rows {
        let row = &src[i * cols..(i + 1) * cols];
        for (j, &v) in row.iter().enumerate() {
            dst[j * rows + i] = v;
        }
    }
}

/// How many transposed weight rows to keep hot per tile: 8 rows of a
/// 784-wide LeNet layer is ~25 KB — comfortably L1/L2 resident.
const COL_TILE: usize = 8;

/// `out[r, j] = bias[j] + x[r, :] · wt[j, :]` for `r < n`, `j < fo`, with
/// `wt` the transposed weights `[fo, fi]`. Tiled over `j` so a tile of `wt`
/// is reused across the whole batch.
pub fn matmul_bias_wt(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    out: &mut [f32],
    n: usize,
    fi: usize,
    fo: usize,
) {
    debug_assert_eq!(x.len(), n * fi);
    debug_assert_eq!(wt.len(), fi * fo);
    debug_assert_eq!(bias.len(), fo);
    debug_assert_eq!(out.len(), n * fo);
    // one dispatch-path lookup for the whole product
    let path = simd::active();
    let mut j0 = 0usize;
    while j0 < fo {
        let j1 = (j0 + COL_TILE).min(fo);
        for r in 0..n {
            let xrow = &x[r * fi..(r + 1) * fi];
            let orow = &mut out[r * fo..(r + 1) * fo];
            for j in j0..j1 {
                orow[j] =
                    bias[j] + dot_with(path, xrow, &wt[j * fi..(j + 1) * fi]);
            }
        }
        j0 = j1;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2/FMA dot: two 8-lane fused accumulators (f32, like the scalar
    //! reference's four-lane split — reassociation/fusion is the documented
    //! ulp-drift source).

    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2,fma")]
    pub fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            // SAFETY: `i + 16 <= n <= a.len(), b.len()` bounds all four
            // 8-lane loads.
            unsafe {
                acc0 = _mm256_fmadd_ps(
                    _mm256_loadu_ps(a.as_ptr().add(i)),
                    _mm256_loadu_ps(b.as_ptr().add(i)),
                    acc0,
                );
                acc1 = _mm256_fmadd_ps(
                    _mm256_loadu_ps(a.as_ptr().add(i + 8)),
                    _mm256_loadu_ps(b.as_ptr().add(i + 8)),
                    acc1,
                );
            }
            i += 16;
        }
        if i + 8 <= n {
            // SAFETY: `i + 8 <= n` bounds both 8-lane loads.
            unsafe {
                acc0 = _mm256_fmadd_ps(
                    _mm256_loadu_ps(a.as_ptr().add(i)),
                    _mm256_loadu_ps(b.as_ptr().add(i)),
                    acc0,
                );
            }
            i += 8;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let s4 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2));
        let mut s = _mm_cvtss_f32(s1);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON dot: two 4-lane fused accumulators.

    use core::arch::aarch64::*;

    pub fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: `i + 8 <= n <= a.len(), b.len()` bounds all four
            // 4-lane loads.
            unsafe {
                acc0 = vfmaq_f32(
                    acc0,
                    vld1q_f32(a.as_ptr().add(i)),
                    vld1q_f32(b.as_ptr().add(i)),
                );
                acc1 = vfmaq_f32(
                    acc1,
                    vld1q_f32(a.as_ptr().add(i + 4)),
                    vld1q_f32(b.as_ptr().add(i + 4)),
                );
            }
            i += 8;
        }
        if i + 4 <= n {
            // SAFETY: `i + 4 <= n` bounds both loads.
            unsafe {
                acc0 = vfmaq_f32(
                    acc0,
                    vld1q_f32(a.as_ptr().add(i)),
                    vld1q_f32(b.as_ptr().add(i)),
                );
            }
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg64::seed(9);
        for len in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
            let naive: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (x as f64) * (y as f64))
                .sum();
            assert!(
                (dot(&a, &b) as f64 - naive).abs() < 1e-4,
                "len={len}"
            );
        }
    }

    #[test]
    fn dispatched_dot_matches_scalar_within_tolerance() {
        // every vector-width boundary: 16-lane unroll, 8-lane step, tails
        let mut rng = Pcg64::seed(77);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 129, 1000] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
            let want = dot_scalar(&a, &b);
            let got = dot_with(simd::detect(), &a, &b);
            assert!(
                (want - got).abs() <= 1e-4 * (1.0 + want.abs()),
                "len={len}: scalar {want} vs dispatched {got}"
            );
        }
    }

    #[test]
    fn transpose_round_trips() {
        let src: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut t = Vec::new();
        transpose_into(&src, 3, 4, &mut t);
        assert_eq!(t.len(), 12);
        // src[i, j] == t[j, i]
        for i in 0..3 {
            for j in 0..4 {
                assert_eq!(t[j * 3 + i], src[i * 4 + j]);
            }
        }
        let mut back = Vec::new();
        transpose_into(&t, 4, 3, &mut back);
        assert_eq!(back, src);
    }

    #[test]
    fn matmul_matches_naive_triple_loop() {
        let mut rng = Pcg64::seed(31);
        for (n, fi, fo) in [(1usize, 5usize, 3usize), (4, 17, 9), (3, 8, 21)] {
            let x: Vec<f32> = (0..n * fi).map(|_| rng.next_f32() - 0.5).collect();
            let w: Vec<f32> = (0..fi * fo).map(|_| rng.next_f32() - 0.5).collect();
            let bias: Vec<f32> = (0..fo).map(|_| rng.next_f32() - 0.5).collect();
            let mut wt = Vec::new();
            transpose_into(&w, fi, fo, &mut wt);
            let mut out = vec![0f32; n * fo];
            matmul_bias_wt(&x, &wt, &bias, &mut out, n, fi, fo);
            for r in 0..n {
                for j in 0..fo {
                    let mut acc = bias[j] as f64;
                    for i in 0..fi {
                        acc += (x[r * fi + i] as f64) * (w[i * fo + j] as f64);
                    }
                    assert!(
                        (out[r * fo + j] as f64 - acc).abs() < 1e-3,
                        "n={n} fi={fi} fo={fo} r={r} j={j}"
                    );
                }
            }
        }
    }
}
