//! Cache-blocked dense kernels behind the native backend's forward/backward
//! passes.
//!
//! The flat parameter layout stores each dense layer's weights row-major as
//! `W [fan_in, fan_out]`. For the batched `x · W` product the better layout
//! is the transpose `Wᵀ [fan_out, fan_in]`: every output coordinate becomes
//! one dot product of two contiguous vectors, which the 4-lane accumulators
//! in [`dot`] let the compiler vectorize without reassociating a single
//! chain (fp semantics stay deterministic — the summation order is fixed,
//! just not strictly left-to-right). [`matmul_bias_wt`] additionally tiles
//! over output columns so a tile of `Wᵀ` rows stays cache-hot across the
//! whole batch instead of being re-streamed per example.

/// Dot product with four independent accumulators (fixed summation order —
/// bit-identical on every call with the same inputs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let mut i = 0usize;
    while i + 4 <= n {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Transpose a row-major `[rows, cols]` matrix into `dst` as `[cols, rows]`
/// (reuses `dst`'s allocation across calls).
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut Vec<f32>) {
    debug_assert_eq!(src.len(), rows * cols);
    dst.clear();
    dst.resize(rows * cols, 0.0);
    for i in 0..rows {
        let row = &src[i * cols..(i + 1) * cols];
        for (j, &v) in row.iter().enumerate() {
            dst[j * rows + i] = v;
        }
    }
}

/// How many transposed weight rows to keep hot per tile: 8 rows of a
/// 784-wide LeNet layer is ~25 KB — comfortably L1/L2 resident.
const COL_TILE: usize = 8;

/// `out[r, j] = bias[j] + x[r, :] · wt[j, :]` for `r < n`, `j < fo`, with
/// `wt` the transposed weights `[fo, fi]`. Tiled over `j` so a tile of `wt`
/// is reused across the whole batch.
pub fn matmul_bias_wt(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    out: &mut [f32],
    n: usize,
    fi: usize,
    fo: usize,
) {
    debug_assert_eq!(x.len(), n * fi);
    debug_assert_eq!(wt.len(), fi * fo);
    debug_assert_eq!(bias.len(), fo);
    debug_assert_eq!(out.len(), n * fo);
    let mut j0 = 0usize;
    while j0 < fo {
        let j1 = (j0 + COL_TILE).min(fo);
        for r in 0..n {
            let xrow = &x[r * fi..(r + 1) * fi];
            let orow = &mut out[r * fo..(r + 1) * fo];
            for j in j0..j1 {
                orow[j] = bias[j] + dot(xrow, &wt[j * fi..(j + 1) * fi]);
            }
        }
        j0 = j1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg64::seed(9);
        for len in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
            let naive: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (x as f64) * (y as f64))
                .sum();
            assert!(
                (dot(&a, &b) as f64 - naive).abs() < 1e-4,
                "len={len}"
            );
        }
    }

    #[test]
    fn transpose_round_trips() {
        let src: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut t = Vec::new();
        transpose_into(&src, 3, 4, &mut t);
        assert_eq!(t.len(), 12);
        // src[i, j] == t[j, i]
        for i in 0..3 {
            for j in 0..4 {
                assert_eq!(t[j * 3 + i], src[i * 4 + j]);
            }
        }
        let mut back = Vec::new();
        transpose_into(&t, 4, 3, &mut back);
        assert_eq!(back, src);
    }

    #[test]
    fn matmul_matches_naive_triple_loop() {
        let mut rng = Pcg64::seed(31);
        for (n, fi, fo) in [(1usize, 5usize, 3usize), (4, 17, 9), (3, 8, 21)] {
            let x: Vec<f32> = (0..n * fi).map(|_| rng.next_f32() - 0.5).collect();
            let w: Vec<f32> = (0..fi * fo).map(|_| rng.next_f32() - 0.5).collect();
            let bias: Vec<f32> = (0..fo).map(|_| rng.next_f32() - 0.5).collect();
            let mut wt = Vec::new();
            transpose_into(&w, fi, fo, &mut wt);
            let mut out = vec![0f32; n * fo];
            matmul_bias_wt(&x, &wt, &bias, &mut out, n, fi, fo);
            for r in 0..n {
                for j in 0..fo {
                    let mut acc = bias[j] as f64;
                    for i in 0..fi {
                        acc += (x[r * fi + i] as f64) * (w[i * fo + j] as f64);
                    }
                    assert!(
                        (out[r * fo + j] as f64 - acc).abs() < 1e-3,
                        "n={n} fi={fi} fo={fo} r={r} j={j}"
                    );
                }
            }
        }
    }
}
