//! `.mrc` compressed-model container. Byte-level spec: `docs/mrc-format.md`.
//!
//! A MIRACLE-compressed model is fully determined by (Algorithm 1's decode
//! step): the model config name (which pins the backend's shared candidate
//! generator), the layout seed (hashing trick + block permutation), the
//! protocol seed (the candidate-stream base key — jax threefry on the PJRT
//! backend, [`crate::prng::candidate_stream`] on the native one), the
//! per-layer encoding stddevs σ_p, the local budget `C_loc` in bits, and one
//! `C_loc`-bit index per block. Everything else is replayed
//! deterministically; the index payload is the Vitányi–Li "transmit the
//! index of the sample" code.
//!
//! Layout (byte-aligned header, then a packed bit payload):
//!
//! ```text
//! magic "MRC1"
//! varint  name_len, name bytes
//! u64     layout_seed
//! u32     protocol_seed (candidate-stream base key)
//! u8      backend family (0 = native, 1 = pjrt)
//! varint  B, S, k_chunk
//! u8      c_loc_bits
//! varint  n_layers, then n_layers * f32 (log sigma_p)
//! payload: B indices, c_loc_bits each (MSB first)
//! ```

use crate::bitstream::{BitReader, BitWriter};
use crate::util::{Error, Result};
use crate::{ensure, err};

pub const MAGIC: &[u8; 4] = b"MRC1";

/// Split a transmitted candidate index into `(chunk, row-within-chunk)` for
/// a given scoring chunk size. The payload's index space is flat — chunking
/// is an execution detail — but encoder, decoder and server must agree on
/// this mapping, so it lives here next to the container spec.
pub fn chunk_and_row(index: u64, k_chunk: usize) -> (u64, usize) {
    let k = k_chunk.max(1) as u64;
    (index / k, (index % k) as usize)
}

/// The backend family that encoded a container. Families use different
/// candidate generators (jax threefry vs the Pcg64 seed tree), so decoding
/// on the wrong family would silently produce garbage weights — the tag
/// turns that into a hard error at load time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendFamily {
    Native,
    Pjrt,
}

impl BackendFamily {
    pub fn code(self) -> u8 {
        match self {
            BackendFamily::Native => 0,
            BackendFamily::Pjrt => 1,
        }
    }

    pub fn from_code(code: u8) -> Result<BackendFamily> {
        match code {
            0 => Ok(BackendFamily::Native),
            1 => Ok(BackendFamily::Pjrt),
            other => err!("unknown backend family code {other}"),
        }
    }

}

/// In-memory form of a compressed model.
#[derive(Debug, Clone, PartialEq)]
pub struct MrcFile {
    pub model: String,
    pub layout_seed: u64,
    pub protocol_seed: i32,
    /// backend family whose candidate stream encoded the payload
    pub backend: BackendFamily,
    pub b: usize,
    pub s: usize,
    pub k_chunk: usize,
    pub c_loc_bits: u8,
    /// per-layer log sigma_p (frozen at encode time)
    pub lsp: Vec<f32>,
    /// transmitted sample index k* per block
    pub indices: Vec<u64>,
}

impl MrcFile {
    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = BitWriter::new();
        for &b in MAGIC {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(self.model.len() as u64);
        for &b in self.model.as_bytes() {
            w.write_bits(b as u64, 8);
        }
        w.write_bits(self.layout_seed, 64);
        w.write_bits(self.protocol_seed as u32 as u64, 32);
        w.write_bits(self.backend.code() as u64, 8);
        w.write_varint(self.b as u64);
        w.write_varint(self.s as u64);
        w.write_varint(self.k_chunk as u64);
        w.write_bits(self.c_loc_bits as u64, 8);
        w.write_varint(self.lsp.len() as u64);
        for &v in &self.lsp {
            w.write_bits(v.to_bits() as u64, 32);
        }
        for &idx in &self.indices {
            w.write_bits(idx, self.c_loc_bits as u32);
        }
        w.finish()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<MrcFile> {
        let mut r = BitReader::new(bytes);
        let mut magic = [0u8; 4];
        for m in magic.iter_mut() {
            *m = r.read_bits(8)? as u8;
        }
        ensure!(&magic == MAGIC, "not an MRC file (magic {magic:?})");
        let name_len = r.read_varint()? as usize;
        ensure!(name_len < 4096, "unreasonable name length {name_len}");
        let mut name = Vec::with_capacity(name_len);
        for _ in 0..name_len {
            name.push(r.read_bits(8)? as u8);
        }
        let model = String::from_utf8(name)
            .map_err(|_| Error::msg("bad model name encoding"))?;
        let layout_seed = r.read_bits(64)?;
        let protocol_seed = r.read_bits(32)? as u32 as i32;
        let backend = BackendFamily::from_code(r.read_bits(8)? as u8)?;
        let b = r.read_varint()? as usize;
        let s = r.read_varint()? as usize;
        let k_chunk = r.read_varint()? as usize;
        let c_loc_bits = r.read_bits(8)? as u8;
        ensure!(
            (1..=63).contains(&c_loc_bits),
            "bad c_loc_bits {c_loc_bits}"
        );
        let n_layers = r.read_varint()? as usize;
        ensure!(n_layers < 1024, "unreasonable layer count {n_layers}");
        let mut lsp = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            lsp.push(f32::from_bits(r.read_bits(32)? as u32));
        }
        let mut indices = Vec::with_capacity(b);
        for _ in 0..b {
            indices.push(r.read_bits(c_loc_bits as u32)?);
        }
        Ok(MrcFile {
            model,
            layout_seed,
            protocol_seed,
            backend,
            b,
            s,
            k_chunk,
            c_loc_bits,
            lsp,
            indices,
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<MrcFile> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::msg(format!("read {path}: {e}")))?;
        MrcFile::from_bytes(&bytes)
    }

    /// Total size in bits (header + payload) — the number Table 1 reports.
    pub fn total_bits(&self) -> usize {
        self.to_bytes().len() * 8
    }

    /// Payload-only size (B * C_loc bits) — the information-theoretic part.
    pub fn payload_bits(&self) -> usize {
        self.b * self.c_loc_bits as usize
    }

    /// Full load-time validation: geometry against the model metadata plus
    /// the backend-family check — a container only decodes on the family
    /// whose candidate stream encoded it.
    pub fn validate_for(
        &self,
        meta: &crate::runtime::ModelMeta,
        family: BackendFamily,
    ) -> Result<()> {
        self.validate(meta)?;
        ensure!(
            self.backend == family,
            "container was encoded on the {:?} backend family but this \
             model runs on {family:?} — candidate streams differ, decode \
             would produce garbage",
            self.backend
        );
        Ok(())
    }

    /// Geometry sanity checks against runtime metadata.
    pub fn validate(&self, meta: &crate::runtime::ModelMeta) -> Result<()> {
        ensure!(self.model == meta.name, "model mismatch: {} vs {}", self.model, meta.name);
        ensure!(self.b == meta.b && self.s == meta.s, "block geometry mismatch");
        ensure!(self.k_chunk == meta.k_chunk, "k_chunk mismatch");
        ensure!(self.lsp.len() == meta.n_layers, "layer count mismatch");
        ensure!(self.indices.len() == self.b, "index count mismatch");
        let k = 1u64 << self.c_loc_bits;
        for (i, &idx) in self.indices.iter().enumerate() {
            if idx >= k {
                return err!("block {i}: index {idx} out of range K={k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop;

    fn sample() -> MrcFile {
        MrcFile {
            model: "tiny_mlp".into(),
            layout_seed: 0xDEAD_BEEF_CAFE_F00D,
            protocol_seed: -7,
            backend: BackendFamily::Native,
            b: 22,
            s: 8,
            k_chunk: 64,
            c_loc_bits: 12,
            lsp: vec![-1.5, -2.25],
            indices: (0..22).map(|i| (i * 37) % 4096).collect(),
        }
    }

    #[test]
    fn round_trip() {
        let m = sample();
        let m2 = MrcFile::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(MrcFile::from_bytes(&bytes).is_err());
    }

    #[test]
    fn size_accounting() {
        let m = sample();
        assert_eq!(m.payload_bits(), 22 * 12);
        assert!(m.total_bits() > m.payload_bits());
        // header overhead is small
        assert!(m.total_bits() < m.payload_bits() + 400);
    }

    #[test]
    fn random_round_trips() {
        quickprop::check("mrc round trip", 40, |g| {
            let b = g.usize_in(1, 200);
            let bits = g.usize_in(1, 24) as u8;
            let m = MrcFile {
                model: "m".into(),
                layout_seed: g.rng.next_u64(),
                protocol_seed: g.rng.next_u32() as i32,
                backend: if g.rng.next_u64() & 1 == 0 {
                    BackendFamily::Native
                } else {
                    BackendFamily::Pjrt
                },
                b,
                s: g.usize_in(1, 64),
                k_chunk: 1 << g.usize_in(0, 12),
                c_loc_bits: bits,
                lsp: (0..g.usize_in(1, 5)).map(|_| g.f32_in(-5.0, 1.0)).collect(),
                indices: (0..b)
                    .map(|_| g.rng.next_u64() & ((1u64 << bits) - 1))
                    .collect(),
            };
            let m2 = MrcFile::from_bytes(&m.to_bytes()).unwrap();
            assert_eq!(m, m2);
        });
    }

    #[test]
    fn truncated_fails() {
        let bytes = sample().to_bytes();
        assert!(MrcFile::from_bytes(&bytes[..bytes.len() - 2]).is_err());
    }

    fn meta_for(m: &MrcFile) -> crate::runtime::ModelMeta {
        crate::runtime::ModelMeta {
            name: m.model.clone(),
            b: m.b,
            s: m.s,
            k_chunk: m.k_chunk,
            n_total: 172,
            n_slots: 172,
            n_layers: m.lsp.len(),
            layer_slots: vec![136, 36],
            layer_counts: vec![136, 36],
            batch: 32,
            eval_batch: 64,
            classes: 4,
            input_shape: vec![16],
        }
    }

    #[test]
    fn validate_accepts_matching_meta() {
        let m = sample();
        m.validate(&meta_for(&m)).unwrap();
    }

    #[test]
    fn validate_rejects_wrong_model() {
        let m = sample();
        let mut meta = meta_for(&m);
        meta.name = "other".into();
        assert!(m.validate(&meta).is_err());
    }

    #[test]
    fn validate_rejects_geometry_mismatch() {
        let m = sample();
        let mut meta = meta_for(&m);
        meta.s += 1;
        assert!(m.validate(&meta).is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_index() {
        let mut m = sample();
        m.indices[3] = 1 << m.c_loc_bits; // == K, out of range
        assert!(m.validate(&meta_for(&m)).is_err());
    }

    #[test]
    fn validate_rejects_layer_count_mismatch() {
        let m = sample();
        let mut meta = meta_for(&m);
        meta.n_layers = 5;
        assert!(m.validate(&meta).is_err());
    }

    #[test]
    fn validate_for_rejects_wrong_backend_family() {
        let m = sample();
        let meta = meta_for(&m);
        m.validate_for(&meta, BackendFamily::Native).unwrap();
        let err = m.validate_for(&meta, BackendFamily::Pjrt).unwrap_err();
        assert!(format!("{err}").contains("backend family"), "{err}");
    }

    #[test]
    fn chunk_and_row_covers_the_flat_index_space() {
        assert_eq!(chunk_and_row(0, 64), (0, 0));
        assert_eq!(chunk_and_row(63, 64), (0, 63));
        assert_eq!(chunk_and_row(64, 64), (1, 0));
        assert_eq!(chunk_and_row(4095, 256), (15, 255));
        // K smaller than one chunk: everything lands in chunk 0
        assert_eq!(chunk_and_row(5, 64), (0, 5));
    }

    #[test]
    fn backend_family_codes_round_trip() {
        for f in [BackendFamily::Native, BackendFamily::Pjrt] {
            assert_eq!(BackendFamily::from_code(f.code()).unwrap(), f);
        }
        assert!(BackendFamily::from_code(7).is_err());
    }
}
