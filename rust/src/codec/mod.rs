//! `.mrc` compressed-model container. Byte-level spec: `docs/mrc-format.md`.
//!
//! A MIRACLE-compressed model is fully determined by (Algorithm 1's decode
//! step): the model config name (which pins the backend's shared candidate
//! generator), the layout seed (hashing trick + block permutation), the
//! protocol seed (the candidate-stream base key — jax threefry on the PJRT
//! backend, [`crate::prng::candidate_stream`] on the native one), the
//! per-layer encoding stddevs σ_p, the local budget `C_loc` in bits, and one
//! `C_loc`-bit index per block. Everything else is replayed
//! deterministically; the index payload is the Vitányi–Li "transmit the
//! index of the sample" code.
//!
//! Because the file *is* the model, a single undetected bit flip in the
//! index stream silently replays the wrong candidate and decodes a
//! plausible-but-wrong network. The current container revision (`MRC2`)
//! therefore carries a CRC-32 over the header and one CRC-32 per
//! [`PAYLOAD_PAGE_BYTES`]-sized page of the packed index payload; readers
//! verify both before any index is trusted, and every header-declared
//! length is bounds-checked against the actual file size before any
//! allocation. Legacy `MRC1` files (no integrity section) remain readable.
//!
//! v2 layout (byte-aligned header, then a packed bit payload):
//!
//! ```text
//! magic "MRC2"
//! varint  name_len, name bytes
//! u64     layout_seed
//! u32     protocol_seed (candidate-stream base key)
//! u8      backend family (0 = native, 1 = pjrt)
//! varint  B, S, k_chunk
//! u8      c_loc_bits
//! varint  n_layers, then n_layers * f32 (log sigma_p)
//! u32     header CRC-32 (over every preceding byte)
//! n_pages * u32  payload page CRC-32s (n_pages = ceil(payload_bytes/1024))
//! payload: B indices, c_loc_bits each (MSB first), zero-padded to a byte
//! ```
//!
//! Malformed input is reported through the structured [`MrcError`] type so
//! callers (CLI, server, tests) can give a one-line diagnosis instead of a
//! low-level parse trace.

use crate::bitstream::{BitReader, BitWriter};
use crate::util::crc32::crc32;
use crate::util::{Error, Result};
use crate::{ensure, err};

/// Current container magic (format revision 2: CRC-protected).
pub const MAGIC: &[u8; 4] = b"MRC2";
/// Legacy magic (revision 1: no integrity section). Still readable.
pub const MAGIC_V1: &[u8; 4] = b"MRC1";

/// Payload bytes covered by one payload CRC-32. A page spans
/// `⌈8·1024/C_loc⌉` blocks, so a page-CRC mismatch localizes corruption to
/// that block range; for small models the whole payload is one page and the
/// integrity section costs 8 bytes total (header CRC + one page CRC).
pub const PAYLOAD_PAGE_BYTES: usize = 1024;

/// Structured decode/load failure for `.mrc` containers. Every variant
/// renders as a one-line diagnosis; none of them can be produced by a panic
/// or an unbounded allocation — malformed input of any shape (truncation,
/// bit flips, hostile length fields) lands here instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MrcError {
    /// Reading the file itself failed.
    Io { path: String, detail: String },
    /// The first four bytes are not an MRC magic.
    NotMrc { found: [u8; 4] },
    /// The buffer ended before the declared content did.
    Truncated,
    /// A header-declared length/count does not fit the actual file size
    /// (checked before any allocation).
    Bounds { field: &'static str, declared: u64, limit: u64 },
    /// Header bytes fail their CRC — seeds/geometry cannot be trusted.
    HeaderCrc { stored: u32, computed: u32 },
    /// A payload page fails its CRC — the index stream is corrupt within
    /// the given block range `[blocks.0, blocks.1)`.
    PayloadCrc { page: usize, blocks: (u64, u64), stored: u32, computed: u32 },
    /// Bytes remain after the declared content (e.g. a v2 file whose magic
    /// was damaged into a v1 magic, or appended garbage).
    TrailingGarbage { extra_bits: usize },
    /// Anything else structurally wrong (bad UTF-8 name, unknown backend
    /// code, out-of-range field values).
    Malformed(String),
}

impl std::fmt::Display for MrcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MrcError::Io { path, detail } => write!(f, "read {path}: {detail}"),
            MrcError::NotMrc { found } => {
                write!(f, "not an MRC file (magic {found:?})")
            }
            MrcError::Truncated => {
                write!(f, "container truncated: ran out of bytes mid-field")
            }
            MrcError::Bounds { field, declared, limit } => write!(
                f,
                "header declares {field} = {declared} but the file can hold \
                 at most {limit} — refusing to allocate"
            ),
            MrcError::HeaderCrc { stored, computed } => write!(
                f,
                "header CRC mismatch (stored {stored:#010x}, computed \
                 {computed:#010x}) — header bytes are corrupt"
            ),
            MrcError::PayloadCrc { page, blocks, stored, computed } => write!(
                f,
                "payload page {page} CRC mismatch (blocks {}..{}, stored \
                 {stored:#010x}, computed {computed:#010x}) — index stream \
                 is corrupt",
                blocks.0, blocks.1
            ),
            MrcError::TrailingGarbage { extra_bits } => write!(
                f,
                "{extra_bits} unexpected bits after the declared payload"
            ),
            MrcError::Malformed(m) => write!(f, "malformed container: {m}"),
        }
    }
}

impl std::error::Error for MrcError {}

impl From<MrcError> for Error {
    fn from(e: MrcError) -> Error {
        Error::msg(e.to_string())
    }
}

impl MrcError {
    /// Map a low-level bitstream error onto the structured kinds.
    fn from_read(e: Error) -> MrcError {
        let m = e.to_string();
        if m.contains("exhausted") {
            MrcError::Truncated
        } else {
            MrcError::Malformed(m)
        }
    }
}

pub type MrcResult<T> = std::result::Result<T, MrcError>;

/// Split a transmitted candidate index into `(chunk, row-within-chunk)` for
/// a given scoring chunk size. The payload's index space is flat — chunking
/// is an execution detail — but encoder, decoder and server must agree on
/// this mapping, so it lives here next to the container spec.
pub fn chunk_and_row(index: u64, k_chunk: usize) -> (u64, usize) {
    let k = k_chunk.max(1) as u64;
    (index / k, (index % k) as usize)
}

/// The backend family that encoded a container. Families use different
/// candidate generators (jax threefry vs the Pcg64 seed tree), so decoding
/// on the wrong family would silently produce garbage weights — the tag
/// turns that into a hard error at load time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendFamily {
    Native,
    Pjrt,
}

impl BackendFamily {
    pub fn code(self) -> u8 {
        match self {
            BackendFamily::Native => 0,
            BackendFamily::Pjrt => 1,
        }
    }

    pub fn from_code(code: u8) -> Result<BackendFamily> {
        match code {
            0 => Ok(BackendFamily::Native),
            1 => Ok(BackendFamily::Pjrt),
            other => err!("unknown backend family code {other}"),
        }
    }

}

/// In-memory form of a compressed model.
#[derive(Debug, Clone, PartialEq)]
pub struct MrcFile {
    pub model: String,
    pub layout_seed: u64,
    pub protocol_seed: i32,
    /// backend family whose candidate stream encoded the payload
    pub backend: BackendFamily,
    pub b: usize,
    pub s: usize,
    pub k_chunk: usize,
    pub c_loc_bits: u8,
    /// per-layer log sigma_p (frozen at encode time)
    pub lsp: Vec<f32>,
    /// transmitted sample index k* per block
    pub indices: Vec<u64>,
}

impl MrcFile {
    /// Header fields shared by both revisions (everything between the magic
    /// and the integrity/payload section), byte-aligned.
    fn write_header(&self, w: &mut BitWriter, magic: &[u8; 4]) {
        for &b in magic {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(self.model.len() as u64);
        for &b in self.model.as_bytes() {
            w.write_bits(b as u64, 8);
        }
        w.write_bits(self.layout_seed, 64);
        w.write_bits(self.protocol_seed as u32 as u64, 32);
        w.write_bits(self.backend.code() as u64, 8);
        w.write_varint(self.b as u64);
        w.write_varint(self.s as u64);
        w.write_varint(self.k_chunk as u64);
        w.write_bits(self.c_loc_bits as u64, 8);
        w.write_varint(self.lsp.len() as u64);
        for &v in &self.lsp {
            w.write_bits(v.to_bits() as u64, 32);
        }
    }

    /// The packed index payload: B × c_loc_bits bits, zero-padded to a byte.
    fn payload_bytes(&self) -> Vec<u8> {
        let mut w = BitWriter::new();
        for &idx in &self.indices {
            w.write_bits(idx, self.c_loc_bits as u32);
        }
        w.finish()
    }

    /// Serialize to bytes in the current (v2, CRC-protected) revision.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = BitWriter::new();
        self.write_header(&mut w, MAGIC);
        let mut out = w.finish(); // header is byte-aligned by construction
        let header_crc = crc32(&out);
        out.extend_from_slice(&header_crc.to_be_bytes());
        let payload = self.payload_bytes();
        for page in payload.chunks(PAYLOAD_PAGE_BYTES) {
            out.extend_from_slice(&crc32(page).to_be_bytes());
        }
        out.extend_from_slice(&payload);
        out
    }

    /// Serialize in the legacy v1 layout (no integrity section). Kept for
    /// the golden-format compatibility fixtures and migration tooling; new
    /// files should always use [`MrcFile::to_bytes`].
    pub fn to_bytes_v1(&self) -> Vec<u8> {
        let mut w = BitWriter::new();
        self.write_header(&mut w, MAGIC_V1);
        for &idx in &self.indices {
            w.write_bits(idx, self.c_loc_bits as u32);
        }
        w.finish()
    }

    /// Container revision of a byte buffer (1 or 2) from its magic, without
    /// parsing anything else.
    pub fn version_of(bytes: &[u8]) -> MrcResult<u8> {
        match bytes.get(..4) {
            Some(m) if m == MAGIC_V1 => Ok(1),
            Some(m) if m == MAGIC => Ok(2),
            Some(m) => Err(MrcError::NotMrc { found: [m[0], m[1], m[2], m[3]] }),
            None => Err(MrcError::Truncated),
        }
    }

    pub fn from_bytes(bytes: &[u8]) -> MrcResult<MrcFile> {
        let version = MrcFile::version_of(bytes)?;
        let mut r = BitReader::new(bytes);
        r.read_bits(32).map_err(MrcError::from_read)?; // past the magic

        // --- header fields, every declared size bounded by what the file
        // can actually hold BEFORE the corresponding allocation ---
        let name_len = r.read_varint().map_err(MrcError::from_read)?;
        let name_limit = (r.remaining_bits() / 8).min(4095) as u64;
        if name_len > name_limit {
            return Err(MrcError::Bounds {
                field: "name_len",
                declared: name_len,
                limit: name_limit,
            });
        }
        let mut name = Vec::with_capacity(name_len as usize);
        for _ in 0..name_len {
            name.push(r.read_bits(8).map_err(MrcError::from_read)? as u8);
        }
        let model = String::from_utf8(name)
            .map_err(|_| MrcError::Malformed("bad model name encoding".into()))?;
        let layout_seed = r.read_bits(64).map_err(MrcError::from_read)?;
        let protocol_seed =
            r.read_bits(32).map_err(MrcError::from_read)? as u32 as i32;
        let backend_code = r.read_bits(8).map_err(MrcError::from_read)? as u8;
        let backend = BackendFamily::from_code(backend_code)
            .map_err(|e| MrcError::Malformed(e.to_string()))?;
        let b = r.read_varint().map_err(MrcError::from_read)?;
        let s = r.read_varint().map_err(MrcError::from_read)?;
        let k_chunk = r.read_varint().map_err(MrcError::from_read)?;
        for (field, v) in [("S", s), ("k_chunk", k_chunk)] {
            if v > u32::MAX as u64 {
                return Err(MrcError::Bounds {
                    field,
                    declared: v,
                    limit: u32::MAX as u64,
                });
            }
        }
        let c_loc_bits = r.read_bits(8).map_err(MrcError::from_read)? as u8;
        if !(1..=63).contains(&c_loc_bits) {
            return Err(MrcError::Malformed(format!(
                "bad c_loc_bits {c_loc_bits}"
            )));
        }
        let n_layers = r.read_varint().map_err(MrcError::from_read)?;
        let layer_limit = ((r.remaining_bits() / 32) as u64).min(1023);
        if n_layers > layer_limit {
            return Err(MrcError::Bounds {
                field: "n_layers",
                declared: n_layers,
                limit: layer_limit,
            });
        }
        let mut lsp = Vec::with_capacity(n_layers as usize);
        for _ in 0..n_layers {
            lsp.push(f32::from_bits(
                r.read_bits(32).map_err(MrcError::from_read)? as u32,
            ));
        }

        // payload size implied by the (not yet trusted) header
        let payload_bits = b
            .checked_mul(c_loc_bits as u64)
            .ok_or(MrcError::Bounds { field: "B", declared: b, limit: u64::MAX })?;
        let payload_len = payload_bits.div_ceil(8);

        let indices = if version >= 2 {
            // --- integrity section: header CRC, then per-page payload CRCs ---
            debug_assert_eq!(r.bit_pos() % 8, 0, "header must be byte-aligned");
            let header_end = r.bit_pos() / 8;
            let stored = r.read_bits(32).map_err(MrcError::from_read)? as u32;
            let computed = crc32(&bytes[..header_end]);
            if stored != computed {
                return Err(MrcError::HeaderCrc { stored, computed });
            }
            // header is now authentic: its declared sizes are what the
            // encoder wrote, but the file must still physically hold them
            let n_pages = payload_len.div_ceil(PAYLOAD_PAGE_BYTES as u64);
            let expected_rest = n_pages
                .checked_mul(4)
                .and_then(|v| v.checked_add(payload_len))
                .ok_or(MrcError::Bounds {
                    field: "B",
                    declared: b,
                    limit: u64::MAX,
                })?;
            let rest = (r.remaining_bits() / 8) as u64;
            if expected_rest > rest {
                return Err(MrcError::Bounds {
                    field: "payload",
                    declared: expected_rest,
                    limit: rest,
                });
            }
            if expected_rest < rest {
                return Err(MrcError::TrailingGarbage {
                    extra_bits: (rest - expected_rest) as usize * 8,
                });
            }
            let mut page_crcs = Vec::with_capacity(n_pages as usize);
            for _ in 0..n_pages {
                page_crcs
                    .push(r.read_bits(32).map_err(MrcError::from_read)? as u32);
            }
            let payload_start = r.bit_pos() / 8;
            let payload = &bytes[payload_start..];
            debug_assert_eq!(payload.len() as u64, payload_len);
            for (page, (slice, &stored)) in
                payload.chunks(PAYLOAD_PAGE_BYTES).zip(&page_crcs).enumerate()
            {
                let computed = crc32(slice);
                if stored != computed {
                    let lo = (page * PAYLOAD_PAGE_BYTES) as u64 * 8
                        / c_loc_bits as u64;
                    let end_byte =
                        (page * PAYLOAD_PAGE_BYTES + slice.len()) as u64;
                    let hi = b.min(
                        (end_byte * 8 + c_loc_bits as u64 - 1)
                            / c_loc_bits as u64,
                    );
                    return Err(MrcError::PayloadCrc {
                        page,
                        blocks: (lo, hi),
                        stored,
                        computed,
                    });
                }
            }
            let mut pr = BitReader::new(payload);
            let mut indices = Vec::with_capacity(b as usize);
            for _ in 0..b {
                indices.push(
                    pr.read_bits(c_loc_bits as u32)
                        .map_err(MrcError::from_read)?,
                );
            }
            indices
        } else {
            // --- legacy v1: no integrity section; still refuse to allocate
            // past what the file holds, and reject trailing bytes (a v2
            // container whose magic byte was damaged into "MRC1" would
            // otherwise misparse its CRC section as indices) ---
            if payload_bits > r.remaining_bits() as u64 {
                return Err(MrcError::Bounds {
                    field: "B",
                    declared: b,
                    limit: r.remaining_bits() as u64 / c_loc_bits as u64,
                });
            }
            let mut indices = Vec::with_capacity(b as usize);
            for _ in 0..b {
                indices.push(
                    r.read_bits(c_loc_bits as u32)
                        .map_err(MrcError::from_read)?,
                );
            }
            if r.remaining_bits() >= 8 {
                return Err(MrcError::TrailingGarbage {
                    extra_bits: r.remaining_bits(),
                });
            }
            indices
        };

        Ok(MrcFile {
            model,
            layout_seed,
            protocol_seed,
            backend,
            b: b as usize,
            s: s as usize,
            k_chunk: k_chunk as usize,
            c_loc_bits,
            lsp,
            indices,
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &str) -> MrcResult<MrcFile> {
        let bytes = std::fs::read(path).map_err(|e| MrcError::Io {
            path: path.to_string(),
            detail: e.to_string(),
        })?;
        MrcFile::from_bytes(&bytes)
    }

    /// Total size in bits (header + integrity section + payload) — the
    /// honest on-disk figure Table 1 reports.
    pub fn total_bits(&self) -> usize {
        self.to_bytes().len() * 8
    }

    /// Payload-only size (B * C_loc bits) — the information-theoretic part.
    pub fn payload_bits(&self) -> usize {
        self.b * self.c_loc_bits as usize
    }

    /// Full load-time validation: geometry against the model metadata plus
    /// the backend-family check — a container only decodes on the family
    /// whose candidate stream encoded it.
    pub fn validate_for(
        &self,
        meta: &crate::runtime::ModelMeta,
        family: BackendFamily,
    ) -> Result<()> {
        self.validate(meta)?;
        ensure!(
            self.backend == family,
            "container was encoded on the {:?} backend family but this \
             model runs on {family:?} — candidate streams differ, decode \
             would produce garbage",
            self.backend
        );
        Ok(())
    }

    /// Geometry sanity checks against runtime metadata.
    pub fn validate(&self, meta: &crate::runtime::ModelMeta) -> Result<()> {
        ensure!(self.model == meta.name, "model mismatch: {} vs {}", self.model, meta.name);
        ensure!(self.b == meta.b && self.s == meta.s, "block geometry mismatch");
        ensure!(self.k_chunk == meta.k_chunk, "k_chunk mismatch");
        ensure!(self.lsp.len() == meta.n_layers, "layer count mismatch");
        ensure!(self.indices.len() == self.b, "index count mismatch");
        let k = 1u64 << self.c_loc_bits;
        for (i, &idx) in self.indices.iter().enumerate() {
            if idx >= k {
                return err!("block {i}: index {idx} out of range K={k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop;

    fn sample() -> MrcFile {
        MrcFile {
            model: "tiny_mlp".into(),
            layout_seed: 0xDEAD_BEEF_CAFE_F00D,
            protocol_seed: -7,
            backend: BackendFamily::Native,
            b: 22,
            s: 8,
            k_chunk: 64,
            c_loc_bits: 12,
            lsp: vec![-1.5, -2.25],
            indices: (0..22).map(|i| (i * 37) % 4096).collect(),
        }
    }

    #[test]
    fn round_trip() {
        let m = sample();
        let m2 = MrcFile::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn v1_round_trip_still_supported() {
        let m = sample();
        let bytes = m.to_bytes_v1();
        assert_eq!(MrcFile::version_of(&bytes).unwrap(), 1);
        let m2 = MrcFile::from_bytes(&bytes).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn version_detection() {
        let m = sample();
        assert_eq!(MrcFile::version_of(&m.to_bytes()).unwrap(), 2);
        assert_eq!(MrcFile::version_of(&m.to_bytes_v1()).unwrap(), 1);
        assert!(matches!(
            MrcFile::version_of(b"JUNKJUNK"),
            Err(MrcError::NotMrc { .. })
        ));
        assert_eq!(MrcFile::version_of(b"MR"), Err(MrcError::Truncated));
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(matches!(
            MrcFile::from_bytes(&bytes),
            Err(MrcError::NotMrc { .. })
        ));
    }

    #[test]
    fn size_accounting() {
        let m = sample();
        assert_eq!(m.payload_bits(), 22 * 12);
        assert!(m.total_bits() > m.payload_bits());
        // header + integrity overhead stays small (one page CRC here)
        assert!(m.total_bits() < m.payload_bits() + 400);
    }

    #[test]
    fn random_round_trips() {
        quickprop::check("mrc round trip", 40, |g| {
            let b = g.usize_in(1, 200);
            let bits = g.usize_in(1, 24) as u8;
            let m = MrcFile {
                model: "m".into(),
                layout_seed: g.rng.next_u64(),
                protocol_seed: g.rng.next_u32() as i32,
                backend: if g.rng.next_u64() & 1 == 0 {
                    BackendFamily::Native
                } else {
                    BackendFamily::Pjrt
                },
                b,
                s: g.usize_in(1, 64),
                k_chunk: 1 << g.usize_in(0, 12),
                c_loc_bits: bits,
                lsp: (0..g.usize_in(1, 5)).map(|_| g.f32_in(-5.0, 1.0)).collect(),
                indices: (0..b)
                    .map(|_| g.rng.next_u64() & ((1u64 << bits) - 1))
                    .collect(),
            };
            let m2 = MrcFile::from_bytes(&m.to_bytes()).unwrap();
            assert_eq!(m, m2);
            let m3 = MrcFile::from_bytes(&m.to_bytes_v1()).unwrap();
            assert_eq!(m, m3);
        });
    }

    #[test]
    fn truncated_fails() {
        let bytes = sample().to_bytes();
        assert!(MrcFile::from_bytes(&bytes[..bytes.len() - 2]).is_err());
    }

    #[test]
    fn header_bit_flip_detected() {
        // byte 6 sits inside the model name: without the header CRC this
        // would "just" rename the model; with it, the flip is a hard error
        let mut bytes = sample().to_bytes();
        bytes[6] ^= 0x01;
        assert!(matches!(
            MrcFile::from_bytes(&bytes),
            Err(MrcError::HeaderCrc { .. })
        ));
    }

    #[test]
    fn payload_bit_flip_detected() {
        let m = sample();
        let bytes = m.to_bytes();
        // flip the very last payload byte — in v1 this silently decoded a
        // different candidate for the final block
        let mut mutated = bytes.clone();
        let last = mutated.len() - 1;
        mutated[last] ^= 0x80;
        match MrcFile::from_bytes(&mutated) {
            Err(MrcError::PayloadCrc { page, blocks, .. }) => {
                assert_eq!(page, 0);
                assert_eq!(blocks.1, m.b as u64);
            }
            other => panic!("expected PayloadCrc, got {other:?}"),
        }
    }

    #[test]
    fn magic_downgrade_to_v1_rejected() {
        // damaging the version byte of a v2 file into "MRC1" must not let
        // the CRC section be misparsed as index payload
        let mut bytes = sample().to_bytes();
        assert_eq!(&bytes[..4], MAGIC);
        bytes[3] = b'1';
        assert!(matches!(
            MrcFile::from_bytes(&bytes),
            Err(MrcError::TrailingGarbage { .. })
        ));
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut v2 = sample().to_bytes();
        v2.push(0xAB);
        assert!(matches!(
            MrcFile::from_bytes(&v2),
            Err(MrcError::TrailingGarbage { .. })
        ));
        let mut v1 = sample().to_bytes_v1();
        v1.push(0xAB);
        assert!(matches!(
            MrcFile::from_bytes(&v1),
            Err(MrcError::TrailingGarbage { .. })
        ));
    }

    #[test]
    fn hostile_block_count_refused_before_allocation() {
        // hand-craft a v1 header declaring B = 2^40 blocks in a ~40-byte
        // file: the parser must reject from the size bound, not allocate
        let mut w = BitWriter::new();
        for &b in MAGIC_V1 {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(1);
        w.write_bits(b'm' as u64, 8);
        w.write_bits(0, 64); // layout seed
        w.write_bits(0, 32); // protocol seed
        w.write_bits(0, 8); // backend: native
        w.write_varint(1u64 << 40); // B — hostile
        w.write_varint(8); // S
        w.write_varint(64); // k_chunk
        w.write_bits(12, 8); // c_loc_bits
        w.write_varint(0); // n_layers
        let bytes = w.finish();
        match MrcFile::from_bytes(&bytes) {
            Err(MrcError::Bounds { field, declared, .. }) => {
                assert_eq!(field, "B");
                assert_eq!(declared, 1u64 << 40);
            }
            other => panic!("expected Bounds, got {other:?}"),
        }
    }

    #[test]
    fn hostile_name_length_refused_before_allocation() {
        let mut w = BitWriter::new();
        for &b in MAGIC {
            w.write_bits(b as u64, 8);
        }
        w.write_varint(u64::MAX >> 1); // name_len — hostile
        let bytes = w.finish();
        assert!(matches!(
            MrcFile::from_bytes(&bytes),
            Err(MrcError::Bounds { field: "name_len", .. })
        ));
    }

    #[test]
    fn error_display_is_one_line() {
        let m = sample();
        let mut bytes = m.to_bytes();
        let last = bytes.len() - 1;
        bytes[last] ^= 1;
        let e = MrcFile::from_bytes(&bytes).unwrap_err();
        let msg = e.to_string();
        assert!(!msg.contains('\n'), "multi-line diagnosis: {msg}");
        assert!(msg.contains("CRC"), "{msg}");
    }

    fn meta_for(m: &MrcFile) -> crate::runtime::ModelMeta {
        crate::runtime::ModelMeta {
            name: m.model.clone(),
            b: m.b,
            s: m.s,
            k_chunk: m.k_chunk,
            n_total: 172,
            n_slots: 172,
            n_layers: m.lsp.len(),
            layer_slots: vec![136, 36],
            layer_counts: vec![136, 36],
            batch: 32,
            eval_batch: 64,
            classes: 4,
            input_shape: vec![16],
        }
    }

    #[test]
    fn validate_accepts_matching_meta() {
        let m = sample();
        m.validate(&meta_for(&m)).unwrap();
    }

    #[test]
    fn validate_rejects_wrong_model() {
        let m = sample();
        let mut meta = meta_for(&m);
        meta.name = "other".into();
        assert!(m.validate(&meta).is_err());
    }

    #[test]
    fn validate_rejects_geometry_mismatch() {
        let m = sample();
        let mut meta = meta_for(&m);
        meta.s += 1;
        assert!(m.validate(&meta).is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_index() {
        let mut m = sample();
        m.indices[3] = 1 << m.c_loc_bits; // == K, out of range
        assert!(m.validate(&meta_for(&m)).is_err());
    }

    #[test]
    fn validate_rejects_layer_count_mismatch() {
        let m = sample();
        let mut meta = meta_for(&m);
        meta.n_layers = 5;
        assert!(m.validate(&meta).is_err());
    }

    #[test]
    fn validate_for_rejects_wrong_backend_family() {
        let m = sample();
        let meta = meta_for(&m);
        m.validate_for(&meta, BackendFamily::Native).unwrap();
        let err = m.validate_for(&meta, BackendFamily::Pjrt).unwrap_err();
        assert!(format!("{err}").contains("backend family"), "{err}");
    }

    #[test]
    fn chunk_and_row_covers_the_flat_index_space() {
        assert_eq!(chunk_and_row(0, 64), (0, 0));
        assert_eq!(chunk_and_row(63, 64), (0, 63));
        assert_eq!(chunk_and_row(64, 64), (1, 0));
        assert_eq!(chunk_and_row(4095, 256), (15, 255));
        // K smaller than one chunk: everything lands in chunk 0
        assert_eq!(chunk_and_row(5, 64), (0, 5));
    }

    #[test]
    fn backend_family_codes_round_trip() {
        for f in [BackendFamily::Native, BackendFamily::Pjrt] {
            assert_eq!(BackendFamily::from_code(f.code()).unwrap(), f);
        }
        assert!(BackendFamily::from_code(7).is_err());
    }

    #[test]
    fn multi_page_payload_round_trips_and_localizes_corruption() {
        // enough blocks that the packed payload spans several CRC pages
        let bits = 16u8;
        let b = 2048; // 2048 * 16 bits = 4096 bytes = 4 pages
        let m = MrcFile {
            model: "paged".into(),
            layout_seed: 1,
            protocol_seed: 2,
            backend: BackendFamily::Native,
            b,
            s: 4,
            k_chunk: 64,
            c_loc_bits: bits,
            lsp: vec![-1.0],
            indices: (0..b as u64).map(|i| i % (1 << bits)).collect(),
        };
        let bytes = m.to_bytes();
        assert_eq!(MrcFile::from_bytes(&bytes).unwrap(), m);
        // corrupt a byte in the third payload page
        let payload_len = (b * bits as usize).div_ceil(8);
        let payload_start = bytes.len() - payload_len;
        let mut mutated = bytes.clone();
        mutated[payload_start + 2 * PAYLOAD_PAGE_BYTES + 10] ^= 0x40;
        match MrcFile::from_bytes(&mutated) {
            Err(MrcError::PayloadCrc { page, blocks, .. }) => {
                assert_eq!(page, 2);
                // 2 bytes per index: page 2 covers blocks [1024, 1536)
                assert_eq!(blocks, (1024, 1536));
            }
            other => panic!("expected PayloadCrc on page 2, got {other:?}"),
        }
    }
}
