//! Inference server over a compressed model — the paper's §5 future-work
//! "inference machine which is able to directly run our compressed models".
//!
//! Requests are classification queries; the server decodes the `.mrc` via
//! the shared-randomness generator (eagerly at startup, or block-by-block on
//! demand in lazy mode), then serves batched forward passes through the
//! backend's `eval_batch` entry point.
//!
//! Threading model: backend handles are not assumed `Send` (PJRT's are
//! not), so the executor stays on the thread that built it; clients run on
//! their own threads and talk to the server loop over an mpsc channel
//! (router + dynamic batcher pattern).
//!
//! Resilience model (`docs/serving.md` has the full picture):
//!
//! - **Admission control** — the unbounded transport channel is drained
//!   eagerly into a *bounded* pending queue ([`ServerCfg::queue_depth`]).
//!   Overflow is shed explicitly per [`ShedPolicy`]: `Reject` bounces the
//!   arriving request, `Oldest` evicts the head (freshest-wins). Every shed
//!   is answered with [`ServeError::Overloaded`] and counted.
//! - **Retry** — lazy decode and batched exec are wrapped in
//!   [`retry_with`] (exponential backoff, seeded jitter, budget-capped), so
//!   a transient backend hiccup costs milliseconds, not a failed batch.
//! - **Circuit breaker** — repeated decode/exec failures trip a
//!   [`Breaker`]; while Open the loop degrades to fast
//!   [`ServeError::BreakerOpen`] answers instead of burning a retry budget
//!   per batch, then HalfOpen probes restore service.
//! - **Hot reload** — a [`ReloadRequest`] channel (fed directly or by
//!   [`spawn_mtime_watcher`]) delivers candidate `.mrc` bytes; they go
//!   through the full MRC2 CRC parse + geometry validation + complete
//!   decode *before* the atomic swap, so a corrupt push can never take down
//!   serving — the last-known-good model keeps answering.
//!
//! Degradation model: the serve loop never dies because of one bad input.
//! Malformed requests, overload sheds, deadline overruns, decode failures,
//! backend errors and breaker fast-fails are all reported to the *affected*
//! clients as structured [`Response::Err`] values while the loop keeps
//! serving everyone else; every admitted request receives exactly one
//! `Response` ([`ServeStats::check_invariant`] pins the accounting). The
//! only way `run` returns is the request channel closing (or a startup-time
//! invariant failing before any request is taken). [`ServerFaults`] injects
//! decode/exec faults and deterministic chaos schedules for tests.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::codec::MrcFile;
use crate::coordinator::encoder::decode_single_block;
use crate::model::Layout;
use crate::obs::{self, Hist, HistSummary, Level as Ev};
use crate::runtime::{DeviceBuf, Input, ModelArtifacts};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::breaker::{Breaker, BreakerCfg, State as BreakerState};
use crate::util::faultline::ChaosSchedule;
use crate::util::json::Json;
use crate::util::retry::{retry_with, RetryPolicy};
use crate::util::Result;
use crate::{ensure, err, info, obs_event};

/// One inference request: a flattened input example.
pub struct Request {
    pub x: Vec<f32>,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

/// What a client gets back: a prediction, or a structured serving error.
/// Errors never wedge the reply channel — every admitted request receives
/// exactly one `Response`.
#[derive(Debug, Clone)]
pub enum Response {
    Ok(Prediction),
    Err(ServeError),
}

impl Response {
    pub fn is_ok(&self) -> bool {
        matches!(self, Response::Ok(_))
    }

    pub fn prediction(&self) -> Option<&Prediction> {
        match self {
            Response::Ok(p) => Some(p),
            Response::Err(_) => None,
        }
    }

    pub fn error(&self) -> Option<&ServeError> {
        match self {
            Response::Ok(_) => None,
            Response::Err(e) => Some(e),
        }
    }
}

/// Prediction + timing.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub latency: Duration,
}

/// Structured per-request failure. The variant tells the client whether the
/// fault was theirs (`BadRequest`), load-induced (`Overloaded`,
/// `DeadlineExceeded` — back off and resend) or server-side (`DecodeFailed`,
/// `ExecFailed`, `BreakerOpen` — retryable once the operator replaces the
/// corrupt container / unwedges the backend / the breaker cools down).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself is malformed (wrong feature dimension).
    BadRequest(String),
    /// The bounded admission queue was full; this request (or, under
    /// [`ShedPolicy::Oldest`], the queue head it displaced) was shed.
    Overloaded { depth: usize },
    /// The request waited longer than [`ServerCfg::deadline`] before its
    /// batch was admitted; it was shed rather than served stale.
    DeadlineExceeded { waited: Duration, deadline: Duration },
    /// Lazily decoding the `.mrc` failed (corrupt container, injected
    /// fault) even after retries. The loop stays alive and later requests
    /// retry the decode.
    DecodeFailed(String),
    /// The backend rejected or failed the batched forward pass even after
    /// retries.
    ExecFailed(String),
    /// The circuit breaker is Open after repeated backend failures; the
    /// request was failed fast instead of queuing behind a broken backend.
    /// `retry_after` is the remaining cooldown.
    BreakerOpen { retry_after: Duration },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadRequest(m) => write!(f, "bad request: {m}"),
            ServeError::Overloaded { depth } => {
                write!(f, "overloaded: admission queue full ({depth} deep)")
            }
            ServeError::DeadlineExceeded { waited, deadline } => write!(
                f,
                "deadline exceeded: waited {:.1}ms against a {:.1}ms budget",
                waited.as_secs_f64() * 1e3,
                deadline.as_secs_f64() * 1e3
            ),
            ServeError::DecodeFailed(m) => write!(f, "model decode failed: {m}"),
            ServeError::ExecFailed(m) => write!(f, "execution failed: {m}"),
            ServeError::BreakerOpen { retry_after } => write!(
                f,
                "circuit breaker open: retry after {:.0}ms",
                retry_after.as_secs_f64() * 1e3
            ),
        }
    }
}

/// What to shed when the bounded admission queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Bounce the arriving request (protects queued work; default).
    #[default]
    Reject,
    /// Evict the queue head to admit the arrival (freshest-wins — the
    /// oldest request is the most likely to miss its deadline anyway).
    Oldest,
}

impl std::str::FromStr for ShedPolicy {
    type Err = crate::util::Error;

    fn from_str(s: &str) -> Result<ShedPolicy> {
        match s {
            "reject" => Ok(ShedPolicy::Reject),
            "oldest" => Ok(ShedPolicy::Oldest),
            other => err!("unknown shed policy '{other}' (reject|oldest)"),
        }
    }
}

/// Test-only fault injection, threaded through [`ServerCfg`]. Defaults are
/// inert; production paths never set them. Compiled unconditionally so the
/// corruption/robustness suites, `rust/tests/server_resilience.rs` and
/// `miracle chaos-serve` exercise the exact shipping code paths rather than
/// a cfg(test) twin.
#[derive(Debug, Clone, Default)]
pub struct ServerFaults {
    /// Fail this many upcoming block decodes with an injected error before
    /// behaving normally again (simulates a transiently corrupt container).
    /// Consumed per *attempt*, so the retry layer is exercised too.
    pub fail_decodes: usize,
    /// Fail this many upcoming batched exec attempts (consumed per attempt,
    /// like `fail_decodes`).
    pub fail_execs: usize,
    /// Sleep this long before every batched execution (simulates a slow or
    /// overloaded backend so deadline shedding can be observed).
    pub exec_delay: Duration,
    /// Deterministic time-based chaos (intermittent exec failures, outage
    /// windows, latency spikes), keyed by batch tick.
    pub schedule: ChaosSchedule,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// max requests folded into one eval_batch invocation (capped by the
    /// artifact's eval_batch size)
    pub max_batch: usize,
    /// how long to wait for more requests before running a partial batch
    pub batch_window: Duration,
    /// decode blocks on first use instead of at startup
    pub lazy_decode: bool,
    /// per-request admission deadline: a request still queued after this
    /// long is answered with [`ServeError::DeadlineExceeded`] instead of
    /// being served stale (load shedding)
    pub deadline: Duration,
    /// bounded pending-queue depth; overflow is shed per [`ShedPolicy`]
    pub queue_depth: usize,
    /// what to shed when the queue is full
    pub shed: ShedPolicy,
    /// backoff for transient decode/exec failures
    pub retry: RetryPolicy,
    /// circuit-breaker thresholds for repeated decode/exec failures
    pub breaker: BreakerCfg,
    /// how often the loop checks the reload channel while idle (only
    /// matters once a reload channel is attached)
    pub reload_poll: Duration,
    /// print a one-line heartbeat (qps, queue depth, p95, breaker state)
    /// on this interval; `Duration::ZERO` (the default) disables it
    pub heartbeat: Duration,
    /// fault injection hooks (inert by default)
    pub faults: ServerFaults,
}

impl Default for ServerCfg {
    fn default() -> ServerCfg {
        ServerCfg {
            max_batch: usize::MAX,
            batch_window: Duration::from_millis(2),
            lazy_decode: false,
            deadline: Duration::from_secs(30),
            queue_depth: 1024,
            shed: ShedPolicy::Reject,
            retry: RetryPolicy::default(),
            breaker: BreakerCfg::default(),
            reload_poll: Duration::from_millis(20),
            heartbeat: Duration::ZERO,
            faults: ServerFaults::default(),
        }
    }
}

/// Shed counters, by reason. Sheds are *admission-side* refusals: the
/// request was never handed to the backend.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShedReasons {
    /// bounced by the bounded queue ([`ServeError::Overloaded`])
    pub overloaded: usize,
    /// exceeded [`ServerCfg::deadline`] while queued
    pub deadline: usize,
    /// malformed ([`ServeError::BadRequest`])
    pub bad_request: usize,
}

impl ShedReasons {
    pub fn total(&self) -> usize {
        self.overloaded + self.deadline + self.bad_request
    }
}

/// Error counters, by reason. Errors are *execution-side* failures: the
/// request was admitted but the serving machinery failed it.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ErrorReasons {
    /// lazy decode failed after retries ([`ServeError::DecodeFailed`])
    pub decode: usize,
    /// backend exec failed after retries ([`ServeError::ExecFailed`])
    pub exec: usize,
    /// failed fast while the breaker was Open ([`ServeError::BreakerOpen`])
    pub breaker: usize,
}

impl ErrorReasons {
    pub fn total(&self) -> usize {
        self.decode + self.exec + self.breaker
    }
}

/// Aggregate serving statistics.
///
/// Accounting invariant (see [`ServeStats::check_invariant`]):
/// `accepted == served + rejected + errored` — every request pulled off the
/// transport channel gets exactly one terminal outcome.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// requests pulled off the transport channel
    pub accepted: usize,
    /// requests answered with a prediction
    pub served: usize,
    pub batches: usize,
    /// admission-side sheds (`== sheds.total()`)
    pub rejected: usize,
    /// execution-side failures (`== errors.total()`)
    pub errored: usize,
    pub sheds: ShedReasons,
    pub errors: ErrorReasons,
    /// deepest the bounded pending queue ever got
    pub queue_high_water: usize,
    /// transient decode/exec failures absorbed by backoff
    pub retries: u64,
    /// times the circuit breaker tripped Open
    pub breaker_trips: u64,
    /// hot reloads applied (model swapped)
    pub reloads: usize,
    /// hot reloads refused (kept last-known-good)
    pub reloads_rejected: usize,
    /// end-to-end request latency percentiles (log₂-bucket histogram)
    pub latency: HistSummary,
    /// backend exec time per batch (log₂-bucket histogram)
    pub exec_time: HistSummary,
    pub decode_secs: f64,
    pub wall_secs: f64,
}

impl ServeStats {
    /// Every admitted request must have exactly one terminal outcome, and
    /// the coarse counters must agree with their per-reason breakdowns.
    pub fn check_invariant(&self) -> Result<()> {
        ensure!(
            self.rejected == self.sheds.total(),
            "stats invariant: rejected {} != shed reasons {:?}",
            self.rejected,
            self.sheds
        );
        ensure!(
            self.errored == self.errors.total(),
            "stats invariant: errored {} != error reasons {:?}",
            self.errored,
            self.errors
        );
        ensure!(
            self.accepted == self.served + self.rejected + self.errored,
            "stats invariant: accepted {} != served {} + rejected {} + errored {}",
            self.accepted,
            self.served,
            self.rejected,
            self.errored
        );
        Ok(())
    }
}

/// A candidate model push: raw container bytes plus a provenance string for
/// logs. Bytes (not a parsed struct) on purpose — the serve loop itself runs
/// the full MRC2 CRC parse, so a corrupt push is caught by the same
/// integrity layer as a corrupt file on disk.
pub struct ReloadRequest {
    pub bytes: Vec<u8>,
    pub origin: String,
}

/// Internal per-run tally; folded into [`ServeStats`] at loop exit.
#[derive(Default)]
struct Tally {
    accepted: usize,
    served: usize,
    batches: usize,
    sheds: ShedReasons,
    errors: ErrorReasons,
    queue_high_water: usize,
    retries: u64,
    reloads: usize,
    reloads_rejected: usize,
}

/// Bounded admission: count the arrival, shed per policy if the queue is
/// full, then enqueue. Every shed gets an [`ServeError::Overloaded`] answer.
fn admit(
    r: Request,
    queue: &mut VecDeque<Request>,
    depth: usize,
    shed: ShedPolicy,
    tally: &mut Tally,
) {
    tally.accepted += 1;
    obs::metrics().serve_accepted.inc();
    if queue.len() >= depth {
        let err = Response::Err(ServeError::Overloaded { depth });
        match shed {
            ShedPolicy::Reject => {
                let _ = r.reply.send(err);
                tally.sheds.overloaded += 1;
                obs::metrics().serve_shed.inc();
                obs_event!(Ev::Info, "shed",
                    "reason" => "overloaded", "policy" => "reject",
                    "depth" => depth);
                return;
            }
            ShedPolicy::Oldest => {
                if let Some(old) = queue.pop_front() {
                    let _ = old.reply.send(err);
                    tally.sheds.overloaded += 1;
                    obs::metrics().serve_shed.inc();
                    obs_event!(Ev::Info, "shed",
                        "reason" => "overloaded", "policy" => "oldest",
                        "depth" => depth);
                }
            }
        }
    }
    queue.push_back(r);
    tally.queue_high_water = tally.queue_high_water.max(queue.len());
}

/// The server: owns the container, decoded weights + the artifact handle.
pub struct Server<'a> {
    arts: &'a ModelArtifacts,
    /// Owned (cloned at construction) so a hot reload can atomically swap
    /// it without caller coordination.
    mrc: MrcFile,
    layout: Layout,
    w_blocks: Vec<f32>,
    decoded: Vec<bool>,
    cfg: ServerCfg,
    reload_rx: Option<Receiver<ReloadRequest>>,
    pub decode_secs: f64,
}

impl<'a> Server<'a> {
    pub fn new(arts: &'a ModelArtifacts, mrc: &MrcFile, cfg: ServerCfg) -> Result<Server<'a>> {
        mrc.validate_for(&arts.meta, arts.backend_family())?;
        let meta = &arts.meta;
        let layout = Layout::generate(meta, mrc.layout_seed);
        let mut server = Server {
            arts,
            mrc: mrc.clone(),
            layout,
            w_blocks: vec![0.0; meta.b * meta.s],
            decoded: vec![false; meta.b],
            cfg,
            reload_rx: None,
            decode_secs: 0.0,
        };
        if !server.cfg.lazy_decode {
            let t = crate::util::Timer::start();
            server.decode_all()?;
            server.decode_secs = t.secs();
            info!(
                "decoded {} blocks in {:.2}s",
                meta.b, server.decode_secs
            );
        }
        Ok(server)
    }

    /// Attach the hot-reload channel. Candidate containers sent here are
    /// CRC-parsed, validated and fully decoded before the atomic swap; any
    /// failure keeps the last-known-good model serving.
    pub fn set_reload(&mut self, rx: Receiver<ReloadRequest>) {
        self.reload_rx = Some(rx);
    }

    fn decode_all(&mut self) -> Result<()> {
        for b in 0..self.arts.meta.b {
            self.ensure_block(b)?;
        }
        Ok(())
    }

    /// Decode-on-demand: the §5 "pseudo-random generators as algorithmic
    /// lookup-tables" path.
    pub fn ensure_block(&mut self, b: usize) -> Result<()> {
        if self.decoded[b] {
            return Ok(());
        }
        if self.cfg.faults.fail_decodes > 0 {
            self.cfg.faults.fail_decodes -= 1;
            return err!("injected decode fault at block {b}");
        }
        let t = crate::util::Timer::start();
        let row = decode_single_block(self.arts, &self.mrc, &self.layout, b)?;
        let s = self.arts.meta.s;
        self.w_blocks[b * s..(b + 1) * s].copy_from_slice(&row);
        self.decoded[b] = true;
        self.decode_secs += t.secs();
        Ok(())
    }

    pub fn blocks_decoded(&self) -> usize {
        self.decoded.iter().filter(|&&d| d).count()
    }

    /// Upload weights + assemble map once; reused for every batch (no
    /// per-request clone or re-validation of ~B*S + n_total values).
    fn upload_weights(
        &self,
        w_blocks: &[f32],
        amap: &[i32],
    ) -> Result<(DeviceBuf, DeviceBuf)> {
        let meta = &self.arts.meta;
        let w_buf = self.arts.upload(&Arg::F32(TensorF32::new(
            vec![meta.b, meta.s],
            w_blocks.to_vec(),
        )?))?;
        let amap_buf = self.arts.upload(&Arg::I32(TensorI32::new(
            vec![meta.n_total],
            amap.to_vec(),
        )?))?;
        Ok((w_buf, amap_buf))
    }

    fn upload_model(&self) -> Result<(DeviceBuf, DeviceBuf)> {
        self.upload_weights(&self.w_blocks, &self.layout.assemble_map)
    }

    /// Validate + decode + upload a pushed container, then swap it in.
    /// Everything fallible happens *before* any state is touched, so an
    /// error leaves the last-known-good model fully intact.
    fn apply_reload(&mut self, req: &ReloadRequest) -> Result<(DeviceBuf, DeviceBuf)> {
        let mrc = MrcFile::from_bytes(&req.bytes)
            .map_err(|e| crate::util::Error::msg(format!("parse: {e}")))?;
        mrc.validate_for(&self.arts.meta, self.arts.backend_family())?;
        let meta = &self.arts.meta;
        let layout = Layout::generate(meta, mrc.layout_seed);
        let t = crate::util::Timer::start();
        let mut w = vec![0.0f32; meta.b * meta.s];
        for b in 0..meta.b {
            let row = decode_single_block(self.arts, &mrc, &layout, b)
                .map_err(|e| e.context(format!("decode block {b}")))?;
            w[b * meta.s..(b + 1) * meta.s].copy_from_slice(&row);
        }
        let bufs = self.upload_weights(&w, &layout.assemble_map)?;
        self.mrc = mrc;
        self.layout = layout;
        self.w_blocks = w;
        self.decoded = vec![true; meta.b];
        self.decode_secs += t.secs();
        Ok(bufs)
    }

    /// Run the serve loop until the request channel closes. Returns stats.
    ///
    /// Per-request failures (overload, deadline, malformed input, decode,
    /// backend or breaker errors) are answered with [`Response::Err`] and
    /// counted; they never terminate the loop.
    pub fn run(&mut self, rx: Receiver<Request>) -> Result<ServeStats> {
        let arts = self.arts;
        let meta = &arts.meta;
        let feat: usize = meta.input_shape.iter().product();
        let eb = meta.eval_batch;
        let max_batch = self.cfg.max_batch.min(eb).max(1);
        let depth = self.cfg.queue_depth.max(1);
        let shed = self.cfg.shed;
        let retry = self.cfg.retry.clone();
        let schedule = self.cfg.faults.schedule.clone();
        let chaos = schedule.is_active();
        let exec_delay = self.cfg.faults.exec_delay;
        let mut fail_execs = self.cfg.faults.fail_execs;
        let mut breaker = Breaker::new(self.cfg.breaker.clone());
        let reload_rx = self.reload_rx.take();
        let reload_poll = self.cfg.reload_poll.max(Duration::from_millis(1));
        let deadline_cfg = self.cfg.deadline;
        let batch_window = self.cfg.batch_window;

        // eager path decoded at construction; lazy path decodes inside the
        // loop so a corrupt block degrades to per-request errors
        let mut bufs: Option<(DeviceBuf, DeviceBuf)> =
            if self.blocks_decoded() == meta.b {
                Some(self.upload_model()?)
            } else {
                None
            };

        let heartbeat = self.cfg.heartbeat;
        let wall = Instant::now();
        // back-dated so the first completed batch always emits a heartbeat
        // (deterministic for tests; a live operator sees signs of life
        // immediately instead of one interval in)
        let mut last_hb =
            Instant::now().checked_sub(heartbeat).unwrap_or_else(Instant::now);
        let mut lat_hist = Hist::new();
        let mut exec_hist = Hist::new();
        let mut tally = Tally::default();
        let mut queue: VecDeque<Request> = VecDeque::new();
        // batch tick: advances once per batch that passes the breaker gate;
        // the chaos schedule is keyed by it, never by wall time
        let mut tick: u64 = 0;
        'serve: loop {
            // apply pushed models before admitting more work
            if let Some(rrx) = &reload_rx {
                while let Ok(req) = rrx.try_recv() {
                    match self.apply_reload(&req) {
                        Ok(nb) => {
                            bufs = Some(nb);
                            tally.reloads += 1;
                            obs::metrics().serve_reloads.inc();
                            obs_event!(Ev::Info, "reload_applied",
                                "origin" => req.origin.as_str(),
                                "bytes" => req.bytes.len());
                            info!("hot reload applied ({})", req.origin);
                        }
                        Err(e) => {
                            tally.reloads_rejected += 1;
                            obs::metrics().serve_reloads_rejected.inc();
                            obs_event!(Ev::Warn, "reload_rejected",
                                "origin" => req.origin.as_str(),
                                "error" => e.to_string());
                            info!(
                                "hot reload REJECTED ({}): {e}; keeping last-known-good",
                                req.origin
                            );
                        }
                    }
                }
            }
            // block for the first request of a batch
            if queue.is_empty() {
                if reload_rx.is_some() {
                    match rx.recv_timeout(reload_poll) {
                        Ok(r) => admit(r, &mut queue, depth, shed, &mut tally),
                        Err(RecvTimeoutError::Timeout) => continue 'serve,
                        Err(RecvTimeoutError::Disconnected) => break 'serve,
                    }
                } else {
                    match rx.recv() {
                        Ok(r) => admit(r, &mut queue, depth, shed, &mut tally),
                        Err(_) => break 'serve, // all senders dropped
                    }
                }
            }
            // gather more within the window up to max_batch
            let gather_deadline = Instant::now() + batch_window;
            while queue.len() < max_batch {
                let now = Instant::now();
                if now >= gather_deadline {
                    break;
                }
                match rx.recv_timeout(gather_deadline - now) {
                    Ok(r) => admit(r, &mut queue, depth, shed, &mut tally),
                    Err(_) => break,
                }
            }
            // drain whatever else already arrived so queue pressure is
            // observed (and shed) now, not hidden in the unbounded channel
            while let Ok(r) = rx.try_recv() {
                admit(r, &mut queue, depth, shed, &mut tally);
            }
            // triage the batch: shed stale requests, bounce malformed ones
            let now = Instant::now();
            let take = queue.len().min(max_batch);
            let mut batch: Vec<Request> = Vec::with_capacity(take);
            for r in queue.drain(..take) {
                let waited = now.saturating_duration_since(r.submitted);
                if waited > deadline_cfg {
                    let _ = r.reply.send(Response::Err(
                        ServeError::DeadlineExceeded {
                            waited,
                            deadline: deadline_cfg,
                        },
                    ));
                    tally.sheds.deadline += 1;
                    obs::metrics().serve_shed.inc();
                    obs_event!(Ev::Info, "shed",
                        "reason" => "deadline",
                        "waited_us" => waited.as_micros() as u64);
                } else if r.x.len() != feat {
                    let _ = r.reply.send(Response::Err(ServeError::BadRequest(
                        format!("feature dim {} != {feat}", r.x.len()),
                    )));
                    tally.sheds.bad_request += 1;
                    obs::metrics().serve_shed.inc();
                    obs_event!(Ev::Info, "shed",
                        "reason" => "bad_request", "dim" => r.x.len());
                } else {
                    batch.push(r);
                }
            }
            if batch.is_empty() {
                continue;
            }
            // breaker gate: while Open, fail fast instead of stalling
            let gate_now = Instant::now();
            if !breaker.allow(gate_now) {
                let err = ServeError::BreakerOpen {
                    retry_after: breaker.retry_after(gate_now).unwrap_or_default(),
                };
                tally.errors.breaker += batch.len();
                obs::metrics().serve_errored.add(batch.len() as u64);
                obs_event!(Ev::Debug, "breaker_fast_fail", "n" => batch.len());
                for r in batch.drain(..) {
                    let _ = r.reply.send(Response::Err(err.clone()));
                }
                continue;
            }
            let cur_tick = tick;
            tick += 1;
            // lazy decode + one-time upload under retry, degrading to
            // per-request errors on exhaustion (the next batch retries)
            if bufs.is_none() {
                let sp = obs::span("serve_lazy_decode");
                let (res, retries) = retry_with(
                    &retry,
                    0xDEC0_DE00 ^ cur_tick,
                    std::thread::sleep,
                    |_| {
                        self.decode_all()?;
                        self.upload_model()
                    },
                );
                drop(sp);
                tally.retries += retries as u64;
                match res {
                    Ok(b) => bufs = Some(b),
                    Err(e) => {
                        breaker.record(Instant::now(), false);
                        let err = ServeError::DecodeFailed(e.to_string());
                        tally.errors.decode += batch.len();
                        obs::metrics().serve_errored.add(batch.len() as u64);
                        obs_event!(Ev::Warn, "decode_failed",
                            "tick" => cur_tick, "n" => batch.len(),
                            "error" => e.to_string());
                        for r in batch.drain(..) {
                            let _ = r.reply.send(Response::Err(err.clone()));
                        }
                        continue;
                    }
                }
            }
            let (w_buf, amap_buf) =
                bufs.as_ref().expect("uploaded above when absent");
            // fault hooks: slow backend + scheduled latency spike
            if !exec_delay.is_zero() {
                std::thread::sleep(exec_delay);
            }
            if chaos {
                if let Some(spike) = schedule.latency(cur_tick) {
                    std::thread::sleep(spike);
                }
            }
            // assemble the padded batch once; retries reuse it
            let n = batch.len();
            let mut xb = vec![0f32; eb * feat];
            for (i, r) in batch.iter().enumerate() {
                xb[i * feat..(i + 1) * feat].copy_from_slice(&r.x);
            }
            let mut shape = vec![eb];
            shape.extend_from_slice(&meta.input_shape);
            let x_arg = match TensorF32::new(shape, xb).map(Arg::F32) {
                Ok(a) => a,
                Err(e) => {
                    // unreachable by construction (we sized xb ourselves),
                    // but the loop must degrade rather than die
                    breaker.record(Instant::now(), false);
                    let err = ServeError::ExecFailed(e.to_string());
                    tally.errors.exec += n;
                    obs::metrics().serve_errored.add(n as u64);
                    for r in batch.drain(..) {
                        let _ = r.reply.send(Response::Err(err.clone()));
                    }
                    continue;
                }
            };
            let t_exec = Instant::now();
            let sp_exec = obs::span("serve_exec");
            let (exec, retries) = retry_with(
                &retry,
                0xE8EC_0000 ^ cur_tick,
                std::thread::sleep,
                |attempt| {
                    if fail_execs > 0 {
                        fail_execs -= 1;
                        return err!(
                            "injected exec fault (tick {cur_tick}, attempt {attempt})"
                        );
                    }
                    if chaos && schedule.exec_fails(cur_tick, attempt) {
                        return err!(
                            "chaos exec fault (tick {cur_tick}, attempt {attempt})"
                        );
                    }
                    arts.invoke_mixed(
                        "eval_batch",
                        &[
                            Input::Dev(w_buf),
                            Input::Dev(amap_buf),
                            Input::Host(&x_arg),
                        ],
                    )
                },
            );
            drop(sp_exec);
            tally.retries += retries as u64;
            let outs = match exec {
                Ok(outs) => outs,
                Err(e) => {
                    breaker.record(Instant::now(), false);
                    let err = ServeError::ExecFailed(e.to_string());
                    tally.errors.exec += n;
                    obs::metrics().serve_errored.add(n as u64);
                    obs_event!(Ev::Warn, "exec_failed",
                        "tick" => cur_tick, "n" => n,
                        "error" => e.to_string());
                    for r in batch.drain(..) {
                        let _ = r.reply.send(Response::Err(err.clone()));
                    }
                    continue;
                }
            };
            exec_hist.record_secs(t_exec.elapsed().as_secs_f64());
            let logits = match outs[0].as_f32() {
                Ok(l) => l,
                Err(e) => {
                    breaker.record(Instant::now(), false);
                    let err = ServeError::ExecFailed(e.to_string());
                    tally.errors.exec += n;
                    obs::metrics().serve_errored.add(n as u64);
                    obs_event!(Ev::Warn, "exec_failed",
                        "tick" => cur_tick, "n" => n,
                        "error" => e.to_string());
                    for r in batch.drain(..) {
                        let _ = r.reply.send(Response::Err(err.clone()));
                    }
                    continue;
                }
            };
            breaker.record(Instant::now(), true);
            let done = Instant::now();
            for (i, r) in batch.drain(..).enumerate() {
                let row = logits.row(i).to_vec();
                let pred = argmax(&row);
                let latency = done - r.submitted;
                lat_hist.record_secs(latency.as_secs_f64());
                let _ = r.reply.send(Response::Ok(Prediction {
                    logits: row,
                    pred,
                    latency,
                }));
            }
            tally.served += n;
            tally.batches += 1;
            let m = obs::metrics();
            m.serve_served.add(n as u64);
            m.serve_batches.inc();
            m.queue_depth.set(queue.len() as u64);
            m.breaker_state.set(match breaker.state() {
                BreakerState::Closed => 0,
                BreakerState::Open => 1,
                BreakerState::HalfOpen => 2,
            });
            obs::metrics_tick(|| {
                let s = lat_hist.summary_secs();
                let secs = wall.elapsed().as_secs_f64().max(1e-9);
                vec![
                    ("phase", Json::str("serve")),
                    ("qps", Json::num(tally.served as f64 / secs)),
                    ("p50_ms", Json::num(s.p50 * 1e3)),
                    ("p95_ms", Json::num(s.p95 * 1e3)),
                    ("p99_ms", Json::num(s.p99 * 1e3)),
                ]
            });
            if !heartbeat.is_zero() && last_hb.elapsed() >= heartbeat {
                last_hb = Instant::now();
                let s = lat_hist.summary_secs();
                let secs = wall.elapsed().as_secs_f64().max(1e-9);
                println!(
                    "[serve] {} served ({:.0} req/s) | queue {} | p95 {:.2}ms | \
                     breaker {:?} | shed {} | errored {}",
                    tally.served,
                    tally.served as f64 / secs,
                    queue.len(),
                    s.p95 * 1e3,
                    breaker.state(),
                    tally.sheds.total(),
                    tally.errors.total()
                );
            }
        }
        let stats = ServeStats {
            accepted: tally.accepted,
            served: tally.served,
            batches: tally.batches,
            rejected: tally.sheds.total(),
            errored: tally.errors.total(),
            sheds: tally.sheds,
            errors: tally.errors,
            queue_high_water: tally.queue_high_water,
            retries: tally.retries,
            breaker_trips: breaker.trips(),
            reloads: tally.reloads,
            reloads_rejected: tally.reloads_rejected,
            latency: lat_hist.summary_secs(),
            exec_time: exec_hist.summary_secs(),
            decode_secs: self.decode_secs,
            wall_secs: wall.elapsed().as_secs_f64(),
        };
        stats.check_invariant()?;
        Ok(stats)
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Poll `path`'s mtime every `poll`; on change, read the file and push its
/// bytes as a [`ReloadRequest`]. The thread exits when the receiver is gone
/// (detected at the next change) — for a serving process that is process
/// lifetime, which is the intent of `--reload-watch`.
pub fn spawn_mtime_watcher(
    path: PathBuf,
    poll: Duration,
) -> (Receiver<ReloadRequest>, std::thread::JoinHandle<()>) {
    let (tx, rx) = channel::<ReloadRequest>();
    let handle = std::thread::spawn(move || {
        let mtime = |p: &PathBuf| std::fs::metadata(p).and_then(|m| m.modified()).ok();
        let mut last = mtime(&path);
        loop {
            std::thread::sleep(poll);
            let cur = mtime(&path);
            if cur.is_some() && cur != last {
                last = cur;
                // read can race the writer; a torn read fails CRC validation
                // in the serve loop and is retried at the next mtime change
                if let Ok(bytes) = std::fs::read(&path) {
                    let origin = format!("file:{}", path.display());
                    if tx.send(ReloadRequest { bytes, origin }).is_err() {
                        return;
                    }
                }
            }
        }
    });
    (rx, handle)
}

/// Client helper: spawn `n_clients` threads each sending `per_client`
/// requests drawn from `examples`; returns the channel for the server and a
/// join handle that collects responses.
pub fn spawn_clients(
    examples: Vec<Vec<f32>>,
    n_clients: usize,
    per_client: usize,
    pace: Duration,
) -> (Receiver<Request>, std::thread::JoinHandle<Vec<Response>>) {
    let (tx, rx) = channel::<Request>();
    let handle = std::thread::spawn(move || {
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let tx = tx.clone();
            let ex = examples.clone();
            joins.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for i in 0..per_client {
                    let (rtx, rrx) = channel();
                    let x = ex[(c * per_client + i) % ex.len()].clone();
                    tx.send(Request { x, submitted: Instant::now(), reply: rtx })
                        .ok();
                    if !pace.is_zero() {
                        std::thread::sleep(pace);
                    }
                    if let Ok(resp) = rrx.recv() {
                        out.push(resp);
                    }
                }
                out
            }));
        }
        drop(tx);
        joins
            .into_iter()
            .flat_map(|j| j.join().unwrap_or_default())
            .collect()
    });
    (rx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
    }

    #[test]
    fn default_cfg_sane() {
        let c = ServerCfg::default();
        assert!(!c.lazy_decode);
        assert!(c.batch_window > Duration::ZERO);
        assert!(c.deadline > Duration::ZERO);
        assert!(c.queue_depth > 0);
        assert_eq!(c.shed, ShedPolicy::Reject);
        assert!(c.heartbeat.is_zero(), "heartbeat must default to off");
        assert!(c.retry.max_attempts >= 1);
        assert_eq!(c.faults.fail_decodes, 0);
        assert_eq!(c.faults.fail_execs, 0);
        assert!(c.faults.exec_delay.is_zero());
        assert!(!c.faults.schedule.is_active());
    }

    #[test]
    fn shed_policy_parses() {
        assert_eq!("reject".parse::<ShedPolicy>().unwrap(), ShedPolicy::Reject);
        assert_eq!("oldest".parse::<ShedPolicy>().unwrap(), ShedPolicy::Oldest);
        assert!("newest".parse::<ShedPolicy>().is_err());
    }

    #[test]
    fn response_accessors() {
        let ok = Response::Ok(Prediction {
            logits: vec![0.0, 1.0],
            pred: 1,
            latency: Duration::from_millis(1),
        });
        assert!(ok.is_ok());
        assert_eq!(ok.prediction().unwrap().pred, 1);
        assert!(ok.error().is_none());
        let err = Response::Err(ServeError::BadRequest("dim".into()));
        assert!(!err.is_ok());
        assert!(err.prediction().is_none());
        assert!(matches!(err.error(), Some(ServeError::BadRequest(_))));
    }

    #[test]
    fn serve_errors_display_one_line() {
        let errs = [
            ServeError::BadRequest("dim".into()),
            ServeError::Overloaded { depth: 8 },
            ServeError::DeadlineExceeded {
                waited: Duration::from_millis(50),
                deadline: Duration::from_millis(10),
            },
            ServeError::DecodeFailed("crc".into()),
            ServeError::ExecFailed("backend".into()),
            ServeError::BreakerOpen { retry_after: Duration::from_millis(75) },
        ];
        for e in errs {
            let msg = e.to_string();
            assert!(!msg.is_empty());
            assert!(!msg.contains('\n'), "{msg}");
        }
    }

    fn req(reply: Sender<Response>) -> Request {
        Request { x: vec![0.0; 4], submitted: Instant::now(), reply }
    }

    #[test]
    fn admission_reject_sheds_the_arrival() {
        let mut queue = VecDeque::new();
        let mut tally = Tally::default();
        let (tx, rx) = channel();
        for _ in 0..3 {
            admit(req(tx.clone()), &mut queue, 2, ShedPolicy::Reject, &mut tally);
        }
        assert_eq!(tally.accepted, 3);
        assert_eq!(queue.len(), 2);
        assert_eq!(tally.sheds.overloaded, 1);
        assert_eq!(tally.queue_high_water, 2);
        // the shed one already has its answer
        let resp = rx.try_recv().unwrap();
        assert!(matches!(resp.error(), Some(ServeError::Overloaded { depth: 2 })));
    }

    #[test]
    fn admission_oldest_evicts_the_head() {
        let mut queue = VecDeque::new();
        let mut tally = Tally::default();
        let (old_tx, old_rx) = channel();
        let (new_tx, new_rx) = channel();
        admit(req(old_tx), &mut queue, 1, ShedPolicy::Oldest, &mut tally);
        admit(req(new_tx), &mut queue, 1, ShedPolicy::Oldest, &mut tally);
        assert_eq!(queue.len(), 1, "newest kept");
        assert_eq!(tally.sheds.overloaded, 1);
        assert!(matches!(
            old_rx.try_recv().unwrap().error(),
            Some(ServeError::Overloaded { .. })
        ), "head was evicted and answered");
        assert!(new_rx.try_recv().is_err(), "arrival still queued");
    }

    #[test]
    fn stats_invariant_checks() {
        let ok = ServeStats {
            accepted: 10,
            served: 6,
            batches: 2,
            rejected: 3,
            errored: 1,
            sheds: ShedReasons { overloaded: 1, deadline: 1, bad_request: 1 },
            errors: ErrorReasons { decode: 0, exec: 1, breaker: 0 },
            queue_high_water: 4,
            retries: 0,
            breaker_trips: 0,
            reloads: 0,
            reloads_rejected: 0,
            latency: HistSummary::default(),
            exec_time: HistSummary::default(),
            decode_secs: 0.0,
            wall_secs: 0.0,
        };
        ok.check_invariant().unwrap();
        let mut bad = ok.clone();
        bad.served = 7;
        assert!(bad.check_invariant().is_err());
        let mut bad2 = ok;
        bad2.rejected = 2;
        assert!(bad2.check_invariant().is_err());
    }
}
