//! Inference server over a compressed model — the paper's §5 future-work
//! "inference machine which is able to directly run our compressed models".
//!
//! Requests are classification queries; the server decodes the `.mrc` via
//! the shared-randomness generator (eagerly at startup, or block-by-block on
//! demand in lazy mode), then serves batched forward passes through the
//! backend's `eval_batch` entry point.
//!
//! Threading model: backend handles are not assumed `Send` (PJRT's are
//! not), so the executor stays on the thread that built it; clients run on
//! their own threads and talk to the server loop over an mpsc channel
//! (router + dynamic batcher pattern).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use crate::codec::MrcFile;
use crate::coordinator::encoder::decode_single_block;
use crate::model::Layout;
use crate::runtime::{Input, ModelArtifacts};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::stats::{summarize, Summary};
use crate::util::Result;
use crate::{ensure, info};

/// One inference request: a flattened input example.
pub struct Request {
    pub x: Vec<f32>,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

/// Prediction + timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub latency: Duration,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// max requests folded into one eval_batch invocation (capped by the
    /// artifact's eval_batch size)
    pub max_batch: usize,
    /// how long to wait for more requests before running a partial batch
    pub batch_window: Duration,
    /// decode blocks on first use instead of at startup
    pub lazy_decode: bool,
}

impl Default for ServerCfg {
    fn default() -> ServerCfg {
        ServerCfg {
            max_batch: usize::MAX,
            batch_window: Duration::from_millis(2),
            lazy_decode: false,
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub latency: Summary,
    pub exec_time: Summary,
    pub decode_secs: f64,
    pub wall_secs: f64,
}

/// The server: owns decoded weights + the artifact handle.
pub struct Server<'a> {
    arts: &'a ModelArtifacts,
    mrc: &'a MrcFile,
    layout: Layout,
    w_blocks: Vec<f32>,
    decoded: Vec<bool>,
    cfg: ServerCfg,
    pub decode_secs: f64,
}

impl<'a> Server<'a> {
    pub fn new(arts: &'a ModelArtifacts, mrc: &'a MrcFile, cfg: ServerCfg) -> Result<Server<'a>> {
        mrc.validate_for(&arts.meta, arts.backend_family())?;
        let meta = &arts.meta;
        let layout = Layout::generate(meta, mrc.layout_seed);
        let mut server = Server {
            arts,
            mrc,
            layout,
            w_blocks: vec![0.0; meta.b * meta.s],
            decoded: vec![false; meta.b],
            cfg,
            decode_secs: 0.0,
        };
        if !server.cfg.lazy_decode {
            let t = crate::util::Timer::start();
            server.decode_all()?;
            server.decode_secs = t.secs();
            info!(
                "decoded {} blocks in {:.2}s",
                meta.b, server.decode_secs
            );
        }
        Ok(server)
    }

    fn decode_all(&mut self) -> Result<()> {
        for b in 0..self.arts.meta.b {
            self.ensure_block(b)?;
        }
        Ok(())
    }

    /// Decode-on-demand: the §5 "pseudo-random generators as algorithmic
    /// lookup-tables" path.
    pub fn ensure_block(&mut self, b: usize) -> Result<()> {
        if self.decoded[b] {
            return Ok(());
        }
        let t = crate::util::Timer::start();
        let row = decode_single_block(self.arts, self.mrc, &self.layout, b)?;
        let s = self.arts.meta.s;
        self.w_blocks[b * s..(b + 1) * s].copy_from_slice(&row);
        self.decoded[b] = true;
        self.decode_secs += t.secs();
        Ok(())
    }

    pub fn blocks_decoded(&self) -> usize {
        self.decoded.iter().filter(|&&d| d).count()
    }

    /// Run the serve loop until the request channel closes. Returns stats.
    pub fn run(&mut self, rx: Receiver<Request>) -> Result<ServeStats> {
        let meta = &self.arts.meta;
        let feat: usize = meta.input_shape.iter().product();
        let eb = meta.eval_batch;
        let max_batch = self.cfg.max_batch.min(eb);
        if self.cfg.lazy_decode {
            self.decode_all()?; // first request would need all layers anyway
        }
        // weights + assemble map uploaded once and reused for every batch:
        // no per-request clone or re-validation of ~B*S + n_total values
        let w_buf = self.arts.upload(&Arg::F32(TensorF32::new(
            vec![meta.b, meta.s],
            self.w_blocks.clone(),
        )?))?;
        let amap_buf = self.arts.upload(&Arg::I32(TensorI32::new(
            vec![meta.n_total],
            self.layout.assemble_map.clone(),
        )?))?;

        let wall = Instant::now();
        let mut latencies = Vec::new();
        let mut exec_times = Vec::new();
        let mut served = 0usize;
        let mut batches = 0usize;
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // block for the first request of a batch
            if pending.is_empty() {
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break, // all senders dropped
                }
            }
            // gather more within the window up to max_batch
            let deadline = Instant::now() + self.cfg.batch_window;
            while pending.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            // assemble the padded batch
            let n = pending.len();
            let mut xb = vec![0f32; eb * feat];
            for (i, r) in pending.iter().enumerate() {
                ensure!(
                    r.x.len() == feat,
                    "request feature dim {} != {feat}",
                    r.x.len()
                );
                xb[i * feat..(i + 1) * feat].copy_from_slice(&r.x);
            }
            let mut shape = vec![eb];
            shape.extend_from_slice(&meta.input_shape);
            let t_exec = Instant::now();
            let x_arg = Arg::F32(TensorF32::new(shape, xb)?);
            let outs = self.arts.invoke_mixed(
                "eval_batch",
                &[
                    Input::Dev(&w_buf),
                    Input::Dev(&amap_buf),
                    Input::Host(&x_arg),
                ],
            )?;
            exec_times.push(t_exec.elapsed().as_secs_f64());
            let logits = outs[0].as_f32()?;
            let done = Instant::now();
            for (i, r) in pending.drain(..).enumerate() {
                let row = logits.row(i).to_vec();
                let pred = argmax(&row);
                let latency = done - r.submitted;
                latencies.push(latency.as_secs_f64());
                let _ = r.reply.send(Response { logits: row, pred, latency });
            }
            served += n;
            batches += 1;
        }
        Ok(ServeStats {
            served,
            batches,
            latency: summarize(&latencies),
            exec_time: summarize(&exec_times),
            decode_secs: self.decode_secs,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Client helper: spawn `n_clients` threads each sending `per_client`
/// requests drawn from `examples`; returns the channel for the server and a
/// join handle that collects responses.
pub fn spawn_clients(
    examples: Vec<Vec<f32>>,
    n_clients: usize,
    per_client: usize,
    pace: Duration,
) -> (Receiver<Request>, std::thread::JoinHandle<Vec<Response>>) {
    let (tx, rx) = channel::<Request>();
    let handle = std::thread::spawn(move || {
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let tx = tx.clone();
            let ex = examples.clone();
            joins.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for i in 0..per_client {
                    let (rtx, rrx) = channel();
                    let x = ex[(c * per_client + i) % ex.len()].clone();
                    tx.send(Request { x, submitted: Instant::now(), reply: rtx })
                        .ok();
                    if !pace.is_zero() {
                        std::thread::sleep(pace);
                    }
                    if let Ok(resp) = rrx.recv() {
                        out.push(resp);
                    }
                }
                out
            }));
        }
        drop(tx);
        joins
            .into_iter()
            .flat_map(|j| j.join().unwrap_or_default())
            .collect()
    });
    (rx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
    }

    #[test]
    fn default_cfg_sane() {
        let c = ServerCfg::default();
        assert!(!c.lazy_decode);
        assert!(c.batch_window > Duration::ZERO);
    }
}
