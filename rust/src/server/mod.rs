//! Inference server over a compressed model — the paper's §5 future-work
//! "inference machine which is able to directly run our compressed models".
//!
//! Requests are classification queries; the server decodes the `.mrc` via
//! the shared-randomness generator (eagerly at startup, or block-by-block on
//! demand in lazy mode), then serves batched forward passes through the
//! backend's `eval_batch` entry point.
//!
//! Threading model: backend handles are not assumed `Send` (PJRT's are
//! not), so the executor stays on the thread that built it; clients run on
//! their own threads and talk to the server loop over an mpsc channel
//! (router + dynamic batcher pattern).
//!
//! Degradation model: the serve loop never dies because of one bad input.
//! Malformed requests, per-request deadline overruns, lazy-decode failures
//! and backend execution errors are all reported to the *affected* clients
//! as structured [`Response::Err`] values while the loop keeps serving
//! everyone else. The only way `run` returns is the request channel
//! closing (or a startup-time invariant failing before any request is
//! taken). [`ServerFaults`] injects decode/execution faults for tests.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use crate::codec::MrcFile;
use crate::coordinator::encoder::decode_single_block;
use crate::model::Layout;
use crate::runtime::{DeviceBuf, Input, ModelArtifacts};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::stats::{summarize, Summary};
use crate::util::Result;
use crate::{err, info};

/// One inference request: a flattened input example.
pub struct Request {
    pub x: Vec<f32>,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

/// What a client gets back: a prediction, or a structured serving error.
/// Errors never wedge the reply channel — every admitted request receives
/// exactly one `Response`.
#[derive(Debug, Clone)]
pub enum Response {
    Ok(Prediction),
    Err(ServeError),
}

impl Response {
    pub fn is_ok(&self) -> bool {
        matches!(self, Response::Ok(_))
    }

    pub fn prediction(&self) -> Option<&Prediction> {
        match self {
            Response::Ok(p) => Some(p),
            Response::Err(_) => None,
        }
    }

    pub fn error(&self) -> Option<&ServeError> {
        match self {
            Response::Ok(_) => None,
            Response::Err(e) => Some(e),
        }
    }
}

/// Prediction + timing.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub latency: Duration,
}

/// Structured per-request failure. The variant tells the client whether the
/// fault was theirs (`BadRequest`), load-induced (`DeadlineExceeded`) or
/// server-side (`DecodeFailed`, `ExecFailed` — retryable once the operator
/// replaces the corrupt container / unwedges the backend).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself is malformed (wrong feature dimension).
    BadRequest(String),
    /// The request waited longer than [`ServerCfg::deadline`] before its
    /// batch was admitted; it was shed rather than served stale.
    DeadlineExceeded { waited: Duration, deadline: Duration },
    /// Lazily decoding the `.mrc` failed (corrupt container, injected
    /// fault). The loop stays alive and later requests retry the decode.
    DecodeFailed(String),
    /// The backend rejected or failed the batched forward pass.
    ExecFailed(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadRequest(m) => write!(f, "bad request: {m}"),
            ServeError::DeadlineExceeded { waited, deadline } => write!(
                f,
                "deadline exceeded: waited {:.1}ms against a {:.1}ms budget",
                waited.as_secs_f64() * 1e3,
                deadline.as_secs_f64() * 1e3
            ),
            ServeError::DecodeFailed(m) => write!(f, "model decode failed: {m}"),
            ServeError::ExecFailed(m) => write!(f, "execution failed: {m}"),
        }
    }
}

/// Test-only fault injection, threaded through [`ServerCfg`]. Defaults are
/// inert; production paths never set them. Compiled unconditionally so the
/// corruption/robustness suites and `miracle fuzz-decode` exercise the
/// exact shipping code paths rather than a cfg(test) twin.
#[derive(Debug, Clone, Default)]
pub struct ServerFaults {
    /// Fail this many upcoming block decodes with an injected error before
    /// behaving normally again (simulates a transiently corrupt container).
    pub fail_decodes: usize,
    /// Sleep this long before every batched execution (simulates a slow or
    /// overloaded backend so deadline shedding can be observed).
    pub exec_delay: Duration,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// max requests folded into one eval_batch invocation (capped by the
    /// artifact's eval_batch size)
    pub max_batch: usize,
    /// how long to wait for more requests before running a partial batch
    pub batch_window: Duration,
    /// decode blocks on first use instead of at startup
    pub lazy_decode: bool,
    /// per-request admission deadline: a request still queued after this
    /// long is answered with [`ServeError::DeadlineExceeded`] instead of
    /// being served stale (load shedding)
    pub deadline: Duration,
    /// fault injection hooks (inert by default)
    pub faults: ServerFaults,
}

impl Default for ServerCfg {
    fn default() -> ServerCfg {
        ServerCfg {
            max_batch: usize::MAX,
            batch_window: Duration::from_millis(2),
            lazy_decode: false,
            deadline: Duration::from_secs(30),
            faults: ServerFaults::default(),
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    /// requests answered with a structured error (deadline, bad request,
    /// decode/exec failure) instead of a prediction
    pub rejected: usize,
    pub latency: Summary,
    pub exec_time: Summary,
    pub decode_secs: f64,
    pub wall_secs: f64,
}

/// The server: owns decoded weights + the artifact handle.
pub struct Server<'a> {
    arts: &'a ModelArtifacts,
    mrc: &'a MrcFile,
    layout: Layout,
    w_blocks: Vec<f32>,
    decoded: Vec<bool>,
    cfg: ServerCfg,
    pub decode_secs: f64,
}

impl<'a> Server<'a> {
    pub fn new(arts: &'a ModelArtifacts, mrc: &'a MrcFile, cfg: ServerCfg) -> Result<Server<'a>> {
        mrc.validate_for(&arts.meta, arts.backend_family())?;
        let meta = &arts.meta;
        let layout = Layout::generate(meta, mrc.layout_seed);
        let mut server = Server {
            arts,
            mrc,
            layout,
            w_blocks: vec![0.0; meta.b * meta.s],
            decoded: vec![false; meta.b],
            cfg,
            decode_secs: 0.0,
        };
        if !server.cfg.lazy_decode {
            let t = crate::util::Timer::start();
            server.decode_all()?;
            server.decode_secs = t.secs();
            info!(
                "decoded {} blocks in {:.2}s",
                meta.b, server.decode_secs
            );
        }
        Ok(server)
    }

    fn decode_all(&mut self) -> Result<()> {
        for b in 0..self.arts.meta.b {
            self.ensure_block(b)?;
        }
        Ok(())
    }

    /// Decode-on-demand: the §5 "pseudo-random generators as algorithmic
    /// lookup-tables" path.
    pub fn ensure_block(&mut self, b: usize) -> Result<()> {
        if self.decoded[b] {
            return Ok(());
        }
        if self.cfg.faults.fail_decodes > 0 {
            self.cfg.faults.fail_decodes -= 1;
            return err!("injected decode fault at block {b}");
        }
        let t = crate::util::Timer::start();
        let row = decode_single_block(self.arts, self.mrc, &self.layout, b)?;
        let s = self.arts.meta.s;
        self.w_blocks[b * s..(b + 1) * s].copy_from_slice(&row);
        self.decoded[b] = true;
        self.decode_secs += t.secs();
        Ok(())
    }

    pub fn blocks_decoded(&self) -> usize {
        self.decoded.iter().filter(|&&d| d).count()
    }

    /// Upload decoded weights + assemble map once; reused for every batch
    /// (no per-request clone or re-validation of ~B*S + n_total values).
    fn upload_model(&self) -> Result<(DeviceBuf, DeviceBuf)> {
        let meta = &self.arts.meta;
        let w_buf = self.arts.upload(&Arg::F32(TensorF32::new(
            vec![meta.b, meta.s],
            self.w_blocks.clone(),
        )?))?;
        let amap_buf = self.arts.upload(&Arg::I32(TensorI32::new(
            vec![meta.n_total],
            self.layout.assemble_map.clone(),
        )?))?;
        Ok((w_buf, amap_buf))
    }

    /// Run the serve loop until the request channel closes. Returns stats.
    ///
    /// Per-request failures (deadline, malformed input, lazy-decode or
    /// backend errors) are answered with [`Response::Err`] and counted in
    /// [`ServeStats::rejected`]; they never terminate the loop.
    pub fn run(&mut self, rx: Receiver<Request>) -> Result<ServeStats> {
        let meta = &self.arts.meta;
        let feat: usize = meta.input_shape.iter().product();
        let eb = meta.eval_batch;
        let max_batch = self.cfg.max_batch.min(eb).max(1);
        // eager path decoded at construction; lazy path decodes inside the
        // loop so a corrupt block degrades to per-request errors
        let mut bufs: Option<(DeviceBuf, DeviceBuf)> =
            if self.blocks_decoded() == meta.b {
                Some(self.upload_model()?)
            } else {
                None
            };

        let wall = Instant::now();
        let mut latencies = Vec::new();
        let mut exec_times = Vec::new();
        let mut served = 0usize;
        let mut batches = 0usize;
        let mut rejected = 0usize;
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // block for the first request of a batch
            if pending.is_empty() {
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break, // all senders dropped
                }
            }
            // gather more within the window up to max_batch
            let deadline = Instant::now() + self.cfg.batch_window;
            while pending.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            // admission triage: shed stale requests, bounce malformed ones
            let now = Instant::now();
            let mut batch: Vec<Request> = Vec::with_capacity(pending.len());
            for r in pending.drain(..) {
                let waited = now.saturating_duration_since(r.submitted);
                if waited > self.cfg.deadline {
                    let _ = r.reply.send(Response::Err(
                        ServeError::DeadlineExceeded {
                            waited,
                            deadline: self.cfg.deadline,
                        },
                    ));
                    rejected += 1;
                } else if r.x.len() != feat {
                    let _ = r.reply.send(Response::Err(ServeError::BadRequest(
                        format!("feature dim {} != {feat}", r.x.len()),
                    )));
                    rejected += 1;
                } else {
                    batch.push(r);
                }
            }
            if batch.is_empty() {
                continue;
            }
            // lazy decode + one-time upload, degrading to per-request
            // errors on failure (the next batch retries)
            if bufs.is_none() {
                match self.decode_all().and_then(|_| self.upload_model()) {
                    Ok(b) => bufs = Some(b),
                    Err(e) => {
                        let err = ServeError::DecodeFailed(e.to_string());
                        rejected += batch.len();
                        for r in batch.drain(..) {
                            let _ = r.reply.send(Response::Err(err.clone()));
                        }
                        continue;
                    }
                }
            }
            let (w_buf, amap_buf) =
                bufs.as_ref().expect("uploaded above when absent");
            // fault hook: simulate a slow backend
            if !self.cfg.faults.exec_delay.is_zero() {
                std::thread::sleep(self.cfg.faults.exec_delay);
            }
            // assemble the padded batch
            let n = batch.len();
            let mut xb = vec![0f32; eb * feat];
            for (i, r) in batch.iter().enumerate() {
                xb[i * feat..(i + 1) * feat].copy_from_slice(&r.x);
            }
            let mut shape = vec![eb];
            shape.extend_from_slice(&meta.input_shape);
            let t_exec = Instant::now();
            let exec = TensorF32::new(shape, xb)
                .map(Arg::F32)
                .and_then(|x_arg| {
                    self.arts.invoke_mixed(
                        "eval_batch",
                        &[
                            Input::Dev(w_buf),
                            Input::Dev(amap_buf),
                            Input::Host(&x_arg),
                        ],
                    )
                });
            let outs = match exec {
                Ok(outs) => outs,
                Err(e) => {
                    let err = ServeError::ExecFailed(e.to_string());
                    rejected += n;
                    for r in batch.drain(..) {
                        let _ = r.reply.send(Response::Err(err.clone()));
                    }
                    continue;
                }
            };
            exec_times.push(t_exec.elapsed().as_secs_f64());
            let logits = match outs[0].as_f32() {
                Ok(l) => l,
                Err(e) => {
                    let err = ServeError::ExecFailed(e.to_string());
                    rejected += n;
                    for r in batch.drain(..) {
                        let _ = r.reply.send(Response::Err(err.clone()));
                    }
                    continue;
                }
            };
            let done = Instant::now();
            for (i, r) in batch.drain(..).enumerate() {
                let row = logits.row(i).to_vec();
                let pred = argmax(&row);
                let latency = done - r.submitted;
                latencies.push(latency.as_secs_f64());
                let _ = r.reply.send(Response::Ok(Prediction {
                    logits: row,
                    pred,
                    latency,
                }));
            }
            served += n;
            batches += 1;
        }
        Ok(ServeStats {
            served,
            batches,
            rejected,
            latency: summarize(&latencies),
            exec_time: summarize(&exec_times),
            decode_secs: self.decode_secs,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Client helper: spawn `n_clients` threads each sending `per_client`
/// requests drawn from `examples`; returns the channel for the server and a
/// join handle that collects responses.
pub fn spawn_clients(
    examples: Vec<Vec<f32>>,
    n_clients: usize,
    per_client: usize,
    pace: Duration,
) -> (Receiver<Request>, std::thread::JoinHandle<Vec<Response>>) {
    let (tx, rx) = channel::<Request>();
    let handle = std::thread::spawn(move || {
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let tx = tx.clone();
            let ex = examples.clone();
            joins.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for i in 0..per_client {
                    let (rtx, rrx) = channel();
                    let x = ex[(c * per_client + i) % ex.len()].clone();
                    tx.send(Request { x, submitted: Instant::now(), reply: rtx })
                        .ok();
                    if !pace.is_zero() {
                        std::thread::sleep(pace);
                    }
                    if let Ok(resp) = rrx.recv() {
                        out.push(resp);
                    }
                }
                out
            }));
        }
        drop(tx);
        joins
            .into_iter()
            .flat_map(|j| j.join().unwrap_or_default())
            .collect()
    });
    (rx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
    }

    #[test]
    fn default_cfg_sane() {
        let c = ServerCfg::default();
        assert!(!c.lazy_decode);
        assert!(c.batch_window > Duration::ZERO);
        assert!(c.deadline > Duration::ZERO);
        assert_eq!(c.faults.fail_decodes, 0);
        assert!(c.faults.exec_delay.is_zero());
    }

    #[test]
    fn response_accessors() {
        let ok = Response::Ok(Prediction {
            logits: vec![0.0, 1.0],
            pred: 1,
            latency: Duration::from_millis(1),
        });
        assert!(ok.is_ok());
        assert_eq!(ok.prediction().unwrap().pred, 1);
        assert!(ok.error().is_none());
        let err = Response::Err(ServeError::BadRequest("dim".into()));
        assert!(!err.is_ok());
        assert!(err.prediction().is_none());
        assert!(matches!(err.error(), Some(ServeError::BadRequest(_))));
    }

    #[test]
    fn serve_error_displays_one_line() {
        let e = ServeError::DeadlineExceeded {
            waited: Duration::from_millis(50),
            deadline: Duration::from_millis(10),
        };
        let msg = e.to_string();
        assert!(msg.contains("deadline"), "{msg}");
        assert!(!msg.contains('\n'));
    }
}
