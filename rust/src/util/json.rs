//! Minimal JSON parser + emitter.
//!
//! serde is not available in the offline vendor set, so configs,
//! `manifest.json` and metrics output go through this hand-rolled
//! implementation. Supports the full JSON data model except exotic number
//! forms; numbers are kept as f64 (with an i64 fast path for integers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::msg(format!("trailing JSON at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn from_file(path: &str) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("read {path}: {e}")))?;
        Json::parse(&text)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::msg(format!("missing key '{key}'"))),
            _ => Err(Error::msg(format!("not an object (key '{key}')"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::msg("not a number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::msg(format!("not a usize: {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            return Err(Error::msg(format!("not an integer: {n}")));
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::msg("not a string")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::msg("not a bool")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::msg("not an array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::msg("not an object")),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- emission -----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::msg("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::msg(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::msg(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => {
                    return Err(Error::msg(format!(
                        "bad array separator '{}' at byte {}",
                        c as char, self.i
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::msg(format!(
                        "bad object separator '{}' at byte {}",
                        c as char, self.i
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::msg("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| Error::msg("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::msg("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs unsupported (not needed here)
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| Error::msg("bad codepoint"))?,
                            );
                        }
                        _ => return Err(Error::msg("bad escape")),
                    }
                }
                c => {
                    // collect the rest of a UTF-8 sequence
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + len).min(self.b.len());
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| Error::msg("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::msg(format!("bad number '{s}' at byte {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "hi\n", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().usize_arr().unwrap(), vec![1, 2]);
        assert!(v.get("zzz").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::num(1.5)),
            ("y", Json::arr([Json::str("a"), Json::Null])),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }
}
