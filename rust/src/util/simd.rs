//! Runtime SIMD dispatch policy for the data-parallel kernels.
//!
//! The hot kernels — fused candidate scoring
//! ([`crate::runtime::kernels`]), bulk Pcg64 generation
//! ([`crate::prng::bulk`]) and the dense micro-kernels
//! ([`crate::tensor::linalg`]) — each ship a scalar implementation plus
//! hand-vectorized variants. This module owns the *one* process-wide
//! decision of which variant runs:
//!
//! 1. a CLI override plumbed through [`force`] (`--simd` on the `miracle`
//!    subcommands), highest precedence;
//! 2. the `MIRACLE_SIMD` env var — strict, like `MIRACLE_BACKEND`: the
//!    accepted values are `auto` / `scalar` / `avx2` / `neon`, anything
//!    else (or a path the CPU cannot run) is a hard error surfaced at
//!    [`crate::runtime::Runtime::cpu`] construction, never a silent
//!    fallback;
//! 3. runtime feature detection (`auto`): AVX2+FMA on x86_64 via
//!    `is_x86_feature_detected!`, NEON on aarch64 (baseline — always
//!    present), scalar everywhere else.
//!
//! The selection is resolved once and cached: kernels read it through
//! [`active`] (infallible — by the time a kernel runs, [`selected`] has
//! validated the env at runtime construction; a library caller that skips
//! that validation gets a one-time warning and the scalar reference path).
//!
//! Correctness contract (details in `docs/perf.md`): the scalar variant is
//! THE reference. Vector variants must be bit-identical for integer
//! kernels (bulk Pcg64 — so `.mrc` decode bytes never depend on the
//! path) and within a documented ulp tolerance for float kernels
//! (scoring logits, dot products — fresh-encode-only drift, same contract
//! the PR-2 constant hoisting established). `rust/tests/simd_parity.rs`
//! enforces both.

use std::sync::OnceLock;

use crate::util::Result;
use crate::{err, info};

/// One executable kernel family. `Avx2`/`Neon` exist on every
/// architecture so match arms stay portable; [`parse`]/[`detect`] only
/// ever yield a variant the current CPU can actually run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdPath {
    /// Portable reference implementation — always available.
    Scalar,
    /// x86_64 AVX2 + FMA (256-bit lanes), runtime-detected.
    Avx2,
    /// aarch64 NEON (128-bit lanes), baseline on every aarch64 CPU.
    Neon,
}

impl SimdPath {
    /// The name `MIRACLE_SIMD` accepts and logs/benches report.
    pub fn name(self) -> &'static str {
        match self {
            SimdPath::Scalar => "scalar",
            SimdPath::Avx2 => "avx2",
            SimdPath::Neon => "neon",
        }
    }
}

impl std::fmt::Display for SimdPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Best path the running CPU supports (the `auto` resolution).
pub fn detect() -> SimdPath {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        {
            return SimdPath::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline ISA; no detection needed.
        return SimdPath::Neon;
    }
    #[allow(unreachable_code)]
    SimdPath::Scalar
}

/// Strict parse of a `MIRACLE_SIMD`-style value. `auto`/empty resolve via
/// [`detect`]; explicit paths error if this build/CPU cannot run them —
/// a typo or an impossible request must never silently benchmark the
/// wrong kernels (same contract as `MIRACLE_BACKEND`).
pub fn parse(v: &str) -> Result<SimdPath> {
    match v {
        "" | "auto" => Ok(detect()),
        "scalar" => Ok(SimdPath::Scalar),
        "avx2" => {
            if detect() == SimdPath::Avx2 {
                Ok(SimdPath::Avx2)
            } else {
                err!(
                    "MIRACLE_SIMD=avx2 requested, but this CPU/build has no \
                     AVX2+FMA (use 'auto' or 'scalar')"
                )
            }
        }
        "neon" => {
            #[cfg(target_arch = "aarch64")]
            {
                Ok(SimdPath::Neon)
            }
            #[cfg(not(target_arch = "aarch64"))]
            {
                err!(
                    "MIRACLE_SIMD=neon requested, but this build does not \
                     target aarch64 (use 'auto' or 'scalar')"
                )
            }
        }
        other => err!(
            "unknown MIRACLE_SIMD '{other}' \
             (expected auto|scalar|avx2|neon)"
        ),
    }
}

static ACTIVE: OnceLock<SimdPath> = OnceLock::new();

/// Resolve (and cache) the dispatch path: a prior [`force`] wins, else the
/// `MIRACLE_SIMD` env var, strictly parsed. Called by
/// [`crate::runtime::Runtime::cpu`] and the bench drivers so an invalid
/// value fails loudly before any kernel runs.
pub fn selected() -> Result<SimdPath> {
    if let Some(p) = ACTIVE.get() {
        return Ok(*p);
    }
    let var = std::env::var("MIRACLE_SIMD").unwrap_or_default();
    let p = parse(var.as_str())?;
    let got = *ACTIVE.get_or_init(|| p);
    crate::obs_event!(crate::obs::Level::Info, "simd_dispatch",
        "path" => got.name(),
        "source" => if var.is_empty() { "auto" } else { "env" });
    Ok(got)
}

/// Pin the dispatch path from the CLI (`--simd`), before any kernel ran.
/// Errors if a different path was already resolved — a half-scalar,
/// half-vector run would make every perf or parity comparison meaningless.
pub fn force(p: SimdPath) -> Result<()> {
    match ACTIVE.get() {
        None => {
            let got = *ACTIVE.get_or_init(|| p);
            if got == p {
                crate::obs_event!(crate::obs::Level::Info, "simd_dispatch",
                    "path" => got.name(), "source" => "cli");
                Ok(())
            } else {
                err!(
                    "simd path already resolved to '{got}' before the \
                     '{p}' override could apply"
                )
            }
        }
        Some(&got) if got == p => Ok(()),
        Some(&got) => err!(
            "simd path already resolved to '{got}' before the '{p}' \
             override could apply"
        ),
    }
}

/// The path kernels dispatch on — infallible for hot-path use. If the env
/// var is invalid *and* nothing validated it earlier (library embedding
/// that never builds a [`crate::runtime::Runtime`]), warns once and pins
/// the scalar reference path.
pub fn active() -> SimdPath {
    if let Some(p) = ACTIVE.get() {
        return *p;
    }
    match selected() {
        Ok(p) => p,
        Err(e) => {
            info!("{e}; falling back to the scalar kernels");
            *ACTIVE.get_or_init(|| SimdPath::Scalar)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_strict() {
        assert!(parse("AVX2").is_err());
        assert!(parse("sse").is_err());
        assert!(parse("Scalar").is_err());
        let msg = parse("turbo").unwrap_err().to_string();
        assert!(msg.contains("MIRACLE_SIMD"), "{msg}");
        assert!(msg.contains("turbo"), "{msg}");
    }

    #[test]
    fn auto_and_scalar_always_parse() {
        assert_eq!(parse("").unwrap(), detect());
        assert_eq!(parse("auto").unwrap(), detect());
        assert_eq!(parse("scalar").unwrap(), SimdPath::Scalar);
    }

    #[test]
    fn detect_is_runnable_here() {
        // whatever detect() picks must be a path parse() accepts explicitly
        let p = detect();
        assert_eq!(parse(p.name()).unwrap(), p);
    }

    #[test]
    fn names_round_trip() {
        for p in [SimdPath::Scalar, SimdPath::Avx2, SimdPath::Neon] {
            assert_eq!(format!("{p}"), p.name());
        }
    }
}
