//! Summary statistics + a micro-benchmark harness (criterion substitute).

/// Streaming summary of a sample of f64s.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(2).saturating_sub(1) as f64;
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| s[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: s[0],
        max: s[n - 1],
        p50: pct(0.50),
        p95: pct(0.95),
        p99: pct(0.99),
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls; returns
/// per-iteration seconds.
pub fn bench_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Pretty row for bench output: name, mean time, throughput note.
pub fn report_bench(name: &str, samples: &[f64], unit_per_iter: Option<(f64, &str)>) {
    let s = summarize(samples);
    let mut line = format!(
        "{name:<44} {:>10.3} us/iter  (p50 {:.3}, p95 {:.3}, n={})",
        s.mean * 1e6,
        s.p50 * 1e6,
        s.p95 * 1e6,
        s.n
    );
    if let Some((units, label)) = unit_per_iter {
        line.push_str(&format!("  {:>10.2} {label}/s", units / s.mean));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(summarize(&[]).n, 0);
    }

    #[test]
    fn percentiles_monotone() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0usize;
        let samples = bench_fn(2, 5, || count += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(count, 7);
    }
}
