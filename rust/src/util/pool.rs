//! Scoped-thread worker pool for the candidate hot path — zero dependencies,
//! deterministic by construction.
//!
//! The only primitive is [`parallel_runs_mut`]: split a mutable buffer into
//! fixed-size *runs* (one per independent work item — e.g. one candidate
//! chunk's logits), hand each worker a contiguous span of whole runs, and
//! join. Workers write disjoint spans, so the result is bit-identical at
//! every thread count; any ordered reduction (Gumbel-max sampling, argmax)
//! happens afterwards on the caller's thread in run order. See
//! `docs/perf.md` for why this preserves the `.mrc` protocol exactly.
//!
//! Workers are *supervised*: a panicking worker is isolated with
//! `catch_unwind`, its span re-executed once on the calling thread, and only
//! a repeat failure surfaces as an error (carrying the panic payload) — see
//! [`parallel_runs_mut`] for the contract and `DESIGN.md` §Crash safety.
//!
//! Thread-count resolution, most specific wins:
//! 1. a scoped [`override_threads`]/[`with_threads`] guard on the calling
//!    thread (how `MiracleCfg::threads` and the invariance tests plumb in),
//! 2. the `MIRACLE_THREADS` env var (`0`/unset/invalid = auto, with a
//!    warning on invalid values),
//! 3. `std::thread::available_parallelism()`.
//!
//! Threads are scoped (`std::thread::scope`) and spawned per call: at the
//! hot path's granularity (a block's worth of chunks, millions of normal
//! draws) the ~tens of microseconds of spawn cost is noise, and no idle
//! pool threads linger in library callers.

use std::cell::Cell;
use std::sync::OnceLock;

thread_local! {
    static OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("MIRACLE_THREADS") {
        Err(_) => 0,
        Ok(v) if v.is_empty() || v == "0" => 0,
        Ok(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                crate::info!(
                    "ignoring invalid MIRACLE_THREADS '{v}' \
                     (want a positive integer; using auto)"
                );
                0
            }
        },
    })
}

/// The worker count a parallel region started from this thread would use.
pub fn current_threads() -> usize {
    let o = OVERRIDE.with(|c| c.get());
    if o != 0 {
        return o;
    }
    let e = env_threads();
    if e != 0 {
        return e;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// RAII guard restoring the previous per-thread override on drop.
pub struct ThreadsGuard {
    prev: usize,
    active: bool,
}

/// Override the worker count for parallel regions started from this thread
/// until the guard drops. `n = 0` is a no-op (keep env/auto resolution) so
/// config fields can be plumbed through unconditionally.
pub fn override_threads(n: usize) -> ThreadsGuard {
    if n == 0 {
        return ThreadsGuard { prev: 0, active: false };
    }
    let prev = OVERRIDE.with(|c| {
        let p = c.get();
        c.set(n);
        p
    });
    ThreadsGuard { prev, active: true }
}

impl Drop for ThreadsGuard {
    fn drop(&mut self) {
        if self.active {
            let p = self.prev;
            OVERRIDE.with(|c| c.set(p));
        }
    }
}

/// Run `f` with the worker count overridden to `n` (0 = no override).
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let _guard = override_threads(n);
    f()
}

/// Render a `catch_unwind` payload as the panic message it carried.
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Process `data` as `data.len() / run_len` fixed-size runs, fanned across
/// the pool. Each worker receives `f(first_run_index, span)` exactly once
/// with a contiguous span of whole runs and must handle
/// `span.chunks_mut(run_len)` itself (this lets it reuse per-worker scratch
/// buffers across its runs). Spans are disjoint, so output bytes are
/// identical at every thread count.
///
/// Worker panics are supervised rather than propagated: each worker runs
/// under `catch_unwind`, and a poisoned span is re-executed once *on the
/// calling thread* — `f` writes its span deterministically from
/// `(first_run, span)` alone, so the retry overwrites any partial output
/// and the result is bit-identical to a panic-free run. If the retry panics
/// too, the call fails with the worker's panic payload in the error (a
/// deterministic panic cannot be retried away; an environmental one — e.g.
/// a starved thread hitting a resource limit — can). Hours-long compression
/// runs therefore survive transient worker deaths instead of losing the
/// whole run at block N-1.
///
/// Panics if `run_len` is zero or does not divide `data.len()`.
pub fn parallel_runs_mut<T, F>(
    data: &mut [T],
    run_len: usize,
    f: F,
) -> crate::util::Result<()>
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    use std::panic::{catch_unwind, AssertUnwindSafe};
    assert!(run_len > 0, "parallel_runs_mut: run_len must be positive");
    assert!(
        data.len() % run_len == 0,
        "parallel_runs_mut: data length {} is not a multiple of run length {run_len}",
        data.len()
    );
    let n_runs = data.len() / run_len;
    if n_runs == 0 {
        return Ok(());
    }
    let nt = current_threads().min(n_runs);
    let per = (n_runs + nt - 1) / nt;
    // span boundaries as (first_run, run_count), so poisoned spans can be
    // re-sliced for the supervisor-thread retry after the scope ends
    let spans: Vec<(usize, usize)> = (0..nt)
        .map(|w| (w * per, per.min(n_runs.saturating_sub(w * per))))
        .filter(|&(_, take)| take > 0)
        .collect();
    // (span index, panic message) of every worker that died
    let poisoned: std::sync::Mutex<Vec<(usize, String)>> =
        std::sync::Mutex::new(Vec::new());
    if nt <= 1 {
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(0, &mut *data))) {
            poisoned.lock().unwrap().push((0, panic_message(p)));
        }
    } else {
        std::thread::scope(|scope| {
            let f = &f;
            let poisoned = &poisoned;
            let mut rest: &mut [T] = data;
            for (si, &(start, take)) in spans.iter().enumerate() {
                let (head, tail) = rest.split_at_mut(take * run_len);
                rest = tail;
                scope.spawn(move || {
                    let _sp = crate::obs::span("pool_worker");
                    if let Err(p) =
                        catch_unwind(AssertUnwindSafe(|| f(start, head)))
                    {
                        poisoned.lock().unwrap().push((si, panic_message(p)));
                    }
                });
            }
        });
    }
    let mut failures = poisoned.into_inner().unwrap();
    failures.sort_by(|a, b| a.0.cmp(&b.0));
    for (si, msg) in failures {
        let (start, take) = spans[si];
        crate::obs::metrics().pool_worker_panics.inc();
        crate::obs_event!(crate::obs::Level::Warn, "pool_worker_panic",
            "first_run" => start, "runs" => take, "panic" => msg.as_str());
        crate::info!(
            "pool: worker for runs {start}..{} panicked ({msg}); \
             retrying once on the supervisor thread",
            start + take
        );
        let span = &mut data[start * run_len..(start + take) * run_len];
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(start, span))) {
            return Err(crate::util::Error::msg(format!(
                "worker for runs {start}..{} panicked twice \
                 (supervisor retry included): {}",
                start + take,
                panic_message(p)
            )));
        }
        crate::obs::metrics().pool_worker_retries.inc();
        crate::obs_event!(crate::obs::Level::Info, "pool_worker_retry_ok",
            "first_run" => start, "runs" => take);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_run_exactly_once() {
        for threads in [1, 2, 3, 8] {
            let mut data = vec![0u32; 40];
            with_threads(threads, || {
                parallel_runs_mut(&mut data, 4, |first_run, span| {
                    for (i, run) in span.chunks_mut(4).enumerate() {
                        for v in run.iter_mut() {
                            *v += (first_run + i) as u32 + 1;
                        }
                    }
                })
                .unwrap();
            });
            let expect: Vec<u32> =
                (0..10u32).flat_map(|r| [r + 1; 4]).collect();
            assert_eq!(data, expect, "threads={threads}");
        }
    }

    #[test]
    fn output_is_thread_count_invariant() {
        let work = |first: usize, span: &mut [f64]| {
            for (i, run) in span.chunks_mut(3).enumerate() {
                let r = (first + i) as f64;
                run[0] = r.sin();
                run[1] = r.cos();
                run[2] = (r + 1.0).ln();
            }
        };
        let mut base = vec![0f64; 3 * 17];
        with_threads(1, || parallel_runs_mut(&mut base, 3, work).unwrap());
        for threads in [2, 5, 16] {
            let mut out = vec![0f64; 3 * 17];
            with_threads(threads, || {
                parallel_runs_mut(&mut out, 3, work).unwrap()
            });
            assert_eq!(out, base, "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_runs_is_fine() {
        let mut data = vec![0usize; 2];
        with_threads(64, || {
            parallel_runs_mut(&mut data, 1, |first, span| {
                span[0] = first + 7;
            })
            .unwrap();
        });
        assert_eq!(data, vec![7, 8]);
    }

    #[test]
    fn transient_worker_panic_is_retried_to_a_correct_result() {
        use std::sync::atomic::{AtomicBool, Ordering};
        for threads in [1, 2, 8] {
            // the first worker invocation that sees run 5 dies mid-span,
            // leaving partial writes; the supervisor retry must overwrite
            // them and produce the exact panic-free result
            let tripped = AtomicBool::new(false);
            let mut data = vec![0u32; 12];
            with_threads(threads, || {
                parallel_runs_mut(&mut data, 1, |first, span| {
                    for (i, run) in span.chunks_mut(1).enumerate() {
                        let r = first + i;
                        run[0] = r as u32 + 100;
                        if r == 5
                            && !tripped.swap(true, Ordering::SeqCst)
                        {
                            panic!("transient fault at run {r}");
                        }
                    }
                })
                .unwrap();
            });
            let expect: Vec<u32> = (100..112).collect();
            assert_eq!(data, expect, "threads={threads}");
        }
    }

    #[test]
    fn persistent_worker_panic_fails_with_the_payload() {
        for threads in [1, 4] {
            let mut data = vec![0u8; 8];
            let err = with_threads(threads, || {
                parallel_runs_mut(&mut data, 1, |first, _span| {
                    if first == 0 {
                        panic!("deterministic bug in run 0");
                    }
                })
            })
            .unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("deterministic bug in run 0"),
                "error must carry the worker's panic payload, got: {msg}"
            );
            assert!(msg.contains("panicked twice"), "got: {msg}");
        }
    }

    #[test]
    fn multiple_poisoned_spans_all_recover() {
        let mut data = vec![0u32; 16];
        with_threads(8, || {
            // every worker thread dies after writing; the closure only
            // succeeds on the supervisor thread, so all 8 spans go through
            // the retry path and must still produce the panic-free result
            let mut expect_ok = vec![0u32; 16];
            parallel_runs_mut(&mut expect_ok, 2, |first, span| {
                for (i, run) in span.chunks_mut(2).enumerate() {
                    run[0] = (first + i) as u32;
                    run[1] = (first + i) as u32 * 2;
                }
            })
            .unwrap();
            let main_thread = std::thread::current().id();
            parallel_runs_mut(&mut data, 2, |first, span| {
                for (i, run) in span.chunks_mut(2).enumerate() {
                    run[0] = (first + i) as u32;
                    run[1] = (first + i) as u32 * 2;
                }
                // die on every worker thread, succeed on the supervisor
                if std::thread::current().id() != main_thread {
                    panic!("worker death in span at {first}");
                }
            })
            .unwrap();
            assert_eq!(data, expect_ok);
        });
    }

    #[test]
    fn override_guard_scopes_and_restores() {
        let auto = current_threads();
        assert!(auto >= 1);
        with_threads(3, || {
            assert_eq!(current_threads(), 3);
            with_threads(5, || assert_eq!(current_threads(), 5));
            assert_eq!(current_threads(), 3);
            // 0 = no override: outer scope still visible
            with_threads(0, || assert_eq!(current_threads(), 3));
        });
        assert_eq!(current_threads(), auto);
    }

    #[test]
    fn empty_data_is_a_no_op() {
        let mut data: Vec<u8> = Vec::new();
        parallel_runs_mut(&mut data, 4, |_, _| panic!("no runs to process"))
            .unwrap();
    }
}
