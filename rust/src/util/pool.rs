//! Scoped-thread worker pool for the candidate hot path — zero dependencies,
//! deterministic by construction.
//!
//! The only primitive is [`parallel_runs_mut`]: split a mutable buffer into
//! fixed-size *runs* (one per independent work item — e.g. one candidate
//! chunk's logits), hand each worker a contiguous span of whole runs, and
//! join. Workers write disjoint spans, so the result is bit-identical at
//! every thread count; any ordered reduction (Gumbel-max sampling, argmax)
//! happens afterwards on the caller's thread in run order. See
//! `docs/perf.md` for why this preserves the `.mrc` protocol exactly.
//!
//! Thread-count resolution, most specific wins:
//! 1. a scoped [`override_threads`]/[`with_threads`] guard on the calling
//!    thread (how `MiracleCfg::threads` and the invariance tests plumb in),
//! 2. the `MIRACLE_THREADS` env var (`0`/unset/invalid = auto, with a
//!    warning on invalid values),
//! 3. `std::thread::available_parallelism()`.
//!
//! Threads are scoped (`std::thread::scope`) and spawned per call: at the
//! hot path's granularity (a block's worth of chunks, millions of normal
//! draws) the ~tens of microseconds of spawn cost is noise, and no idle
//! pool threads linger in library callers.

use std::cell::Cell;
use std::sync::OnceLock;

thread_local! {
    static OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("MIRACLE_THREADS") {
        Err(_) => 0,
        Ok(v) if v.is_empty() || v == "0" => 0,
        Ok(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                crate::info!(
                    "ignoring invalid MIRACLE_THREADS '{v}' \
                     (want a positive integer; using auto)"
                );
                0
            }
        },
    })
}

/// The worker count a parallel region started from this thread would use.
pub fn current_threads() -> usize {
    let o = OVERRIDE.with(|c| c.get());
    if o != 0 {
        return o;
    }
    let e = env_threads();
    if e != 0 {
        return e;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// RAII guard restoring the previous per-thread override on drop.
pub struct ThreadsGuard {
    prev: usize,
    active: bool,
}

/// Override the worker count for parallel regions started from this thread
/// until the guard drops. `n = 0` is a no-op (keep env/auto resolution) so
/// config fields can be plumbed through unconditionally.
pub fn override_threads(n: usize) -> ThreadsGuard {
    if n == 0 {
        return ThreadsGuard { prev: 0, active: false };
    }
    let prev = OVERRIDE.with(|c| {
        let p = c.get();
        c.set(n);
        p
    });
    ThreadsGuard { prev, active: true }
}

impl Drop for ThreadsGuard {
    fn drop(&mut self) {
        if self.active {
            let p = self.prev;
            OVERRIDE.with(|c| c.set(p));
        }
    }
}

/// Run `f` with the worker count overridden to `n` (0 = no override).
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let _guard = override_threads(n);
    f()
}

/// Process `data` as `data.len() / run_len` fixed-size runs, fanned across
/// the pool. Each worker receives `f(first_run_index, span)` exactly once
/// with a contiguous span of whole runs and must handle
/// `span.chunks_mut(run_len)` itself (this lets it reuse per-worker scratch
/// buffers across its runs). Spans are disjoint, so output bytes are
/// identical at every thread count.
///
/// Panics if `run_len` is zero or does not divide `data.len()`. Worker
/// panics propagate to the caller after all workers joined.
pub fn parallel_runs_mut<T, F>(data: &mut [T], run_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(run_len > 0, "parallel_runs_mut: run_len must be positive");
    assert!(
        data.len() % run_len == 0,
        "parallel_runs_mut: data length {} is not a multiple of run length {run_len}",
        data.len()
    );
    let n_runs = data.len() / run_len;
    if n_runs == 0 {
        return;
    }
    let nt = current_threads().min(n_runs);
    if nt <= 1 {
        f(0, data);
        return;
    }
    let per = (n_runs + nt - 1) / nt;
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = data;
        let mut start = 0usize;
        while start < n_runs {
            let take = per.min(n_runs - start);
            let slice = std::mem::take(&mut rest);
            let (head, tail) = slice.split_at_mut(take * run_len);
            rest = tail;
            scope.spawn(move || f(start, head));
            start += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_run_exactly_once() {
        for threads in [1, 2, 3, 8] {
            let mut data = vec![0u32; 40];
            with_threads(threads, || {
                parallel_runs_mut(&mut data, 4, |first_run, span| {
                    for (i, run) in span.chunks_mut(4).enumerate() {
                        for v in run.iter_mut() {
                            *v += (first_run + i) as u32 + 1;
                        }
                    }
                });
            });
            let expect: Vec<u32> =
                (0..10u32).flat_map(|r| [r + 1; 4]).collect();
            assert_eq!(data, expect, "threads={threads}");
        }
    }

    #[test]
    fn output_is_thread_count_invariant() {
        let work = |first: usize, span: &mut [f64]| {
            for (i, run) in span.chunks_mut(3).enumerate() {
                let r = (first + i) as f64;
                run[0] = r.sin();
                run[1] = r.cos();
                run[2] = (r + 1.0).ln();
            }
        };
        let mut base = vec![0f64; 3 * 17];
        with_threads(1, || parallel_runs_mut(&mut base, 3, work));
        for threads in [2, 5, 16] {
            let mut out = vec![0f64; 3 * 17];
            with_threads(threads, || parallel_runs_mut(&mut out, 3, work));
            assert_eq!(out, base, "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_runs_is_fine() {
        let mut data = vec![0usize; 2];
        with_threads(64, || {
            parallel_runs_mut(&mut data, 1, |first, span| {
                span[0] = first + 7;
            });
        });
        assert_eq!(data, vec![7, 8]);
    }

    #[test]
    fn override_guard_scopes_and_restores() {
        let auto = current_threads();
        assert!(auto >= 1);
        with_threads(3, || {
            assert_eq!(current_threads(), 3);
            with_threads(5, || assert_eq!(current_threads(), 5));
            assert_eq!(current_threads(), 3);
            // 0 = no override: outer scope still visible
            with_threads(0, || assert_eq!(current_threads(), 3));
        });
        assert_eq!(current_threads(), auto);
    }

    #[test]
    fn empty_data_is_a_no_op() {
        let mut data: Vec<u8> = Vec::new();
        parallel_runs_mut(&mut data, 4, |_, _| panic!("no runs to process"));
    }
}
