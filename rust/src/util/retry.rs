//! Retry with exponential backoff, deterministic seeded jitter and a hard
//! sleep budget.
//!
//! The serve loop wraps its backend calls (batched `eval_batch`, lazy block
//! decode) in [`retry_with`] so a *transient* fault — a worker hiccup, an
//! injected chaos failure — costs a few milliseconds instead of a failed
//! request, while a *persistent* fault still surfaces quickly: attempts are
//! capped and the total time spent sleeping can never exceed
//! [`RetryPolicy::budget`], so retries cannot stall the loop into missing
//! every other request's deadline.
//!
//! Jitter is drawn from a seeded [`Pcg64`] stream, not the wall clock: the
//! same `(policy, seed)` always produces the same delay sequence, which is
//! what lets `rust/tests/server_resilience.rs` and `miracle chaos-serve`
//! reproduce a failure from the seed alone.

use std::time::Duration;

use crate::prng::Pcg64;
use crate::util::Result;

/// Backoff shape shared by every retried operation.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts including the first; `1` disables retries.
    pub max_attempts: u32,
    /// Delay before the first retry.
    pub base: Duration,
    /// Multiplier applied per retry (exponential growth).
    pub factor: f64,
    /// Cap on any single delay.
    pub max_delay: Duration,
    /// Hard cap on the *total* time slept across all retries of one
    /// operation. Exhausting it ends retrying even if attempts remain.
    pub budget: Duration,
    /// Jitter fraction in `[0, 1]`: each delay is scaled by
    /// `1 - jitter * u` with `u ~ U[0, 1)` from the seeded stream. `0.0`
    /// makes the schedule exactly the exponential sequence.
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(2),
            factor: 2.0,
            max_delay: Duration::from_millis(50),
            budget: Duration::from_millis(200),
            jitter: 0.5,
        }
    }
}

impl RetryPolicy {
    /// A policy that never retries (single attempt, zero sleeping).
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            base: Duration::ZERO,
            factor: 1.0,
            max_delay: Duration::ZERO,
            budget: Duration::ZERO,
            jitter: 0.0,
        }
    }
}

/// Backoff state for one logical operation: hands out the delay before each
/// retry until attempts or the sleep budget run out.
#[derive(Debug, Clone)]
pub struct Backoff {
    policy: RetryPolicy,
    rng: Pcg64,
    retries: u32,
    slept: Duration,
}

impl Backoff {
    /// A fresh schedule. The same `(policy, seed)` yields the same delays.
    pub fn new(policy: &RetryPolicy, seed: u64) -> Backoff {
        Backoff {
            policy: policy.clone(),
            rng: Pcg64::seed(seed ^ 0x5E7B_ACC0_FF5E_7B0F),
            retries: 0,
            slept: Duration::ZERO,
        }
    }

    /// Retries handed out so far (== attempts beyond the first).
    pub fn retries(&self) -> u32 {
        self.retries
    }

    /// The delay to sleep before the next retry, or `None` once attempts or
    /// the sleep budget are exhausted. The returned delay is already clamped
    /// into the remaining budget.
    pub fn next_delay(&mut self) -> Option<Duration> {
        if self.retries + 1 >= self.policy.max_attempts.max(1) {
            return None;
        }
        let remaining = self.policy.budget.checked_sub(self.slept)?;
        if remaining.is_zero() {
            return None;
        }
        let exp = self.policy.base.as_secs_f64()
            * self.policy.factor.powi(self.retries as i32);
        let capped = exp.min(self.policy.max_delay.as_secs_f64()).max(0.0);
        let scale = (1.0 - self.policy.jitter * self.rng.next_f64()).max(0.0);
        let delay = Duration::from_secs_f64(capped * scale).min(remaining);
        self.slept += delay;
        self.retries += 1;
        Some(delay)
    }

    /// Drain the whole schedule (test/diagnostic helper).
    pub fn schedule(mut self) -> Vec<Duration> {
        let mut out = Vec::new();
        while let Some(d) = self.next_delay() {
            out.push(d);
        }
        out
    }
}

/// Run `op` under `policy`, sleeping through the injected `sleep` hook
/// between attempts. Returns the final result plus the number of retries
/// performed (0 = first attempt succeeded or retries were disabled).
///
/// `op` receives the 0-based attempt number. The `sleep` hook exists so the
/// serve loop owns its own blocking and unit tests can record the schedule
/// instead of actually waiting.
pub fn retry_with<T, F, S>(
    policy: &RetryPolicy,
    seed: u64,
    mut sleep: S,
    mut op: F,
) -> (Result<T>, u32)
where
    F: FnMut(u32) -> Result<T>,
    S: FnMut(Duration),
{
    let mut backoff = Backoff::new(policy, seed);
    loop {
        let attempt = backoff.retries();
        match op(attempt) {
            Ok(v) => {
                let retries = backoff.retries();
                if retries > 0 {
                    crate::obs::metrics().retries_absorbed.add(retries as u64);
                    crate::obs_event!(crate::obs::Level::Info, "retry_absorbed",
                        "seed" => seed, "retries" => retries);
                }
                return (Ok(v), retries);
            }
            Err(e) => match backoff.next_delay() {
                Some(d) => {
                    crate::obs_event!(crate::obs::Level::Debug, "retry_attempt",
                        "seed" => seed,
                        "attempt" => backoff.retries(),
                        "delay_us" => d.as_micros() as u64,
                        "error" => e.to_string());
                    sleep(d)
                }
                None => {
                    if backoff.retries() > 0 {
                        crate::obs::metrics().retries_exhausted.inc();
                        crate::obs_event!(crate::obs::Level::Warn, "retry_exhausted",
                            "seed" => seed,
                            "retries" => backoff.retries(),
                            "error" => e.to_string());
                    }
                    return (Err(e), backoff.retries());
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::err;

    fn policy() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base: Duration::from_millis(10),
            factor: 2.0,
            max_delay: Duration::from_millis(500),
            budget: Duration::from_secs(5),
            jitter: 0.5,
        }
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let a = Backoff::new(&policy(), 42).schedule();
        let b = Backoff::new(&policy(), 42).schedule();
        assert_eq!(a, b);
        assert_eq!(a.len(), 4, "max_attempts 5 => 4 retries");
        let c = Backoff::new(&policy(), 43).schedule();
        assert_ne!(a, c, "different seeds must jitter differently");
    }

    #[test]
    fn zero_jitter_is_the_exact_exponential_sequence() {
        let mut p = policy();
        p.jitter = 0.0;
        let s = Backoff::new(&p, 7).schedule();
        assert_eq!(
            s,
            vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(40),
                Duration::from_millis(80),
            ]
        );
    }

    #[test]
    fn jitter_only_shrinks_delays() {
        let jittered = Backoff::new(&policy(), 11).schedule();
        let mut p = policy();
        p.jitter = 0.0;
        let exact = Backoff::new(&p, 11).schedule();
        for (j, e) in jittered.iter().zip(&exact) {
            assert!(j <= e, "jitter must never exceed the base delay");
            assert!(*j >= Duration::from_millis(5), "jitter 0.5 halves at most");
        }
    }

    #[test]
    fn max_delay_caps_growth() {
        let mut p = policy();
        p.jitter = 0.0;
        p.max_attempts = 12;
        p.max_delay = Duration::from_millis(25);
        p.budget = Duration::from_secs(60);
        let s = Backoff::new(&p, 1).schedule();
        assert_eq!(s[0], Duration::from_millis(10));
        assert_eq!(s[1], Duration::from_millis(20));
        for d in &s[2..] {
            assert_eq!(*d, Duration::from_millis(25));
        }
    }

    #[test]
    fn budget_exhaustion_stops_retrying() {
        let mut p = policy();
        p.jitter = 0.0;
        p.max_attempts = 100;
        p.budget = Duration::from_millis(35);
        let s = Backoff::new(&p, 9).schedule();
        // 10 + 20 + (clamped 5) = 35ms, then nothing
        assert_eq!(
            s,
            vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(5),
            ]
        );
        let total: Duration = s.iter().sum();
        assert_eq!(total, p.budget, "total sleep equals the budget exactly");
    }

    #[test]
    fn none_policy_never_sleeps() {
        assert!(Backoff::new(&RetryPolicy::none(), 3).schedule().is_empty());
    }

    #[test]
    fn retry_with_recovers_from_transient_failures() {
        let mut failures_left = 2u32;
        let mut slept = Vec::new();
        let (res, retries) = retry_with(
            &policy(),
            17,
            |d| slept.push(d),
            |attempt| {
                if failures_left > 0 {
                    failures_left -= 1;
                    err!("transient (attempt {attempt})")
                } else {
                    Ok(attempt)
                }
            },
        );
        assert_eq!(res.unwrap(), 2, "succeeded on the third attempt");
        assert_eq!(retries, 2);
        assert_eq!(slept.len(), 2);
    }

    #[test]
    fn retry_with_gives_up_with_the_last_error() {
        let (res, retries) = retry_with::<(), _, _>(
            &policy(),
            23,
            |_| {},
            |attempt| err!("always fails (attempt {attempt})"),
        );
        let msg = res.unwrap_err().to_string();
        assert!(msg.contains("attempt 4"), "last error surfaces: {msg}");
        assert_eq!(retries, 4);
    }

    #[test]
    fn retry_with_none_policy_is_a_single_attempt() {
        let mut calls = 0u32;
        let (res, retries) = retry_with::<(), _, _>(
            &RetryPolicy::none(),
            0,
            |_| panic!("must not sleep"),
            |_| {
                calls += 1;
                err!("fails")
            },
        );
        assert!(res.is_err());
        assert_eq!(calls, 1);
        assert_eq!(retries, 0);
    }
}
