//! Circuit breaker for the serve loop's backend calls.
//!
//! Classic three-state machine. **Closed**: calls flow; outcomes feed a
//! rolling window and the breaker trips to Open when the failure ratio over
//! at least `min_samples` recent calls reaches `trip_ratio`. **Open**: calls
//! are refused instantly (the server degrades to a fast per-request error
//! instead of burning a retry budget per request) until `cooldown` elapses.
//! **HalfOpen**: up to `probes` trial calls are admitted; any failure
//! re-trips to Open with a fresh cooldown, while `probes` consecutive
//! successes close the breaker and clear the window.
//!
//! Time is always passed in as an [`Instant`] parameter — the breaker never
//! reads the clock itself — so unit tests and the chaos harness drive the
//! state machine with synthetic offsets instead of real sleeping.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Breaker state, observable for stats/diagnostics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    /// Normal operation; outcomes are being windowed.
    Closed,
    /// Tripped: all calls refused until the cooldown deadline.
    Open,
    /// Cooldown elapsed: admitting a limited number of probe calls.
    HalfOpen,
}

/// Trip/recovery thresholds.
#[derive(Debug, Clone)]
pub struct BreakerCfg {
    /// Rolling window length (outcomes remembered while Closed).
    pub window: usize,
    /// Minimum outcomes in the window before the ratio can trip.
    pub min_samples: usize,
    /// Failure ratio in `[0, 1]` that trips the breaker.
    pub trip_ratio: f64,
    /// How long Open refuses calls before moving to HalfOpen.
    pub cooldown: Duration,
    /// Consecutive probe successes required in HalfOpen to close.
    pub probes: u32,
}

impl Default for BreakerCfg {
    fn default() -> BreakerCfg {
        BreakerCfg {
            window: 16,
            min_samples: 4,
            trip_ratio: 0.5,
            cooldown: Duration::from_millis(100),
            probes: 2,
        }
    }
}

/// The state machine. Drive it with [`Breaker::allow`] before each guarded
/// call and [`Breaker::record`] after.
#[derive(Debug, Clone)]
pub struct Breaker {
    cfg: BreakerCfg,
    state: State,
    window: VecDeque<bool>,
    opened_at: Option<Instant>,
    probe_successes: u32,
    probes_in_flight: u32,
    trips: u64,
}

impl Breaker {
    pub fn new(cfg: BreakerCfg) -> Breaker {
        Breaker {
            cfg,
            state: State::Closed,
            window: VecDeque::new(),
            opened_at: None,
            probe_successes: 0,
            probes_in_flight: 0,
            trips: 0,
        }
    }

    pub fn state(&self) -> State {
        self.state
    }

    /// Times the breaker has transitioned into Open (including re-trips
    /// from failed HalfOpen probes).
    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// Remaining cooldown if a call at `now` would be refused.
    pub fn retry_after(&self, now: Instant) -> Option<Duration> {
        match (self.state, self.opened_at) {
            (State::Open, Some(at)) => {
                let deadline = at + self.cfg.cooldown;
                (now < deadline).then(|| deadline - now)
            }
            _ => None,
        }
    }

    /// Should a call at `now` be attempted? Open flips to HalfOpen once the
    /// cooldown has elapsed; HalfOpen admits at most `probes` in-flight
    /// trial calls.
    pub fn allow(&mut self, now: Instant) -> bool {
        match self.state {
            State::Closed => true,
            State::Open => {
                let elapsed = self
                    .opened_at
                    .map(|at| now.duration_since(at) >= self.cfg.cooldown)
                    .unwrap_or(true);
                if elapsed {
                    self.state = State::HalfOpen;
                    self.probe_successes = 0;
                    self.probes_in_flight = 1;
                    crate::obs_event!(crate::obs::Level::Info, "breaker_half_open",
                        "probes" => self.cfg.probes);
                    true
                } else {
                    false
                }
            }
            State::HalfOpen => {
                if self.probes_in_flight < self.cfg.probes.max(1) {
                    self.probes_in_flight += 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Feed the outcome of a call that `allow` admitted.
    pub fn record(&mut self, now: Instant, ok: bool) {
        match self.state {
            State::Closed => {
                self.window.push_back(ok);
                while self.window.len() > self.cfg.window.max(1) {
                    self.window.pop_front();
                }
                if self.should_trip() {
                    self.trip(now);
                }
            }
            State::HalfOpen => {
                self.probes_in_flight = self.probes_in_flight.saturating_sub(1);
                if ok {
                    self.probe_successes += 1;
                    if self.probe_successes >= self.cfg.probes.max(1) {
                        self.close();
                    }
                } else {
                    self.trip(now);
                }
            }
            // A straggler finishing after a concurrent trip: the window was
            // already judged, so the late outcome is dropped.
            State::Open => {}
        }
    }

    fn should_trip(&self) -> bool {
        let n = self.window.len();
        if n < self.cfg.min_samples.max(1) {
            return false;
        }
        let failures = self.window.iter().filter(|ok| !**ok).count();
        failures as f64 / n as f64 >= self.cfg.trip_ratio
    }

    fn trip(&mut self, now: Instant) {
        self.state = State::Open;
        self.opened_at = Some(now);
        self.window.clear();
        self.probe_successes = 0;
        self.probes_in_flight = 0;
        self.trips += 1;
        // exactly one event per trip, so event-log counts reconcile with
        // `trips()` (asserted by chaos-serve --events-out)
        crate::obs::metrics().breaker_trips.inc();
        crate::obs_event!(crate::obs::Level::Warn, "breaker_open",
            "trips" => self.trips,
            "cooldown_ms" => self.cfg.cooldown.as_millis() as u64);
    }

    fn close(&mut self) {
        self.state = State::Closed;
        self.opened_at = None;
        self.window.clear();
        self.probe_successes = 0;
        self.probes_in_flight = 0;
        crate::obs_event!(crate::obs::Level::Info, "breaker_closed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BreakerCfg {
        BreakerCfg {
            window: 8,
            min_samples: 4,
            trip_ratio: 0.5,
            cooldown: Duration::from_millis(100),
            probes: 2,
        }
    }

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn stays_closed_under_min_samples() {
        let t0 = Instant::now();
        let mut b = Breaker::new(cfg());
        for _ in 0..3 {
            assert!(b.allow(t0));
            b.record(t0, false);
        }
        assert_eq!(b.state(), State::Closed, "3 < min_samples, no trip yet");
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn trips_at_failure_ratio_and_refuses_during_cooldown() {
        let t0 = Instant::now();
        let mut b = Breaker::new(cfg());
        for i in 0..4 {
            assert!(b.allow(t0));
            b.record(t0, i % 2 == 0); // 2 ok, 2 fail => ratio 0.5
        }
        assert_eq!(b.state(), State::Open);
        assert_eq!(b.trips(), 1);
        assert!(!b.allow(t0 + ms(50)), "mid-cooldown calls refused");
        let after = b.retry_after(t0 + ms(50)).unwrap();
        assert_eq!(after, ms(50));
    }

    #[test]
    fn mostly_ok_traffic_never_trips() {
        let t0 = Instant::now();
        let mut b = Breaker::new(cfg());
        for i in 0..100 {
            assert!(b.allow(t0));
            b.record(t0, i % 4 != 0); // 25% failures < trip_ratio 0.5
        }
        assert_eq!(b.state(), State::Closed);
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn half_open_probe_successes_close_the_breaker() {
        let t0 = Instant::now();
        let mut b = Breaker::new(cfg());
        for _ in 0..4 {
            b.allow(t0);
            b.record(t0, false);
        }
        assert_eq!(b.state(), State::Open);

        let t1 = t0 + ms(100); // cooldown elapsed
        assert!(b.allow(t1), "first probe admitted");
        assert_eq!(b.state(), State::HalfOpen);
        assert!(b.allow(t1), "second probe admitted (probes = 2)");
        assert!(!b.allow(t1), "third concurrent probe refused");
        b.record(t1, true);
        assert_eq!(b.state(), State::HalfOpen, "one success is not enough");
        assert!(b.allow(t1), "slot freed by the recorded probe");
        b.record(t1, true);
        assert_eq!(b.state(), State::Closed, "probe quota met, closed");
        assert_eq!(b.trips(), 1);
        assert!(b.allow(t1 + ms(1)));
    }

    #[test]
    fn half_open_probe_failure_retrips_with_fresh_cooldown() {
        let t0 = Instant::now();
        let mut b = Breaker::new(cfg());
        for _ in 0..4 {
            b.allow(t0);
            b.record(t0, false);
        }
        let t1 = t0 + ms(100);
        assert!(b.allow(t1));
        b.record(t1, false); // probe fails
        assert_eq!(b.state(), State::Open);
        assert_eq!(b.trips(), 2);
        assert!(!b.allow(t1 + ms(99)), "cooldown restarted at t1");
        assert!(b.allow(t1 + ms(100)), "fresh cooldown elapses from t1");
    }

    #[test]
    fn window_is_cleared_after_recovery() {
        let t0 = Instant::now();
        let mut b = Breaker::new(cfg());
        for _ in 0..4 {
            b.allow(t0);
            b.record(t0, false);
        }
        let t1 = t0 + ms(100);
        b.allow(t1);
        b.record(t1, true);
        b.allow(t1);
        b.record(t1, true);
        assert_eq!(b.state(), State::Closed);
        // One failure right after recovery must not trip (window restarted).
        b.allow(t1);
        b.record(t1, false);
        assert_eq!(b.state(), State::Closed);
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn late_outcome_while_open_is_ignored() {
        let t0 = Instant::now();
        let mut b = Breaker::new(cfg());
        for _ in 0..4 {
            b.allow(t0);
            b.record(t0, false);
        }
        assert_eq!(b.state(), State::Open);
        b.record(t0, true); // straggler from before the trip
        assert_eq!(b.state(), State::Open);
        assert_eq!(b.trips(), 1);
    }
}
