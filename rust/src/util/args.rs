//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Unknown flags are an error; every accessor records its key so `finish()`
//! can report unused arguments.

use std::collections::BTreeMap;

use crate::util::{Error, Result};

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    used: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an explicit token list (first token must NOT be argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        items: I,
        known_flags: &[&str],
    ) -> Result<Args> {
        let mut a = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    a.flags.push(body.to_string());
                } else if it.peek().is_some() {
                    let v = it.next().unwrap();
                    a.opts.insert(body.to_string(), v);
                } else {
                    return Err(Error::msg(format!("--{body} needs a value")));
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn parse(known_flags: &[&str]) -> Result<Args> {
        Args::parse_from(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.used.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.used.borrow_mut().push(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or(default).to_string()
    }

    pub fn require(&self, name: &str) -> Result<String> {
        self.opt_str(name)
            .map(str::to_string)
            .ok_or_else(|| Error::msg(format!("missing required --{name}")))
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt_str(name) {
            Some(s) => Ok(s.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt_str(name) {
            Some(s) => Ok(s.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt_str(name) {
            Some(s) => Ok(s.parse()?),
            None => Ok(default),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error on any option that was provided but never read.
    pub fn finish(&self) -> Result<()> {
        let used = self.used.borrow();
        for k in self.opts.keys() {
            if !used.iter().any(|u| u == k) {
                return Err(Error::msg(format!("unknown option --{k}")));
            }
        }
        for f in &self.flags {
            if !used.iter().any(|u| u == f) {
                return Err(Error::msg(format!("unknown flag --{f}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_opts_flags_positional() {
        let a = Args::parse_from(toks("--x 1 --y=2 --verbose pos1 pos2"),
                                 &["verbose"]).unwrap();
        assert_eq!(a.usize("x", 0).unwrap(), 1);
        assert_eq!(a.usize("y", 0).unwrap(), 2);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1", "pos2"]);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_caught_by_finish() {
        let a = Args::parse_from(toks("--mystery 5"), &[]).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_required() {
        let a = Args::parse_from(toks(""), &[]).unwrap();
        assert!(a.require("config").is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(toks(""), &[]).unwrap();
        assert_eq!(a.f64("lr", 0.001).unwrap(), 0.001);
        assert_eq!(a.str("name", "d"), "d");
        assert!(!a.flag("quiet"));
    }
}
