//! Shared utilities: error type, CLI args, JSON, stats, logging,
//! prop-testing, the scoped-thread worker pool ([`pool`]), CRC-32
//! ([`crc32`]) and the deterministic fault-injection harness
//! ([`faultline`]).

pub mod args;
pub mod crc32;
pub mod faultline;
pub mod json;
pub mod pool;
pub mod quickprop;
pub mod stats;

use std::fmt;

/// Library-wide error type (anyhow-style but owned; carries a message chain).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<S: Into<String>>(s: S) -> Error {
        Error { msg: s.into() }
    }

    pub fn context<S: Into<String>>(self, s: S) -> Error {
        Error { msg: format!("{}: {}", s.into(), self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(format!("io: {e}"))
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::msg(format!("xla: {e}"))
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(format!("parse int: {e}"))
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(format!("parse float: {e}"))
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// `err!(...)` — format an `Err(Error)`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => { Err($crate::util::Error::msg(format!($($arg)*))) };
}

/// `ensure!(cond, ...)` — bail with a formatted error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::Error::msg(format!($($arg)*)));
        }
    };
}

/// Wall-clock timer for coarse phase timing.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Log level gate, settable via `MIRACLE_LOG` (0=quiet, 1=info, 2=debug).
pub fn log_level() -> u8 {
    static LEVEL: std::sync::OnceLock<u8> = std::sync::OnceLock::new();
    *LEVEL.get_or_init(|| {
        std::env::var("MIRACLE_LOG")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1)
    })
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 1 { eprintln!("[miracle] {}", format!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 2 { eprintln!("[miracle:dbg] {}", format!($($arg)*)); }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_context_chains() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn ensure_macro() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
    }
}
