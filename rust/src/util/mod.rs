//! Shared utilities: error type, CLI args, JSON, stats, logging,
//! prop-testing, the scoped-thread worker pool ([`pool`]), the SIMD
//! dispatch policy ([`simd`]), CRC-32 ([`crc32`]), the deterministic
//! fault-injection harness ([`faultline`]) and the serving resilience
//! primitives ([`retry`], [`breaker`]).

pub mod args;
pub mod breaker;
pub mod crc32;
pub mod faultline;
pub mod json;
pub mod pool;
pub mod quickprop;
pub mod retry;
pub mod simd;
pub mod stats;

use std::fmt;

/// Library-wide error type (anyhow-style but owned; carries a message chain
/// and, optionally, one typed payload for callers that need to react to a
/// *specific* failure — e.g. the coordinator's `--on-nonfinite` policy
/// downcasting a `NonFinite { step, block }` out of a `train_step` error).
pub struct Error {
    msg: String,
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    pub fn msg<S: Into<String>>(s: S) -> Error {
        Error { msg: s.into(), payload: None }
    }

    /// An error carrying a typed payload retrievable via [`Error::payload`].
    pub fn with_payload<S, P>(s: S, payload: P) -> Error
    where
        S: Into<String>,
        P: std::any::Any + Send + Sync,
    {
        Error { msg: s.into(), payload: Some(Box::new(payload)) }
    }

    /// Downcast the attached payload, if any. Context wrapping preserves it.
    pub fn payload<P: std::any::Any>(&self) -> Option<&P> {
        self.payload.as_ref().and_then(|p| p.downcast_ref())
    }

    pub fn context<S: Into<String>>(self, s: S) -> Error {
        Error {
            msg: format!("{}: {}", s.into(), self.msg),
            payload: self.payload,
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Error")
            .field("msg", &self.msg)
            .field("has_payload", &self.payload.is_some())
            .finish()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(format!("io: {e}"))
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::msg(format!("xla: {e}"))
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(format!("parse int: {e}"))
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(format!("parse float: {e}"))
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// `err!(...)` — format an `Err(Error)`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => { Err($crate::util::Error::msg(format!($($arg)*))) };
}

/// `ensure!(cond, ...)` — bail with a formatted error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::Error::msg(format!($($arg)*)));
        }
    };
}

/// Wall-clock timer for coarse phase timing.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Log level gate, settable via `MIRACLE_LOG` (0=quiet, 1=info, 2=debug).
pub fn log_level() -> u8 {
    static LEVEL: std::sync::OnceLock<u8> = std::sync::OnceLock::new();
    *LEVEL.get_or_init(|| {
        std::env::var("MIRACLE_LOG")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1)
    })
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 1 { eprintln!("[miracle] {}", format!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 2 { eprintln!("[miracle:dbg] {}", format!($($arg)*)); }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_context_chains() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn payload_survives_context_wrapping() {
        #[derive(Debug, PartialEq)]
        struct Marker(u32);
        let e = Error::with_payload("boom", Marker(7)).context("outer");
        assert_eq!(e.to_string(), "outer: boom");
        assert_eq!(e.payload::<Marker>(), Some(&Marker(7)));
        assert!(e.payload::<String>().is_none());
        assert!(Error::msg("plain").payload::<Marker>().is_none());
    }

    #[test]
    fn ensure_macro() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
    }
}
