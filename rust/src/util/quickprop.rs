//! Mini property-based testing framework (proptest substitute for the
//! offline vendor set).
//!
//! `check(name, cases, |g| { ... })` runs a closure against `cases`
//! independently seeded generators. On failure it re-runs a bounded shrink
//! loop over the seed space is not attempted (seeds are reported instead so
//! a failure is reproducible: re-run with `QuickProp::with_seed`).

use crate::prng::Pcg64;

/// Value generator handed to property closures.
pub struct Gen {
    pub rng: Pcg64,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_u64(&mut self, len: usize) -> Vec<u64> {
        (0..len).map(|_| self.rng.next_u64()).collect()
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Run `prop` for `cases` random cases; panic with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(0xD1B54A32D192ED03);
        let mut g = Gen { rng: Pcg64::seed(seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn with_seed<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen { rng: Pcg64::seed(seed), seed };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add commutes", 50, |g| {
            let a = g.i64_in(-1000, 1000);
            let b = g.i64_in(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 5, |g| {
            let x = g.usize_in(0, 10);
            assert!(x > 100, "x={x}");
        });
    }

    #[test]
    fn gen_ranges() {
        check("ranges respected", 100, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        });
    }
}
