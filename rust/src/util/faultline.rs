//! Deterministic fault injection for serialized containers.
//!
//! A `.mrc` plus shared randomness *is* the model, so a single flipped bit
//! that goes unnoticed replays the wrong candidate and decodes a
//! plausible-but-wrong network. This module produces the adversarial inputs
//! that prove the codec's integrity layer holds: seed-driven truncations,
//! single-bit flips and byte mutations of an in-memory byte buffer. The same
//! plans drive `rust/tests/corruption.rs` and the hidden
//! `miracle fuzz-decode` subcommand, so a CI failure is reproducible from
//! `(seed, iter)` alone.
//!
//! Faults are never identity transforms: every [`Fault`] produced by
//! [`sample`] yields bytes that differ from the input.

use crate::prng::Pcg64;

/// One mutation of a byte buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Keep only the first `len` bytes (`len` strictly less than the input).
    Truncate { len: usize },
    /// Flip the bit at absolute bit offset `bit` (MSB-first within a byte,
    /// matching the container's bit order).
    FlipBit { bit: usize },
    /// XOR the byte at `offset` with `xor` (`xor != 0`).
    MutateByte { offset: usize, xor: u8 },
    /// Keep the first `len` bytes and overwrite the tail with `fill`,
    /// preserving total length — a crash mid-`write_all` onto a
    /// pre-allocated file, where only the prefix reached the disk.
    /// Produced by [`crash_plan`], not [`sample`].
    TornWrite { len: usize, fill: u8 },
}

impl Fault {
    /// Apply to `bytes`, returning the mutated copy.
    pub fn apply(&self, bytes: &[u8]) -> Vec<u8> {
        match *self {
            Fault::Truncate { len } => bytes[..len.min(bytes.len())].to_vec(),
            Fault::FlipBit { bit } => {
                let mut out = bytes.to_vec();
                if bit / 8 < out.len() {
                    out[bit / 8] ^= 0x80 >> (bit % 8);
                }
                out
            }
            Fault::MutateByte { offset, xor } => {
                let mut out = bytes.to_vec();
                if offset < out.len() {
                    out[offset] ^= xor;
                }
                out
            }
            Fault::TornWrite { len, fill } => {
                let mut out = bytes.to_vec();
                for b in out.iter_mut().skip(len) {
                    *b = fill;
                }
                out
            }
        }
    }

    /// Short reproducible description for diagnostics.
    pub fn describe(&self) -> String {
        match *self {
            Fault::Truncate { len } => format!("truncate to {len} bytes"),
            Fault::FlipBit { bit } => {
                format!("flip bit {bit} (byte {}, bit {})", bit / 8, bit % 8)
            }
            Fault::MutateByte { offset, xor } => {
                format!("xor byte {offset} with {xor:#04x}")
            }
            Fault::TornWrite { len, fill } => {
                format!("torn write: keep {len} bytes, fill tail with {fill:#04x}")
            }
        }
    }
}

/// The `iter`-th fault of the `(seed)` plan against a `len`-byte buffer.
/// Deterministic: the same `(seed, iter, len)` always yields the same fault,
/// and the fault is never an identity transform. Panics if `len == 0`
/// (there is nothing to corrupt).
pub fn sample(seed: u64, iter: u64, len: usize) -> Fault {
    assert!(len > 0, "cannot corrupt an empty buffer");
    let mut rng = Pcg64::seed(seed).fold_in(iter);
    match rng.below(3) {
        0 => Fault::Truncate { len: rng.below(len as u64) as usize },
        1 => Fault::FlipBit { bit: rng.below(len as u64 * 8) as usize },
        _ => Fault::MutateByte {
            offset: rng.below(len as u64) as usize,
            xor: 1 + rng.below(255) as u8,
        },
    }
}

/// The full `iters`-long plan for a buffer of `len` bytes.
pub fn plan(seed: u64, iters: usize, len: usize) -> Vec<Fault> {
    (0..iters as u64).map(|i| sample(seed, i, len)).collect()
}

/// Exhaustive mid-write crash plan: for every cut point a writer could die
/// at, both on-disk outcomes the kernel can leave behind — a short file
/// ([`Fault::Truncate`]) and a full-length file whose tail never made it
/// out ([`Fault::TornWrite`] with a seed-chosen fill; 0x00 is the common
/// case but not the only one). `2 * len` faults total. Drives the MCK2
/// checkpoint corruption suite. [`sample`]'s byte-stable stream is
/// deliberately untouched, so existing CI seeds keep reproducing.
pub fn crash_plan(seed: u64, len: usize) -> Vec<Fault> {
    let mut rng = Pcg64::seed(seed).fold_in(0xC4A5);
    let mut out = Vec::with_capacity(len * 2);
    for cut in 0..len {
        out.push(Fault::Truncate { len: cut });
        let fill = if rng.below(2) == 0 {
            0x00
        } else {
            rng.below(256) as u8
        };
        out.push(Fault::TornWrite { len: cut, fill });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic() {
        let a = plan(42, 50, 128);
        let b = plan(42, 50, 128);
        assert_eq!(a, b);
        let c = plan(43, 50, 128);
        assert_ne!(a, c, "different seeds must give different plans");
    }

    #[test]
    fn faults_are_never_identity() {
        let bytes: Vec<u8> = (0..97u8).collect();
        for f in plan(7, 300, bytes.len()) {
            let m = f.apply(&bytes);
            assert_ne!(m, bytes, "identity fault: {}", f.describe());
        }
    }

    #[test]
    fn truncate_shortens_flip_preserves_length() {
        let bytes = vec![0xAAu8; 16];
        let t = Fault::Truncate { len: 5 }.apply(&bytes);
        assert_eq!(t.len(), 5);
        let f = Fault::FlipBit { bit: 0 }.apply(&bytes);
        assert_eq!(f.len(), 16);
        assert_eq!(f[0], 0x2A, "bit 0 is the MSB of byte 0");
        let m = Fault::MutateByte { offset: 3, xor: 0xFF }.apply(&bytes);
        assert_eq!(m[3], 0x55);
    }

    #[test]
    fn torn_write_preserves_length_and_fills_the_tail() {
        let bytes: Vec<u8> = (1..=8u8).collect();
        let t = Fault::TornWrite { len: 3, fill: 0xEE }.apply(&bytes);
        assert_eq!(t, vec![1, 2, 3, 0xEE, 0xEE, 0xEE, 0xEE, 0xEE]);
        // out-of-range cut is a no-op, not a panic
        let n = Fault::TornWrite { len: 99, fill: 0 }.apply(&bytes);
        assert_eq!(n, bytes);
    }

    #[test]
    fn crash_plan_covers_every_cut_point_both_ways() {
        let p = crash_plan(20260807, 16);
        assert_eq!(p.len(), 32);
        assert_eq!(p, crash_plan(20260807, 16), "plan must be deterministic");
        for cut in 0..16usize {
            assert_eq!(p[2 * cut], Fault::Truncate { len: cut });
            match p[2 * cut + 1] {
                Fault::TornWrite { len, .. } => assert_eq!(len, cut),
                ref f => panic!("expected TornWrite, got {}", f.describe()),
            }
        }
    }

    #[test]
    fn out_of_range_faults_are_noops_not_panics() {
        let bytes = vec![1u8, 2, 3];
        assert_eq!(Fault::FlipBit { bit: 999 }.apply(&bytes), bytes);
        assert_eq!(
            Fault::MutateByte { offset: 99, xor: 1 }.apply(&bytes),
            bytes
        );
        assert_eq!(Fault::Truncate { len: 99 }.apply(&bytes), bytes);
    }
}
