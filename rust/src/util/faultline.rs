//! Deterministic fault injection for serialized containers.
//!
//! A `.mrc` plus shared randomness *is* the model, so a single flipped bit
//! that goes unnoticed replays the wrong candidate and decodes a
//! plausible-but-wrong network. This module produces the adversarial inputs
//! that prove the codec's integrity layer holds: seed-driven truncations,
//! single-bit flips and byte mutations of an in-memory byte buffer. The same
//! plans drive `rust/tests/corruption.rs` and the hidden
//! `miracle fuzz-decode` subcommand, so a CI failure is reproducible from
//! `(seed, iter)` alone.
//!
//! Faults are never identity transforms: every [`Fault`] produced by
//! [`sample`] yields bytes that differ from the input.

use std::time::Duration;

use crate::prng::Pcg64;

/// One mutation of a byte buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Keep only the first `len` bytes (`len` strictly less than the input).
    Truncate { len: usize },
    /// Flip the bit at absolute bit offset `bit` (MSB-first within a byte,
    /// matching the container's bit order).
    FlipBit { bit: usize },
    /// XOR the byte at `offset` with `xor` (`xor != 0`).
    MutateByte { offset: usize, xor: u8 },
    /// Keep the first `len` bytes and overwrite the tail with `fill`,
    /// preserving total length — a crash mid-`write_all` onto a
    /// pre-allocated file, where only the prefix reached the disk.
    /// Produced by [`crash_plan`], not [`sample`].
    TornWrite { len: usize, fill: u8 },
}

impl Fault {
    /// Apply to `bytes`, returning the mutated copy.
    pub fn apply(&self, bytes: &[u8]) -> Vec<u8> {
        match *self {
            Fault::Truncate { len } => bytes[..len.min(bytes.len())].to_vec(),
            Fault::FlipBit { bit } => {
                let mut out = bytes.to_vec();
                if bit / 8 < out.len() {
                    out[bit / 8] ^= 0x80 >> (bit % 8);
                }
                out
            }
            Fault::MutateByte { offset, xor } => {
                let mut out = bytes.to_vec();
                if offset < out.len() {
                    out[offset] ^= xor;
                }
                out
            }
            Fault::TornWrite { len, fill } => {
                let mut out = bytes.to_vec();
                for b in out.iter_mut().skip(len) {
                    *b = fill;
                }
                out
            }
        }
    }

    /// Short reproducible description for diagnostics.
    pub fn describe(&self) -> String {
        match *self {
            Fault::Truncate { len } => format!("truncate to {len} bytes"),
            Fault::FlipBit { bit } => {
                format!("flip bit {bit} (byte {}, bit {})", bit / 8, bit % 8)
            }
            Fault::MutateByte { offset, xor } => {
                format!("xor byte {offset} with {xor:#04x}")
            }
            Fault::TornWrite { len, fill } => {
                format!("torn write: keep {len} bytes, fill tail with {fill:#04x}")
            }
        }
    }
}

/// The `iter`-th fault of the `(seed)` plan against a `len`-byte buffer.
/// Deterministic: the same `(seed, iter, len)` always yields the same fault,
/// and the fault is never an identity transform. Panics if `len == 0`
/// (there is nothing to corrupt).
pub fn sample(seed: u64, iter: u64, len: usize) -> Fault {
    assert!(len > 0, "cannot corrupt an empty buffer");
    let mut rng = Pcg64::seed(seed).fold_in(iter);
    match rng.below(3) {
        0 => Fault::Truncate { len: rng.below(len as u64) as usize },
        1 => Fault::FlipBit { bit: rng.below(len as u64 * 8) as usize },
        _ => Fault::MutateByte {
            offset: rng.below(len as u64) as usize,
            xor: 1 + rng.below(255) as u8,
        },
    }
}

/// The full `iters`-long plan for a buffer of `len` bytes.
pub fn plan(seed: u64, iters: usize, len: usize) -> Vec<Fault> {
    (0..iters as u64).map(|i| sample(seed, i, len)).collect()
}

/// Exhaustive mid-write crash plan: for every cut point a writer could die
/// at, both on-disk outcomes the kernel can leave behind — a short file
/// ([`Fault::Truncate`]) and a full-length file whose tail never made it
/// out ([`Fault::TornWrite`] with a seed-chosen fill; 0x00 is the common
/// case but not the only one). `2 * len` faults total. Drives the MCK2
/// checkpoint corruption suite. [`sample`]'s byte-stable stream is
/// deliberately untouched, so existing CI seeds keep reproducing.
pub fn crash_plan(seed: u64, len: usize) -> Vec<Fault> {
    let mut rng = Pcg64::seed(seed).fold_in(0xC4A5);
    let mut out = Vec::with_capacity(len * 2);
    for cut in 0..len {
        out.push(Fault::Truncate { len: cut });
        let fill = if rng.below(2) == 0 {
            0x00
        } else {
            rng.below(256) as u8
        };
        out.push(Fault::TornWrite { len: cut, fill });
    }
    out
}

/// Time-based fault schedule for the serve loop, keyed by **batch tick**
/// (the index of the executed batch), not wall time — so a given
/// `(schedule, tick)` pair always produces the same fault regardless of
/// machine speed, and `miracle chaos-serve --seed N` reproduces exactly.
///
/// Three independent seed-derived streams (distinct salts, so adding one
/// knob never shifts another's decisions):
/// - *intermittent exec failures*: each tick fails with probability
///   `exec_fail_p`;
/// - *hard outage*: every exec in the half-open tick window
///   `[outage.0, outage.1)` fails — this is what drives the circuit breaker
///   to trip, and its end is what lets HalfOpen probes recover;
/// - *latency spikes*: each tick stalls the executor by `spike` with
///   probability `spike_p` (drives deadline sheds under load).
///
/// [`sample`]'s byte-stable stream is deliberately untouched.
#[derive(Debug, Clone, Default)]
pub struct ChaosSchedule {
    pub seed: u64,
    /// Per-tick probability in `[0, 1]` of an injected exec failure.
    pub exec_fail_p: f64,
    /// Half-open tick window `[start, end)` of guaranteed exec failures.
    pub outage: Option<(u64, u64)>,
    /// Per-tick probability in `[0, 1]` of a latency spike.
    pub spike_p: f64,
    /// Stall applied when a spike fires.
    pub spike: Duration,
}

impl ChaosSchedule {
    fn coin(&self, salt: u64, tick: u64, sub: u64, p: f64) -> bool {
        p > 0.0
            && Pcg64::seed(self.seed)
                .fold_in(salt)
                .fold_in(tick)
                .fold_in(sub)
                .next_f64()
                < p
    }

    /// Does the exec call at `tick`, retry `attempt`, fail? Inside the
    /// outage window every attempt fails (defeating retries — this is what
    /// trips the breaker); intermittent failures are an independent coin per
    /// `(tick, attempt)` so a retry genuinely re-rolls, the way a transient
    /// backend hiccup would.
    pub fn exec_fails(&self, tick: u64, attempt: u32) -> bool {
        if let Some((start, end)) = self.outage {
            if tick >= start && tick < end {
                return true;
            }
        }
        self.coin(0xE4EC, tick, attempt as u64, self.exec_fail_p)
    }

    /// Latency spike to apply before executing `tick`, if any.
    pub fn latency(&self, tick: u64) -> Option<Duration> {
        self.coin(0x57A1, tick, 0, self.spike_p).then_some(self.spike)
    }

    /// Does the schedule inject anything at all? Lets the serve loop skip
    /// chaos bookkeeping entirely when unconfigured.
    pub fn is_active(&self) -> bool {
        self.exec_fail_p > 0.0 || self.outage.is_some() || self.spike_p > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic() {
        let a = plan(42, 50, 128);
        let b = plan(42, 50, 128);
        assert_eq!(a, b);
        let c = plan(43, 50, 128);
        assert_ne!(a, c, "different seeds must give different plans");
    }

    #[test]
    fn faults_are_never_identity() {
        let bytes: Vec<u8> = (0..97u8).collect();
        for f in plan(7, 300, bytes.len()) {
            let m = f.apply(&bytes);
            assert_ne!(m, bytes, "identity fault: {}", f.describe());
        }
    }

    #[test]
    fn truncate_shortens_flip_preserves_length() {
        let bytes = vec![0xAAu8; 16];
        let t = Fault::Truncate { len: 5 }.apply(&bytes);
        assert_eq!(t.len(), 5);
        let f = Fault::FlipBit { bit: 0 }.apply(&bytes);
        assert_eq!(f.len(), 16);
        assert_eq!(f[0], 0x2A, "bit 0 is the MSB of byte 0");
        let m = Fault::MutateByte { offset: 3, xor: 0xFF }.apply(&bytes);
        assert_eq!(m[3], 0x55);
    }

    #[test]
    fn torn_write_preserves_length_and_fills_the_tail() {
        let bytes: Vec<u8> = (1..=8u8).collect();
        let t = Fault::TornWrite { len: 3, fill: 0xEE }.apply(&bytes);
        assert_eq!(t, vec![1, 2, 3, 0xEE, 0xEE, 0xEE, 0xEE, 0xEE]);
        // out-of-range cut is a no-op, not a panic
        let n = Fault::TornWrite { len: 99, fill: 0 }.apply(&bytes);
        assert_eq!(n, bytes);
    }

    #[test]
    fn crash_plan_covers_every_cut_point_both_ways() {
        let p = crash_plan(20260807, 16);
        assert_eq!(p.len(), 32);
        assert_eq!(p, crash_plan(20260807, 16), "plan must be deterministic");
        for cut in 0..16usize {
            assert_eq!(p[2 * cut], Fault::Truncate { len: cut });
            match p[2 * cut + 1] {
                Fault::TornWrite { len, .. } => assert_eq!(len, cut),
                ref f => panic!("expected TornWrite, got {}", f.describe()),
            }
        }
    }

    #[test]
    fn chaos_schedule_is_deterministic_per_seed_and_tick() {
        let s = ChaosSchedule {
            seed: 7,
            exec_fail_p: 0.3,
            outage: None,
            spike_p: 0.2,
            spike: Duration::from_millis(5),
        };
        for tick in 0..200 {
            assert_eq!(s.exec_fails(tick, 0), s.exec_fails(tick, 0));
            assert_eq!(s.latency(tick), s.latency(tick));
        }
        let fails: Vec<u64> = (0..200).filter(|&t| s.exec_fails(t, 0)).collect();
        assert!(!fails.is_empty(), "p=0.3 over 200 ticks must fire");
        assert!(fails.len() < 150, "p=0.3 must not fire nearly always");
        let other = ChaosSchedule { seed: 8, ..s.clone() };
        let fails2: Vec<u64> =
            (0..200).filter(|&t| other.exec_fails(t, 0)).collect();
        assert_ne!(fails, fails2, "different seeds differ");
        // a retry re-rolls: attempt is part of the key
        let per_attempt: Vec<bool> = (0..4).map(|a| s.exec_fails(0, a)).collect();
        let again: Vec<bool> = (0..4).map(|a| s.exec_fails(0, a)).collect();
        assert_eq!(per_attempt, again);
    }

    #[test]
    fn outage_window_is_total_and_half_open() {
        let s = ChaosSchedule {
            seed: 1,
            outage: Some((10, 20)),
            ..ChaosSchedule::default()
        };
        for t in 10..20 {
            for a in 0..3 {
                assert!(s.exec_fails(t, a), "tick {t} attempt {a} in outage");
            }
        }
        assert!(!s.exec_fails(9, 0));
        assert!(!s.exec_fails(20, 0), "end is exclusive");
    }

    #[test]
    fn fail_and_spike_streams_are_independent() {
        let s = ChaosSchedule {
            seed: 3,
            exec_fail_p: 0.5,
            spike_p: 0.5,
            spike: Duration::from_millis(1),
            ..ChaosSchedule::default()
        };
        let fails: Vec<bool> = (0..256).map(|t| s.exec_fails(t, 0)).collect();
        let spikes: Vec<bool> = (0..256).map(|t| s.latency(t).is_some()).collect();
        assert_ne!(fails, spikes, "distinct salts => distinct streams");
    }

    #[test]
    fn default_schedule_is_inert() {
        let s = ChaosSchedule::default();
        assert!(!s.is_active());
        for t in 0..64 {
            assert!(!s.exec_fails(t, 0));
            assert!(s.latency(t).is_none());
        }
    }

    #[test]
    fn out_of_range_faults_are_noops_not_panics() {
        let bytes = vec![1u8, 2, 3];
        assert_eq!(Fault::FlipBit { bit: 999 }.apply(&bytes), bytes);
        assert_eq!(
            Fault::MutateByte { offset: 99, xor: 1 }.apply(&bytes),
            bytes
        );
        assert_eq!(Fault::Truncate { len: 99 }.apply(&bytes), bytes);
    }
}
