//! Deterministic fault injection for serialized containers.
//!
//! A `.mrc` plus shared randomness *is* the model, so a single flipped bit
//! that goes unnoticed replays the wrong candidate and decodes a
//! plausible-but-wrong network. This module produces the adversarial inputs
//! that prove the codec's integrity layer holds: seed-driven truncations,
//! single-bit flips and byte mutations of an in-memory byte buffer. The same
//! plans drive `rust/tests/corruption.rs` and the hidden
//! `miracle fuzz-decode` subcommand, so a CI failure is reproducible from
//! `(seed, iter)` alone.
//!
//! Faults are never identity transforms: every [`Fault`] produced by
//! [`sample`] yields bytes that differ from the input.

use crate::prng::Pcg64;

/// One mutation of a byte buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Keep only the first `len` bytes (`len` strictly less than the input).
    Truncate { len: usize },
    /// Flip the bit at absolute bit offset `bit` (MSB-first within a byte,
    /// matching the container's bit order).
    FlipBit { bit: usize },
    /// XOR the byte at `offset` with `xor` (`xor != 0`).
    MutateByte { offset: usize, xor: u8 },
}

impl Fault {
    /// Apply to `bytes`, returning the mutated copy.
    pub fn apply(&self, bytes: &[u8]) -> Vec<u8> {
        match *self {
            Fault::Truncate { len } => bytes[..len.min(bytes.len())].to_vec(),
            Fault::FlipBit { bit } => {
                let mut out = bytes.to_vec();
                if bit / 8 < out.len() {
                    out[bit / 8] ^= 0x80 >> (bit % 8);
                }
                out
            }
            Fault::MutateByte { offset, xor } => {
                let mut out = bytes.to_vec();
                if offset < out.len() {
                    out[offset] ^= xor;
                }
                out
            }
        }
    }

    /// Short reproducible description for diagnostics.
    pub fn describe(&self) -> String {
        match *self {
            Fault::Truncate { len } => format!("truncate to {len} bytes"),
            Fault::FlipBit { bit } => {
                format!("flip bit {bit} (byte {}, bit {})", bit / 8, bit % 8)
            }
            Fault::MutateByte { offset, xor } => {
                format!("xor byte {offset} with {xor:#04x}")
            }
        }
    }
}

/// The `iter`-th fault of the `(seed)` plan against a `len`-byte buffer.
/// Deterministic: the same `(seed, iter, len)` always yields the same fault,
/// and the fault is never an identity transform. Panics if `len == 0`
/// (there is nothing to corrupt).
pub fn sample(seed: u64, iter: u64, len: usize) -> Fault {
    assert!(len > 0, "cannot corrupt an empty buffer");
    let mut rng = Pcg64::seed(seed).fold_in(iter);
    match rng.below(3) {
        0 => Fault::Truncate { len: rng.below(len as u64) as usize },
        1 => Fault::FlipBit { bit: rng.below(len as u64 * 8) as usize },
        _ => Fault::MutateByte {
            offset: rng.below(len as u64) as usize,
            xor: 1 + rng.below(255) as u8,
        },
    }
}

/// The full `iters`-long plan for a buffer of `len` bytes.
pub fn plan(seed: u64, iters: usize, len: usize) -> Vec<Fault> {
    (0..iters as u64).map(|i| sample(seed, i, len)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic() {
        let a = plan(42, 50, 128);
        let b = plan(42, 50, 128);
        assert_eq!(a, b);
        let c = plan(43, 50, 128);
        assert_ne!(a, c, "different seeds must give different plans");
    }

    #[test]
    fn faults_are_never_identity() {
        let bytes: Vec<u8> = (0..97u8).collect();
        for f in plan(7, 300, bytes.len()) {
            let m = f.apply(&bytes);
            assert_ne!(m, bytes, "identity fault: {}", f.describe());
        }
    }

    #[test]
    fn truncate_shortens_flip_preserves_length() {
        let bytes = vec![0xAAu8; 16];
        let t = Fault::Truncate { len: 5 }.apply(&bytes);
        assert_eq!(t.len(), 5);
        let f = Fault::FlipBit { bit: 0 }.apply(&bytes);
        assert_eq!(f.len(), 16);
        assert_eq!(f[0], 0x2A, "bit 0 is the MSB of byte 0");
        let m = Fault::MutateByte { offset: 3, xor: 0xFF }.apply(&bytes);
        assert_eq!(m[3], 0x55);
    }

    #[test]
    fn out_of_range_faults_are_noops_not_panics() {
        let bytes = vec![1u8, 2, 3];
        assert_eq!(Fault::FlipBit { bit: 999 }.apply(&bytes), bytes);
        assert_eq!(
            Fault::MutateByte { offset: 99, xor: 1 }.apply(&bytes),
            bytes
        );
        assert_eq!(Fault::Truncate { len: 99 }.apply(&bytes), bytes);
    }
}
