//! CRC-32 (IEEE 802.3, the zlib/`crc32` polynomial) — the integrity check
//! behind the `.mrc` v2 container ([`crate::codec`]).
//!
//! Reflected algorithm, polynomial `0xEDB88320`, initial value `!0`, final
//! xor `!0` — byte-for-byte compatible with `zlib.crc32`, so fixtures and
//! external tooling can produce/verify checksums without this crate.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Continue a running CRC over `data`. `state` is the *internal* (already
/// inverted) register: start from [`crc32`] for one-shot use, or thread
/// `update(update(!0, a), b)` and finish with `!state` for streaming.
pub fn update(mut state: u32, data: &[u8]) -> u32 {
    for &b in data {
        state = (state >> 8) ^ TABLE[((state ^ b as u32) & 0xff) as usize];
    }
    state
}

/// One-shot CRC-32 of `data` (equals `zlib.crc32(data)`).
pub fn crc32(data: &[u8]) -> u32 {
    !update(!0u32, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical check value for CRC-32/ISO-HDLC
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data = b"minimal random code learning";
        let (a, b) = data.split_at(7);
        assert_eq!(!update(update(!0, a), b), crc32(data));
    }

    #[test]
    fn any_single_bit_flip_changes_the_crc() {
        let data: Vec<u8> = (0..64u8).collect();
        let base = crc32(&data);
        for bit in 0..data.len() * 8 {
            let mut m = data.clone();
            m[bit / 8] ^= 1 << (bit % 8);
            assert_ne!(crc32(&m), base, "flip at bit {bit} undetected");
        }
    }
}
