//! Canonical Huffman coding over u32 symbols.
//!
//! Substrate for the Deep-Compression baseline (Han et al., 2016): cluster
//! indices and sparse run lengths are Huffman coded. Builds code lengths with
//! the standard two-queue method over a sorted histogram, converts to
//! canonical form so only the length table needs to be stored.

use std::collections::BTreeMap;

use super::{BitReader, BitWriter};
use crate::util::{Error, Result};

/// Huffman code book: symbol -> (code bits, length).
#[derive(Debug, Clone)]
pub struct Huffman {
    /// canonical code per symbol, ordered map for determinism
    codes: BTreeMap<u32, (u64, u32)>,
    /// decode table: (length, first code value at that length, symbols)
    decode: Vec<(u32, u64, Vec<u32>)>,
}

impl Huffman {
    /// Build from symbol frequencies (zero-frequency symbols are excluded).
    pub fn from_freqs(freqs: &BTreeMap<u32, u64>) -> Result<Huffman> {
        let mut items: Vec<(u32, u64)> = freqs
            .iter()
            .filter(|(_, &f)| f > 0)
            .map(|(&s, &f)| (s, f))
            .collect();
        if items.is_empty() {
            return Err(Error::msg("huffman: empty alphabet"));
        }
        if items.len() == 1 {
            // degenerate: one symbol, one bit
            let mut codes = BTreeMap::new();
            codes.insert(items[0].0, (0u64, 1u32));
            return Ok(Huffman {
                decode: vec![(1, 0, vec![items[0].0])],
                codes,
            });
        }
        // two-queue method over sorted leaves
        items.sort_by_key(|&(s, f)| (f, s));
        #[derive(Debug)]
        enum Node {
            Leaf(u32),
            Internal(usize, usize),
        }
        let mut nodes: Vec<(u64, Node)> = Vec::with_capacity(items.len() * 2);
        let mut leaves: std::collections::VecDeque<usize> = Default::default();
        for &(s, f) in &items {
            nodes.push((f, Node::Leaf(s)));
            leaves.push_back(nodes.len() - 1);
        }
        let mut internal: std::collections::VecDeque<usize> = Default::default();
        let pop_min = |nodes: &Vec<(u64, Node)>,
                       a: &mut std::collections::VecDeque<usize>,
                       b: &mut std::collections::VecDeque<usize>|
         -> usize {
            match (a.front(), b.front()) {
                (Some(&x), Some(&y)) => {
                    if nodes[x].0 <= nodes[y].0 {
                        a.pop_front().unwrap()
                    } else {
                        b.pop_front().unwrap()
                    }
                }
                (Some(_), None) => a.pop_front().unwrap(),
                (None, Some(_)) => b.pop_front().unwrap(),
                (None, None) => unreachable!(),
            }
        };
        while leaves.len() + internal.len() > 1 {
            let x = pop_min(&nodes, &mut leaves, &mut internal);
            let y = pop_min(&nodes, &mut leaves, &mut internal);
            nodes.push((nodes[x].0 + nodes[y].0, Node::Internal(x, y)));
            internal.push_back(nodes.len() - 1);
        }
        // depth-first to get code lengths
        let root = internal.pop_front().unwrap();
        let mut lengths: BTreeMap<u32, u32> = BTreeMap::new();
        let mut stack = vec![(root, 0u32)];
        while let Some((idx, depth)) = stack.pop() {
            match &nodes[idx].1 {
                Node::Leaf(s) => {
                    lengths.insert(*s, depth.max(1));
                }
                Node::Internal(a, b) => {
                    stack.push((*a, depth + 1));
                    stack.push((*b, depth + 1));
                }
            }
        }
        Ok(Huffman::from_lengths(&lengths))
    }

    /// Canonical codes from a length table.
    pub fn from_lengths(lengths: &BTreeMap<u32, u32>) -> Huffman {
        // sort by (length, symbol)
        let mut syms: Vec<(u32, u32)> =
            lengths.iter().map(|(&s, &l)| (l, s)).collect();
        syms.sort();
        let mut codes = BTreeMap::new();
        let mut decode: Vec<(u32, u64, Vec<u32>)> = Vec::new();
        let mut code = 0u64;
        let mut prev_len = 0u32;
        for &(len, sym) in &syms {
            code <<= len - prev_len;
            prev_len = len;
            codes.insert(sym, (code, len));
            match decode.last_mut() {
                Some((l, _, group)) if *l == len => group.push(sym),
                _ => decode.push((len, code, vec![sym])),
            }
            code += 1;
        }
        Huffman { codes, decode }
    }

    pub fn lengths(&self) -> BTreeMap<u32, u32> {
        self.codes.iter().map(|(&s, &(_, l))| (s, l)).collect()
    }

    pub fn encode_symbol(&self, w: &mut BitWriter, sym: u32) -> Result<()> {
        let &(code, len) = self
            .codes
            .get(&sym)
            .ok_or_else(|| Error::msg(format!("huffman: unknown symbol {sym}")))?;
        w.write_bits(code, len);
        Ok(())
    }

    pub fn decode_symbol(&self, r: &mut BitReader) -> Result<u32> {
        let mut code = 0u64;
        let mut len = 0u32;
        for &(l, first, ref group) in &self.decode {
            code = (code << (l - len)) | r.read_bits(l - len)?;
            len = l;
            if code >= first && ((code - first) as usize) < group.len() {
                return Ok(group[(code - first) as usize]);
            }
        }
        Err(Error::msg("huffman: invalid code"))
    }

    /// Encoded size in bits for a symbol stream, given this book.
    pub fn encoded_bits(&self, syms: &[u32]) -> Result<usize> {
        let mut total = 0usize;
        for &s in syms {
            let &(_, l) = self
                .codes
                .get(&s)
                .ok_or_else(|| Error::msg(format!("huffman: unknown symbol {s}")))?;
            total += l as usize;
        }
        Ok(total)
    }

    /// Bits to store the code book itself (canonical: one length per symbol,
    /// symbol ids varint-coded). Used for honest size accounting.
    pub fn table_bits(&self) -> usize {
        let mut w = BitWriter::new();
        w.write_varint(self.codes.len() as u64);
        for (&s, &(_, l)) in &self.codes {
            w.write_varint(s as u64);
            w.write_varint(l as u64);
        }
        w.bit_len()
    }
}

/// Convenience: build + encode a full stream; returns (book, payload bits).
pub fn encode_stream(syms: &[u32]) -> Result<(Huffman, Vec<u8>, usize)> {
    let mut freqs = BTreeMap::new();
    for &s in syms {
        *freqs.entry(s).or_insert(0u64) += 1;
    }
    let book = Huffman::from_freqs(&freqs)?;
    let mut w = BitWriter::new();
    for &s in syms {
        book.encode_symbol(&mut w, s)?;
    }
    let bits = w.bit_len();
    Ok((book, w.finish(), bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop;

    fn round_trip(syms: &[u32]) {
        let (book, bytes, bits) = encode_stream(syms).unwrap();
        let mut r = BitReader::new(&bytes);
        for &s in syms {
            assert_eq!(book.decode_symbol(&mut r).unwrap(), s);
        }
        assert_eq!(r.bit_pos(), bits);
    }

    #[test]
    fn skewed_distribution() {
        let mut syms = vec![0u32; 1000];
        syms.extend(vec![1u32; 100]);
        syms.extend(vec![2u32; 10]);
        syms.push(3);
        round_trip(&syms);
        let (book, _, bits) = encode_stream(&syms).unwrap();
        // frequent symbol must get a short code
        assert_eq!(book.codes[&0].1, 1);
        // compression beats fixed 2-bit coding
        assert!(bits < syms.len() * 2);
    }

    #[test]
    fn single_symbol() {
        round_trip(&[7u32; 50]);
    }

    #[test]
    fn near_entropy() {
        // geometric-ish distribution; huffman within 1 bit/sym of entropy
        let mut syms = Vec::new();
        let freqs = [512usize, 256, 128, 64, 32, 16, 8, 4, 2, 1];
        for (s, &f) in freqs.iter().enumerate() {
            syms.extend(std::iter::repeat(s as u32).take(f));
        }
        let n: usize = syms.len();
        let entropy: f64 = freqs
            .iter()
            .map(|&f| {
                let p = f as f64 / n as f64;
                -p * p.log2()
            })
            .sum();
        let (_, _, bits) = encode_stream(&syms).unwrap();
        let rate = bits as f64 / n as f64;
        assert!(rate >= entropy - 1e-9, "rate {rate} entropy {entropy}");
        assert!(rate <= entropy + 1.0, "rate {rate} entropy {entropy}");
    }

    #[test]
    fn random_streams_round_trip() {
        quickprop::check("huffman round trip", 30, |g| {
            let n_sym = g.usize_in(1, 40);
            let len = g.usize_in(1, 400);
            let syms: Vec<u32> =
                (0..len).map(|_| g.usize_in(0, n_sym - 1) as u32).collect();
            round_trip(&syms);
        });
    }

    #[test]
    fn kraft_inequality_holds() {
        quickprop::check("kraft", 20, |g| {
            let n_sym = g.usize_in(2, 64);
            let mut freqs = BTreeMap::new();
            for s in 0..n_sym {
                freqs.insert(s as u32, g.usize_in(1, 1000) as u64);
            }
            let book = Huffman::from_freqs(&freqs).unwrap();
            let kraft: f64 = book
                .lengths()
                .values()
                .map(|&l| 2f64.powi(-(l as i32)))
                .sum();
            assert!(kraft <= 1.0 + 1e-9, "kraft {kraft}");
        });
    }
}
