//! Bit-level I/O and integer codes.
//!
//! The `.mrc` container writes each transmitted index `k*` with a fixed
//! `C_loc`-bit width (Algorithm 1's code), the theory bench uses the
//! Vitányi–Li prefix-free code for unbounded indices (Appendix A, Eq. 15),
//! and the Deep-Compression baseline uses the canonical Huffman coder in
//! [`huffman`].

pub mod huffman;

use crate::util::{Error, Result};

/// MSB-first bit writer with a 64-bit accumulator (bytes are flushed in
/// bulk — the hot path for index payloads and Huffman streams).
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// pending bits, left-aligned within the low `fill` positions
    acc: u64,
    /// number of valid bits in `acc` (0..=63)
    fill: u32,
}

impl BitWriter {
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write the low `width` bits of `v`, MSB first. `width <= 64`.
    pub fn write_bits(&mut self, v: u64, width: u32) {
        assert!(width <= 64);
        if width == 0 {
            return;
        }
        let v = if width == 64 { v } else { v & ((1u64 << width) - 1) };
        if self.fill + width <= 64 {
            self.acc = if width == 64 { v } else { (self.acc << width) | v };
            self.fill += width;
        } else {
            let hi = self.fill + width - 64; // bits that don't fit
            self.acc = (self.acc << (width - hi)) | (v >> hi);
            self.fill = 64;
            self.flush_full();
            self.acc = v & ((1u64 << hi) - 1);
            self.fill = hi;
        }
        while self.fill >= 8 {
            self.flush_byte();
        }
    }

    fn flush_byte(&mut self) {
        let b = (self.acc >> (self.fill - 8)) as u8;
        self.buf.push(b);
        self.fill -= 8;
        if self.fill < 64 {
            self.acc &= (1u64 << self.fill).wrapping_sub(1);
        }
    }

    fn flush_full(&mut self) {
        debug_assert_eq!(self.fill, 64);
        self.buf.extend_from_slice(&self.acc.to_be_bytes());
        self.fill = 0;
        self.acc = 0;
    }

    /// Unary: n zeros then a one.
    pub fn write_unary(&mut self, n: u64) {
        for _ in 0..n {
            self.write_bit(false);
        }
        self.write_bit(true);
    }

    /// Elias gamma code for n >= 1.
    pub fn write_elias_gamma(&mut self, n: u64) {
        assert!(n >= 1);
        let nbits = 64 - n.leading_zeros();
        self.write_unary((nbits - 1) as u64);
        if nbits > 1 {
            self.write_bits(n & ((1 << (nbits - 1)) - 1), nbits - 1);
        }
    }

    /// Vitányi–Li style prefix-free code for n >= 0:
    /// Elias-gamma(len+1) then the binary digits of n without the implied
    /// leading structure; length is log n + 2 log log n + O(1).
    pub fn write_vitanyi_li(&mut self, n: u64) {
        let m = n + 1; // shift to >= 1
        let nbits = 64 - m.leading_zeros();
        self.write_elias_gamma(nbits as u64);
        if nbits > 1 {
            self.write_bits(m & ((1 << (nbits - 1)) - 1), nbits - 1);
        }
    }

    /// LEB128-ish byte varint (for headers, byte-aligned use only).
    pub fn write_varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.write_bits(b as u64, 8);
                return;
            }
            self.write_bits((b | 0x80) as u64, 8);
        }
    }

    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.fill as usize
    }

    /// Pad to a byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.fill > 0 {
            let b = (self.acc << (8 - self.fill)) as u8;
            self.buf.push(b);
        }
        self.buf
    }
}

/// MSB-first bit reader.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader { buf, pos: 0 }
    }

    pub fn read_bit(&mut self) -> Result<bool> {
        let byte = self.pos / 8;
        if byte >= self.buf.len() {
            return Err(Error::msg("bitstream exhausted"));
        }
        let bit = (self.buf[byte] >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Ok(bit)
    }

    pub fn read_bits(&mut self, width: u32) -> Result<u64> {
        let mut v = 0u64;
        for _ in 0..width {
            v = (v << 1) | self.read_bit()? as u64;
        }
        Ok(v)
    }

    pub fn read_unary(&mut self) -> Result<u64> {
        let mut n = 0;
        while !self.read_bit()? {
            n += 1;
        }
        Ok(n)
    }

    pub fn read_elias_gamma(&mut self) -> Result<u64> {
        let extra = self.read_unary()?;
        let rest = self.read_bits(extra as u32)?;
        Ok((1 << extra) | rest)
    }

    pub fn read_vitanyi_li(&mut self) -> Result<u64> {
        let nbits = self.read_elias_gamma()?;
        if nbits == 0 || nbits > 64 {
            return Err(Error::msg(format!("bad VL length {nbits}")));
        }
        let rest = self.read_bits((nbits - 1) as u32)?;
        Ok(((1u64 << (nbits - 1)) | rest) - 1)
    }

    pub fn read_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0;
        loop {
            let b = self.read_bits(8)? as u8;
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift >= 64 {
                return Err(Error::msg("varint too long"));
            }
        }
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Bits left before exhaustion. Container parsers use this to bound
    /// header-declared lengths against the physical input size *before*
    /// allocating (a hostile varint must not drive `Vec::with_capacity`).
    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

/// Code length (bits) of the Vitányi–Li code for n — used to *account* for
/// message lengths without materializing them.
pub fn vitanyi_li_len(n: u64) -> usize {
    let m = n + 1;
    let nbits = 64 - m.leading_zeros();
    let g = nbits as u64;
    let gbits = 64 - g.leading_zeros();
    // gamma(g): (gbits-1) zeros + gbits digits; then nbits-1 payload digits
    (2 * gbits - 1) as usize + (nbits - 1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop;

    #[test]
    fn bits_round_trip() {
        let mut w = BitWriter::new();
        w.write_bits(0b1011, 4);
        w.write_bits(0xdeadbeef, 32);
        w.write_bits(1, 1);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4).unwrap(), 0b1011);
        assert_eq!(r.read_bits(32).unwrap(), 0xdeadbeef);
        assert_eq!(r.read_bits(1).unwrap(), 1);
    }

    #[test]
    fn gamma_round_trip_small() {
        let mut w = BitWriter::new();
        for n in 1..100u64 {
            w.write_elias_gamma(n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for n in 1..100u64 {
            assert_eq!(r.read_elias_gamma().unwrap(), n);
        }
    }

    #[test]
    fn vitanyi_li_round_trip_prop() {
        quickprop::check("VL round trip", 200, |g| {
            let ns: Vec<u64> = (0..20)
                .map(|_| {
                    let shift = g.usize_in(0, 50);
                    g.rng.next_u64() >> shift
                })
                .collect();
            let mut w = BitWriter::new();
            for &n in &ns {
                w.write_vitanyi_li(n);
            }
            let expected_bits = w.bit_len();
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &n in &ns {
                assert_eq!(r.read_vitanyi_li().unwrap(), n);
            }
            assert_eq!(
                expected_bits,
                ns.iter().map(|&n| vitanyi_li_len(n)).sum::<usize>()
            );
        });
    }

    #[test]
    fn vl_len_is_log_plus_loglog() {
        // |l(n)| = log n + 2 log log n + O(1)  (Vitányi & Li)
        for &n in &[10u64, 1000, 1 << 20, 1 << 40] {
            let len = vitanyi_li_len(n) as f64;
            let log = (n as f64).log2();
            let loglog = log.max(1.0).log2();
            assert!(
                len <= log + 2.0 * loglog + 4.0,
                "n={n} len={len} bound={}",
                log + 2.0 * loglog + 4.0
            );
        }
    }

    #[test]
    fn varint_round_trip() {
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.write_varint(v);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.read_varint().unwrap(), v);
        }
    }

    #[test]
    fn exhaustion_is_error() {
        let bytes = [0xffu8];
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bits(8).is_ok());
        assert!(r.read_bit().is_err());
    }

    #[test]
    fn remaining_bits_tracks_consumption() {
        let bytes = [0u8; 4];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.remaining_bits(), 32);
        r.read_bits(5).unwrap();
        assert_eq!(r.remaining_bits(), 27);
        r.read_bits(27).unwrap();
        assert_eq!(r.remaining_bits(), 0);
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bits(0, 3);
        assert_eq!(w.bit_len(), 3);
        w.write_bits(0, 13);
        assert_eq!(w.bit_len(), 16);
    }
}
