//! Synthetic image-classification datasets (MNIST/CIFAR substitutes).
//!
//! The sandbox has no network access, so the paper's MNIST/CIFAR-10
//! benchmarks are replaced by deterministic *class-conditional generators*
//! that preserve what the experiments actually exercise: a non-trivially
//! learnable mapping from images to 10 classes, an overfitting regime
//! (so the KL/size constraint visibly trades off against test error), and
//! disjoint train/test splits. See DESIGN.md §4 (Substitutions).
//!
//! `synth_mnist`: 28x28x1 "digits" — each class is a fixed stroke pattern
//! (bars/crosses/boxes at class-specific positions) warped by a per-sample
//! random shift and pixel noise.
//!
//! `synth_cifar`: HxWx3 "textures" — each class is a colored frequency
//! pattern (class-specific sinusoid orientation + palette) plus noise.

use crate::prng::Pcg64;
use crate::tensor::TensorF32;

/// An in-memory dataset of flattened images + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// [n, feature_dim] for MLPs or [n, h, w, c] semantics (row-major);
    /// stored flat with the per-example shape recorded.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub example_shape: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn feature_dim(&self) -> usize {
        self.example_shape.iter().product()
    }

    /// Copy examples `idx` into a [batch, ...] tensor pair.
    pub fn gather(&self, idx: &[usize]) -> (TensorF32, Vec<i32>) {
        let d = self.feature_dim();
        let mut x = Vec::with_capacity(idx.len() * d);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(&self.x[i * d..(i + 1) * d]);
            y.push(self.y[i]);
        }
        let mut shape = vec![idx.len()];
        shape.extend_from_slice(&self.example_shape);
        (TensorF32 { shape, data: x }, y)
    }

    /// Sequential batch (wrapping), for eval loops.
    pub fn batch_range(&self, start: usize, n: usize) -> (TensorF32, Vec<i32>) {
        let idx: Vec<usize> = (0..n).map(|i| (start + i) % self.len()).collect();
        self.gather(&idx)
    }
}

/// Deterministic batch iterator with per-epoch reshuffling.
pub struct BatchIter {
    order: Vec<usize>,
    pos: usize,
    batch: usize,
    rng: Pcg64,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, seed: u64) -> BatchIter {
        let mut rng = Pcg64::seed(seed);
        let order = rng.permutation(n).into_iter().map(|i| i as usize).collect();
        BatchIter { order, pos: 0, batch, rng }
    }

    /// Indices of the next batch (reshuffles at epoch end).
    pub fn next_indices(&mut self) -> Vec<usize> {
        let n = self.order.len();
        if self.pos + self.batch > n {
            let perm = self.rng.permutation(n);
            self.order = perm.into_iter().map(|i| i as usize).collect();
            self.pos = 0;
        }
        let idx = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        idx
    }
}

/// 28x28x1 stroke-pattern digits, flattened to [n, 784].
pub fn synth_mnist(n: usize, seed: u64) -> Dataset {
    let (h, w) = (28usize, 28usize);
    let classes = 10;
    let mut rng = Pcg64::seed(seed);
    let mut x = vec![0f32; n * h * w];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let c = (rng.below(classes as u64)) as usize;
        y[i] = c as i32;
        let img = &mut x[i * h * w..(i + 1) * h * w];
        let dx = rng.below(5) as isize - 2;
        let dy = rng.below(5) as isize - 2;
        draw_digit_pattern(img, h, w, c, dx, dy);
        // pixel noise + blur-ish jitter
        for p in img.iter_mut() {
            *p += rng.next_normal() as f32 * 0.15;
            *p = p.clamp(0.0, 1.0);
        }
    }
    Dataset { x, y, example_shape: vec![h * w], classes }
}

fn draw_digit_pattern(img: &mut [f32], h: usize, w: usize, c: usize, dx: isize, dy: isize) {
    // Each class is a fixed pseudo-random 7x7 cell pattern (4px cells, so
    // 28x28 exactly); coarse cells survive the ±2px jitter that defeats
    // thin strokes. Patterns are ~50% dense and pairwise far apart w.h.p.
    use crate::prng::mix64;
    const CELL: usize = 4;
    let cells = h / CELL; // 7 for 28x28
    for cr in 0..cells {
        for cc in 0..cells {
            let on = mix64(((c as u64) << 32) ^ (cr * cells + cc) as u64) & 1 == 1;
            if !on {
                continue;
            }
            for r in 0..CELL {
                for col in 0..CELL {
                    let rr = (cr * CELL + r) as isize + dy;
                    let ww = (cc * CELL + col) as isize + dx;
                    if rr >= 0 && ww >= 0 && (rr as usize) < h && (ww as usize) < w {
                        img[rr as usize * w + ww as usize] = 1.0;
                    }
                }
            }
        }
    }
}

/// Gaussian class-prototype vectors: x = proto[c] + noise. The cleanly
/// learnable small task used by the tiny test config (and unit benches).
pub fn synth_protos(n: usize, dim: usize, classes: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed(seed ^ 0x9876);
    // prototypes fixed by seed-of-task, not by sample seed, so train/test
    // splits share them: derive from a constant stream
    let mut proto_rng = Pcg64::seed(0xC1A5_5E5 ^ dim as u64 ^ (classes as u64) << 8);
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..dim).map(|_| proto_rng.next_normal() as f32).collect())
        .collect();
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes as u64) as usize;
        y.push(c as i32);
        for j in 0..dim {
            x.push(protos[c][j] + rng.next_normal() as f32 * 0.4);
        }
    }
    Dataset { x, y, example_shape: vec![dim], classes }
}

/// HxWx3 colored texture classes, flattened to [n, h, w, 3] (NHWC).
pub fn synth_cifar(n: usize, h: usize, w: usize, seed: u64) -> Dataset {
    let classes = 10;
    let mut rng = Pcg64::seed(seed);
    let mut x = vec![0f32; n * h * w * 3];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let c = rng.below(classes as u64) as usize;
        y[i] = c as i32;
        let img = &mut x[i * h * w * 3..(i + 1) * h * w * 3];
        let angle = c as f32 * std::f32::consts::PI / 10.0;
        let freq = 0.5 + (c % 3) as f32 * 0.4;
        let phase = rng.next_f32() * std::f32::consts::PI;
        let (sa, ca) = angle.sin_cos();
        let palette = [
            0.3 + 0.07 * c as f32,
            0.9 - 0.08 * c as f32,
            0.2 + 0.05 * ((c * 3) % 10) as f32,
        ];
        for r in 0..h {
            for col in 0..w {
                let t = (r as f32 * ca + col as f32 * sa) * freq + phase;
                let v = 0.5 + 0.5 * t.sin();
                for ch in 0..3 {
                    let noise = rng.next_normal() as f32 * 0.1;
                    img[(r * w + col) * 3 + ch] =
                        (v * palette[ch] + noise).clamp(0.0, 1.0);
                }
            }
        }
    }
    Dataset { x, y, example_shape: vec![h, w, 3], classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = synth_mnist(16, 5);
        let b = synth_mnist(16, 5);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = synth_mnist(16, 6);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn shapes_and_ranges() {
        let d = synth_mnist(8, 1);
        assert_eq!(d.x.len(), 8 * 784);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.y.iter().all(|&c| (0..10).contains(&c)));
        let d = synth_cifar(4, 16, 16, 1);
        assert_eq!(d.x.len(), 4 * 16 * 16 * 3);
        assert_eq!(d.example_shape, vec![16, 16, 3]);
    }

    #[test]
    fn all_classes_present() {
        let d = synth_mnist(500, 2);
        let mut seen = [false; 10];
        for &c in &d.y {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // nearest-template classification on clean patterns should beat
        // chance by a lot — sanity that the task is learnable
        let d = synth_mnist(300, 3);
        let mut templates = vec![vec![0f32; 784]; 10];
        for c in 0..10 {
            draw_digit_pattern(&mut templates[c], 28, 28, c, 0, 0);
        }
        let mut correct = 0;
        for i in 0..d.len() {
            let img = &d.x[i * 784..(i + 1) * 784];
            let mut best = (f32::INFINITY, 0usize);
            for (c, t) in templates.iter().enumerate() {
                let dist: f32 = img
                    .iter()
                    .zip(t)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as i32 == d.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.5, "template acc {acc}");
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut it = BatchIter::new(10, 3, 1);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..3 {
            for i in it.next_indices() {
                assert!(seen.insert(i), "duplicate before epoch end");
            }
        }
        assert_eq!(seen.len(), 9);
    }

    #[test]
    fn gather_layout() {
        let d = synth_cifar(6, 8, 8, 9);
        let (x, y) = d.gather(&[3, 1]);
        assert_eq!(x.shape, vec![2, 8, 8, 3]);
        assert_eq!(y.len(), 2);
        assert_eq!(&x.data[..8 * 8 * 3], &d.x[3 * 8 * 8 * 3..4 * 8 * 8 * 3]);
    }
}
