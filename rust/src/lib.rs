//! # MIRACLE — Minimal Random Code Learning
//!
//! Rust + JAX + Pallas reproduction of *"Minimal Random Code Learning:
//! Getting Bits Back from Compressed Model Parameters"* (Havasi, Peharz,
//! Hernández-Lobato — ICLR 2019).
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: Algorithm 2's block scheduler and
//!   β-annealing controller, the `.mrc` codec, baselines, benches and an
//!   inference server. Owns the event loop; python is never on the hot path.
//! * **L2 (python/compile/model.py)** — variational model graphs, AOT-lowered
//!   to HLO text artifacts loaded by [`runtime`].
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the importance
//!   scoring hot-spot, fused sampled-linear and block-KL.

pub mod baselines;
pub mod bitstream;
pub mod codec;
pub mod coordinator;
pub mod data;
pub mod grc;
pub mod metrics;
pub mod model;
pub mod prng;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;
