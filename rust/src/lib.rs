//! # MIRACLE — Minimal Random Code Learning
//!
//! Rust reproduction of *"Minimal Random Code Learning: Getting Bits Back
//! from Compressed Model Parameters"* (Havasi, Peharz, Hernández-Lobato —
//! ICLR 2019), with a pluggable execution backend.
//!
//! Architecture (full layering in `DESIGN.md`; the backend split is recorded
//! in `docs/adr/001-backend-abstraction.md`, the container format in
//! `docs/mrc-format.md`):
//! * **L3 (this crate)** — the coordinator: Algorithm 2's block scheduler and
//!   β-annealing controller, the `.mrc` codec ([`codec`]), baselines, benches
//!   and an inference server. Owns the event loop; python is never on the
//!   hot path.
//! * **L2 ([`runtime`])** — the [`runtime::Backend`] boundary. Default:
//!   [`runtime::native::NativeBackend`], pure-Rust kernels over [`tensor`]
//!   with built-in MLP configs ([`model::arch`]) — zero Python, zero XLA.
//!   Optional (`--features xla` + `MIRACLE_BACKEND=xla`): AOT HLO artifacts
//!   lowered from `python/compile/model.py`, executed via PJRT.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the importance
//!   scoring hot-spot, fused sampled-linear and block-KL on the PJRT path;
//!   the native backend's equivalents live in `runtime/native.rs`.

pub mod baselines;
pub mod bitstream;
pub mod codec;
pub mod coordinator;
pub mod data;
pub mod grc;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod prng;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;
