//! Experiment records: rows of named values emitted as aligned tables,
//! CSV files and JSON — the output layer for benches and EXPERIMENTS.md.

use std::io::Write;

use crate::util::json::Json;
use crate::util::Result;

/// One experiment table: ordered columns, appendable rows.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table (the bench output format).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i] + 2))
                .collect::<String>()
        };
        out.push_str(&fmt_row(&self.columns));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .columns
            .iter()
            .map(|c| esc(c))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "columns",
                Json::arr(self.columns.iter().map(|c| Json::str(c))),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::str(c)))),
                ),
            ),
        ])
    }

    pub fn save_csv(&self, path: &str) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// Format a byte size the way the paper reports them.
pub fn fmt_size(bytes: f64) -> String {
    if bytes >= 1e6 {
        format!("{:.2} MB", bytes / 1e6)
    } else if bytes >= 1e3 {
        format!("{:.2} kB", bytes / 1e3)
    } else {
        format!("{bytes:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let r = t.render();
        assert!(r.contains("== t =="));
        assert!(r.contains("bb"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn sizes() {
        assert_eq!(fmt_size(1720e3), "1.72 MB");
        assert_eq!(fmt_size(3030.0), "3.03 kB");
        assert_eq!(fmt_size(12.0), "12 B");
    }
}
