//! Shared helpers for the bench binaries (criterion substitute).
#![allow(dead_code)] // each bench binary uses a subset
//!
//! Scale control: `MIRACLE_BENCH_SCALE=full` runs paper-scale settings
//! (minutes per bench); the default `quick` scale keeps every bench under
//! ~1-2 minutes on one CPU core so `cargo bench` completes end to end.

use miracle::data::{self, Dataset};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

pub fn scale() -> Scale {
    match std::env::var("MIRACLE_BENCH_SCALE").as_deref() {
        Ok("full") => Scale::Full,
        _ => Scale::Quick,
    }
}

/// Datasets for a model config name.
pub fn datasets_for(model: &str, s: Scale) -> (Dataset, Dataset) {
    let (nt, ne) = match s {
        Scale::Quick => (2048, 1024),
        Scale::Full => (8192, 2048),
    };
    if model.starts_with("conv") {
        (
            data::synth_cifar(nt, 16, 16, 1234),
            data::synth_cifar(ne, 16, 16, 1234 ^ 0x7E57),
        )
    } else if model.starts_with("lenet") {
        (
            data::synth_mnist(nt, 1234),
            data::synth_mnist(ne, 1234 ^ 0x7E57),
        )
    } else {
        (
            data::synth_protos(512, 16, 4, 1234),
            data::synth_protos(512, 16, 4, 1234 ^ 0x7E57),
        )
    }
}

/// MIRACLE iteration budget per scale.
pub fn miracle_iters(s: Scale) -> (usize, usize) {
    match s {
        Scale::Quick => (2500, 1), // (i0, intermediate I)
        Scale::Full => (6000, 1),
    }
}

pub fn dense_steps(s: Scale) -> usize {
    match s {
        Scale::Quick => 1500,
        Scale::Full => 4000,
    }
}

pub fn banner(name: &str) {
    println!("\n############################################################");
    println!("# {name}   (scale: {:?})", scale());
    println!("############################################################");
}
