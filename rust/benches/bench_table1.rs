//! Regenerates **Table 1** of the paper: size / ratio / test-error rows for
//! Uncompressed, Deep Compression, Bayesian Compression and MIRACLE at two
//! operating points, on both benchmarks (synth-MNIST `lenet_synth`,
//! synth-CIFAR `conv_synth` — both MLPs on the native backend, see
//! `model::arch`).
//!
//! Expected *shape* (paper): MIRACLE rows Pareto-dominate — the low-error
//! point beats every baseline's error at smaller size, the high-compression
//! point reaches ratios no baseline attains at comparable error.
//!
//! `MIRACLE_BENCH_SCALE=full cargo bench --bench bench_table1` for the long
//! version; default quick scale finishes in a few minutes.

mod common;

use common::{banner, datasets_for, dense_steps, miracle_iters, scale};
use miracle::baselines::bayescomp::BayesCompCfg;
use miracle::baselines::deepcomp::DeepCompCfg;
use miracle::baselines::runner;
use miracle::coordinator::{self, MiracleCfg};
use miracle::metrics::{fmt_size, Table};
use miracle::runtime::{self, Runtime};
use miracle::util::Result;

fn bench_model(rt: &Runtime, model: &str, lr: f32) -> Result<Table> {
    let s = scale();
    let arts = runtime::load(rt, model)?;
    let dense_arts = runtime::load(rt, &format!("{model}_dense"))?;
    let (train, test) = datasets_for(model, s);
    let (i0, i_int) = miracle_iters(s);

    let n_bits_fp32 = dense_arts.meta.n_total * 32;
    let mut table = Table::new(
        &format!("Table 1 — {model}"),
        &["Compression", "Size", "Ratio", "Test error"],
    );

    let post = runner::train_dense(
        &dense_arts,
        &train,
        dense_steps(s),
        lr,
        train.len() as f32,
        7,
    )?;
    let suite = runner::baseline_suite(
        &dense_arts,
        &post,
        &test,
        &DeepCompCfg { sparsity: 0.9, clusters: 16, ..Default::default() },
        &BayesCompCfg::default(),
    )?;
    for p in &suite {
        table.row(vec![
            p.label.clone(),
            fmt_size(p.bits as f64 / 8.0),
            format!("{:.0}x", n_bits_fp32 as f64 / p.bits as f64),
            format!("{:.2} %", p.test_error * 100.0),
        ]);
    }

    for (tag, bits) in [
        ("MIRACLE (lowest error)", 12u8),
        ("MIRACLE (highest compression)", 3),
    ] {
        let cfg = MiracleCfg {
            c_loc_bits: bits,
            i0,
            i_intermediate: i_int,
            lr,
            beta0: 1e-4,
            eps_beta: 0.01,
            data_scale: train.len() as f32,
            ..Default::default()
        };
        let r = coordinator::compress(&arts, &train, &test, &cfg)?;
        table.row(vec![
            tag.to_string(),
            fmt_size(r.total_bits as f64 / 8.0),
            format!("{:.0}x", n_bits_fp32 as f64 / r.total_bits as f64),
            format!("{:.2} %", r.test_error * 100.0),
        ]);
    }
    Ok(table)
}

fn main() -> Result<()> {
    banner("Table 1 — compression method comparison");
    let rt = Runtime::cpu()?;
    let t1 = bench_model(&rt, "lenet_synth", 2e-3)?;
    print!("{}", t1.render());
    t1.save_csv("bench_table1_lenet.csv")?;
    let t2 = bench_model(&rt, "conv_synth", 2e-3)?;
    print!("{}", t2.render());
    t2.save_csv("bench_table1_conv.csv")?;
    println!("\nCSV written: bench_table1_lenet.csv bench_table1_conv.csv");
    Ok(())
}
