//! L3 hot-path microbenches:
//!
//! * backend invocation overhead + latency of each runtime entry
//!   (train_step, score_block, decode_block, eval_batch) — pure-Rust
//!   native kernels by default, PJRT with `--features xla`
//! * encode throughput (blocks/s) and candidate-scoring throughput
//!   (candidates/s) — the paper's compute hot-spot
//! * bitstream + Huffman coder throughput
//! * server throughput / latency under closed-loop clients
//!
//! * per-kernel rows: the dispatched SIMD variants (bulk Pcg64, fused
//!   score, dot) timed against the scalar reference on identical buffers —
//!   the speedup is measured, not asserted (dispatch path + thread count
//!   are recorded in the JSON)
//!
//! Flags (after `--` under `cargo bench`):
//! * `--json`  — additionally write `BENCH_runtime_perf.json` at the repo
//!   root (machine-readable trajectory point; see `docs/perf.md`)
//! * `--quick` — reduced iteration counts for CI smoke runs

mod common;

use miracle::bitstream::huffman;
use miracle::bitstream::{BitReader, BitWriter};
use miracle::codec::MrcFile;
use miracle::coordinator::{encoder, MiracleCfg, Session};
use miracle::data;
use miracle::prng::Pcg64;
use miracle::runtime::{self, Runtime};
use miracle::server::{spawn_clients, Server, ServerCfg, ShedPolicy};
use miracle::util::json::Json;
use miracle::util::pool;
use miracle::util::simd::{self, SimdPath};
use miracle::util::stats::{bench_fn, report_bench, summarize};
use miracle::util::Result;

#[derive(Clone, Copy)]
struct Opts {
    quick: bool,
    json: bool,
}

impl Opts {
    /// (warmup, iters) scaled down under --quick.
    fn iters(&self, warmup: usize, iters: usize) -> (usize, usize) {
        if self.quick {
            (1, ((iters + 7) / 8).max(2))
        } else {
            (warmup, iters)
        }
    }
}

fn mean(samples: &[f64]) -> f64 {
    summarize(samples).mean
}

fn bench_artifacts(rt: &Runtime, opts: &Opts) -> Result<(Json, &'static str)> {
    println!("\n-- backend entry latency (tiny_mlp) --");
    let arts = runtime::load(rt, "tiny_mlp")?;
    let backend = arts.backend_kind();
    let n_blocks = arts.meta.b;
    let train = data::synth_protos(512, 16, 4, 1);
    let cfg = MiracleCfg { i0: 0, data_scale: 512.0, ..Default::default() };
    let mut session = Session::new(&arts, &train, &cfg)?;
    let (w, n) = opts.iters(3, 30);
    let train_samples = bench_fn(w, n, || {
        session.train_step(true).unwrap();
    });
    report_bench(
        &format!(
            "train_step (B={n_blocks},S={},batch={})",
            arts.meta.s, arts.meta.batch
        ),
        &train_samples,
        None,
    );

    let mut b = 0usize;
    let (wu, n) = opts.iters(3, 30);
    let encode_samples = bench_fn(wu, n, || {
        // rotate blocks so freezing doesn't accumulate into the timing
        session.frozen_mask[b % n_blocks] = 0.0;
        let _ = encoder::encode_block(&mut session, b % n_blocks).unwrap();
        b += 1;
    });
    let k = 1u64 << cfg.c_loc_bits;
    report_bench(
        &format!("encode_block (K={k}, k_chunk={})", arts.meta.k_chunk),
        &encode_samples,
        Some((k as f64, "candidates")),
    );

    let lsp = vec![-2.0f32; arts.meta.s];
    let (wu, n) = opts.iters(3, 50);
    let decode_samples = bench_fn(wu, n, || {
        let _ = encoder::decode_block_row(&arts, 7, 3, 17, &lsp).unwrap();
    });
    report_bench("decode_block_row", &decode_samples, None);

    let json = Json::obj(vec![
        ("train_step_us", Json::num(mean(&train_samples) * 1e6)),
        (
            "encode_block",
            Json::obj(vec![
                ("k", Json::num(k as f64)),
                ("mean_us", Json::num(mean(&encode_samples) * 1e6)),
                (
                    "candidates_per_s",
                    Json::num(k as f64 / mean(&encode_samples)),
                ),
            ]),
        ),
        ("decode_block_us", Json::num(mean(&decode_samples) * 1e6)),
    ]);
    Ok((json, backend))
}

fn bench_lenet_hotpath(rt: &Runtime, opts: &Opts) -> Result<Json> {
    println!("\n-- paper-scale hot path (lenet_synth) --");
    let arts = runtime::load(rt, "lenet_synth")?;
    let train = data::synth_mnist(1024, 1);
    let cfg = MiracleCfg { i0: 0, c_loc_bits: 12, data_scale: 1024.0, ..Default::default() };
    let n_blocks = arts.meta.b;
    let label = format!(
        "train_step (B={},S={},batch={})",
        arts.meta.b, arts.meta.s, arts.meta.batch
    );
    let mut session = Session::new(&arts, &train, &cfg)?;
    let (w, n) = opts.iters(2, 15);
    let train_samples = bench_fn(w, n, || {
        session.train_step(true).unwrap();
    });
    report_bench(&label, &train_samples, None);

    let mut b = 0usize;
    let (wu, n) = opts.iters(2, 15);
    let encode_samples = bench_fn(wu, n, || {
        session.frozen_mask[b % n_blocks] = 0.0;
        let _ = encoder::encode_block(&mut session, b % n_blocks).unwrap();
        b += 1;
    });
    let k = 1u64 << cfg.c_loc_bits;
    report_bench(
        &format!("encode_block (K={k}, k_chunk={})", arts.meta.k_chunk),
        &encode_samples,
        Some((k as f64, "candidates")),
    );
    // per-entry cumulative stats gathered by the runtime
    for (name, n, secs) in arts.invocation_stats() {
        if n > 0 {
            println!(
                "   {name:<24} {n:>6} calls  {:>8.3} ms/call",
                secs * 1e3 / n as f64
            );
        }
    }

    Ok(Json::obj(vec![
        ("train_step_ms", Json::num(mean(&train_samples) * 1e3)),
        (
            "encode_block",
            Json::obj(vec![
                ("k", Json::num(k as f64)),
                ("mean_ms", Json::num(mean(&encode_samples) * 1e3)),
                (
                    "candidates_per_s",
                    Json::num(k as f64 / mean(&encode_samples)),
                ),
            ]),
        ),
    ]))
}

fn bench_bitstream(opts: &Opts) -> Json {
    println!("\n-- bitstream / huffman substrate --");
    let mut rng = Pcg64::seed(3);
    let vals: Vec<u64> = (0..10_000).map(|_| rng.next_u64() & 0xfff).collect();
    let (w, n) = opts.iters(3, 50);
    let write_samples = bench_fn(w, n, || {
        let mut w = BitWriter::new();
        for &v in &vals {
            w.write_bits(v, 12);
        }
        std::hint::black_box(w.finish());
    });
    report_bench("bitwriter 10k x 12-bit", &write_samples, Some((10_000.0, "sym")));

    let mut w = BitWriter::new();
    for &v in &vals {
        w.write_bits(v, 12);
    }
    let bytes = w.finish();
    let (wu, n) = opts.iters(3, 50);
    let read_samples = bench_fn(wu, n, || {
        let mut r = BitReader::new(&bytes);
        let mut acc = 0u64;
        for _ in 0..vals.len() {
            acc ^= r.read_bits(12).unwrap();
        }
        std::hint::black_box(acc);
    });
    report_bench("bitreader 10k x 12-bit", &read_samples, Some((10_000.0, "sym")));

    let syms: Vec<u32> = (0..20_000)
        .map(|_| {
            // geometric-ish
            let mut s = 0u32;
            while rng.next_f64() < 0.5 && s < 15 {
                s += 1;
            }
            s
        })
        .collect();
    let (wu, n) = opts.iters(2, 20);
    let huff_samples = bench_fn(wu, n, || {
        let _ = huffman::encode_stream(&syms).unwrap();
    });
    report_bench("huffman build+encode 20k syms", &huff_samples, Some((20_000.0, "sym")));

    Json::obj(vec![
        ("bitwriter_sym_per_s", Json::num(10_000.0 / mean(&write_samples))),
        ("bitreader_sym_per_s", Json::num(10_000.0 / mean(&read_samples))),
        ("huffman_sym_per_s", Json::num(20_000.0 / mean(&huff_samples))),
    ])
}

/// Per-kernel rows: dispatched variant vs the scalar reference on the same
/// buffers, single-threaded — isolates the SIMD win from pool scaling.
fn bench_kernels(opts: &Opts) -> Json {
    use miracle::prng::bulk;
    use miracle::runtime::kernels;
    use miracle::tensor::linalg;

    let path = simd::active();
    println!("\n-- dispatched kernels vs scalar reference (simd={path}) --");
    let mut rows = Vec::new();
    let mut row = |name: &str,
                   items: f64,
                   unit: &str,
                   scalar_s: &[f64],
                   disp_s: &[f64]| {
        let sm = mean(scalar_s);
        let dm = mean(disp_s);
        println!(
            "   {name:<26} scalar {:>9.3} ms   {path:<6} {:>9.3} ms   speedup {:>5.2}x",
            sm * 1e3,
            dm * 1e3,
            sm / dm
        );
        rows.push(Json::obj(vec![
            ("kernel", Json::str(name)),
            ("scalar_ms", Json::num(sm * 1e3)),
            ("dispatched_ms", Json::num(dm * 1e3)),
            ("speedup", Json::num(sm / dm)),
            (
                &format!("{unit}_per_s"),
                Json::num(items / dm),
            ),
        ]));
    };

    // bit-exact bulk Pcg64 (integer LCG jump) — 64Ki u64 draws
    let n_u64 = 65_536usize;
    let mut buf = vec![0u64; n_u64];
    let (w, n) = opts.iters(3, 40);
    let scal = bench_fn(w, n, || {
        std::hint::black_box(bulk::fill_u64s_with(
            SimdPath::Scalar,
            0x0DDB_1A5E_5BAD_5EED,
            0x9E37_79B9 | 1,
            &mut buf,
        ));
    });
    let disp = bench_fn(w, n, || {
        std::hint::black_box(bulk::fill_u64s_with(
            path,
            0x0DDB_1A5E_5BAD_5EED,
            0x9E37_79B9 | 1,
            &mut buf,
        ));
    });
    row("pcg_fill_u64s (64Ki)", n_u64 as f64, "u64", &scal, &disp);

    // fused candidate scoring — 256 rows of S=512 (a lenet-scale block)
    let (s_dim, k) = (512usize, 256usize);
    let mut rng = Pcg64::seed(0xBE7C);
    let mk = |rng: &mut Pcg64, lo: f32, hi: f32, n: usize| -> Vec<f32> {
        (0..n).map(|_| lo + (hi - lo) * rng.next_f32()).collect()
    };
    let mu = mk(&mut rng, -0.5, 0.5, s_dim);
    let rho = mk(&mut rng, -2.0, -0.5, s_dim);
    let lsp = mk(&mut rng, -1.5, -0.5, s_dim);
    let mask = vec![1f32; s_dim];
    let consts = kernels::score_consts(&mu, &rho, &lsp, &mask);
    let zs = miracle::prng::normals_f32(&mut rng, k * s_dim);
    let mut logits = vec![0f32; k];
    let (w, n) = opts.iters(3, 40);
    let scal = bench_fn(w, n, || {
        kernels::score_rows_with(SimdPath::Scalar, &consts, &zs, &mut logits);
        std::hint::black_box(&mut logits);
    });
    let disp = bench_fn(w, n, || {
        kernels::score_rows_with(path, &consts, &zs, &mut logits);
        std::hint::black_box(&mut logits);
    });
    row(
        &format!("score_rows (K={k},S={s_dim})"),
        k as f64,
        "rows",
        &scal,
        &disp,
    );

    // dense dot micro-kernel — 64 pairs of length 4096 per sample
    let (pairs, len) = (64usize, 4096usize);
    let a = mk(&mut rng, -0.5, 0.5, pairs * len);
    let b = mk(&mut rng, -0.5, 0.5, pairs * len);
    let (w, n) = opts.iters(3, 40);
    let scal = bench_fn(w, n, || {
        let mut acc = 0f32;
        for p in 0..pairs {
            let r = p * len..(p + 1) * len;
            acc += linalg::dot_with(SimdPath::Scalar, &a[r.clone()], &b[r]);
        }
        std::hint::black_box(acc);
    });
    let disp = bench_fn(w, n, || {
        let mut acc = 0f32;
        for p in 0..pairs {
            let r = p * len..(p + 1) * len;
            acc += linalg::dot_with(path, &a[r.clone()], &b[r]);
        }
        std::hint::black_box(acc);
    });
    row(
        &format!("dot ({pairs}x{len})"),
        (pairs * len) as f64,
        "mac",
        &scal,
        &disp,
    );

    Json::Arr(rows)
}

fn bench_server(rt: &Runtime, opts: &Opts) -> Result<Json> {
    println!("\n-- inference server (tiny_mlp, closed-loop clients) --");
    let arts = runtime::load(rt, "tiny_mlp")?;
    let mrc = MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0xABCD,
        protocol_seed: 7,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: 10,
        lsp: vec![-2.0f32; arts.meta.n_layers],
        indices: (0..arts.meta.b as u64).map(|i| (i * 37) % 1024).collect(),
    };
    let test = data::synth_protos(256, 16, 4, 9);
    let feat = test.feature_dim();
    let examples: Vec<Vec<f32>> = (0..test.len())
        .map(|i| test.x[i * feat..(i + 1) * feat].to_vec())
        .collect();
    let client_counts: &[usize] = if opts.quick { &[1, 4] } else { &[1, 4, 16] };
    let total_requests = if opts.quick { 64 } else { 256 };
    let mut rows = Vec::new();
    for &clients in client_counts {
        let mut server = Server::new(&arts, &mrc, ServerCfg::default())?;
        let (rx, join) = spawn_clients(
            examples.clone(),
            clients,
            total_requests / clients,
            std::time::Duration::ZERO,
        );
        let stats = server.run(rx)?;
        let _ = join.join();
        let req_per_s = stats.served as f64 / stats.wall_secs;
        println!(
            "   {clients:>2} clients: {req_per_s:>7.0} req/s   p50 {:>7.2} ms   p99 {:>7.2} ms   avg batch {:.1}",
            stats.latency.p50 * 1e3,
            stats.latency.p99 * 1e3,
            stats.served as f64 / stats.batches.max(1) as f64,
        );
        rows.push(Json::obj(vec![
            ("clients", Json::num(clients as f64)),
            ("req_per_s", Json::num(req_per_s)),
            ("p50_ms", Json::num(stats.latency.p50 * 1e3)),
            ("p99_ms", Json::num(stats.latency.p99 * 1e3)),
        ]));
    }

    // bounded-admission row: same closed-loop load against a shallow queue,
    // so the shed path (admission check + Overloaded answer) is on the
    // clock too — resilience must not cost serve-path throughput
    let clients = *client_counts.last().unwrap();
    let cfg = ServerCfg {
        queue_depth: 8,
        shed: ShedPolicy::Reject,
        ..Default::default()
    };
    let mut server = Server::new(&arts, &mrc, cfg)?;
    let (rx, join) = spawn_clients(
        examples,
        clients,
        total_requests / clients,
        std::time::Duration::ZERO,
    );
    let stats = server.run(rx)?;
    let _ = join.join();
    let answered_per_s =
        (stats.served + stats.rejected) as f64 / stats.wall_secs;
    println!(
        "   {clients:>2} clients (queue 8): {answered_per_s:>7.0} answers/s   {} served / {} shed   high-water {}",
        stats.served, stats.rejected, stats.queue_high_water,
    );
    rows.push(Json::obj(vec![
        ("clients", Json::num(clients as f64)),
        ("queue_depth", Json::num(8.0)),
        ("answers_per_s", Json::num(answered_per_s)),
        ("served", Json::num(stats.served as f64)),
        ("shed", Json::num(stats.rejected as f64)),
        ("queue_high_water", Json::num(stats.queue_high_water as f64)),
    ]));
    Ok(Json::Arr(rows))
}

/// `BENCH_runtime_perf.json` lives at the workspace root regardless of the
/// invocation directory, so trajectory points across PRs land in one place.
fn json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."))
        .join("BENCH_runtime_perf.json")
}

fn main() -> Result<()> {
    let mut opts = Opts { quick: false, json: false };
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--quick" => opts.quick = true,
            "--json" => opts.json = true,
            other => eprintln!("bench_runtime_perf: ignoring unknown flag '{other}'"),
        }
    }
    common::banner("Runtime perf microbenches");
    let rt = Runtime::cpu()?;
    println!(
        "simd dispatch: {} (MIRACLE_SIMD to override), threads: {}",
        simd::active(),
        pool::current_threads()
    );
    let (tiny, backend) = bench_artifacts(&rt, &opts)?;
    let lenet = bench_lenet_hotpath(&rt, &opts)?;
    let kernels = bench_kernels(&opts);
    let bitstream = bench_bitstream(&opts);
    let server = bench_server(&rt, &opts)?;
    if opts.json {
        let doc = Json::obj(vec![
            // schema 2: adds "simd" (dispatch path) + "kernels" (per-kernel
            // scalar-vs-dispatched rows)
            ("schema", Json::num(2.0)),
            ("bench", Json::str("runtime_perf")),
            ("quick", Json::Bool(opts.quick)),
            ("backend", Json::str(backend)),
            ("simd", Json::str(simd::active().name())),
            ("threads", Json::num(pool::current_threads() as f64)),
            ("tiny_mlp", tiny),
            ("lenet_synth", lenet),
            ("kernels", kernels),
            ("bitstream", bitstream),
            ("server_tiny_mlp", server),
        ]);
        let path = json_path();
        std::fs::write(&path, doc.to_pretty() + "\n")?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}
