//! L3 hot-path microbenches:
//!
//! * backend invocation overhead + latency of each runtime entry
//!   (train_step, score_chunk, decode_chunk, eval_batch) — pure-Rust
//!   native kernels by default, PJRT with `--features xla`
//! * encode throughput (blocks/s) and candidate-scoring throughput
//!   (candidates/s) — the paper's compute hot-spot
//! * bitstream + Huffman coder throughput
//! * server throughput / latency under closed-loop clients

mod common;

use miracle::bitstream::huffman;
use miracle::bitstream::{BitReader, BitWriter};
use miracle::codec::MrcFile;
use miracle::coordinator::{encoder, MiracleCfg, Session};
use miracle::data;
use miracle::prng::Pcg64;
use miracle::runtime::{self, Runtime};
use miracle::server::{spawn_clients, Server, ServerCfg};
use miracle::util::stats::{bench_fn, report_bench};
use miracle::util::Result;

fn bench_artifacts(rt: &Runtime) -> Result<()> {
    println!("\n-- backend entry latency (tiny_mlp) --");
    let arts = runtime::load(rt, "tiny_mlp")?;
    let train = data::synth_protos(512, 16, 4, 1);
    let cfg = MiracleCfg { i0: 0, data_scale: 512.0, ..Default::default() };
    let mut session = Session::new(&arts, &train, &cfg)?;
    let samples = bench_fn(3, 30, || {
        session.train_step(true).unwrap();
    });
    report_bench("train_step (B=22,S=8,batch=32)", &samples, None);

    let mut b = 0usize;
    let samples = bench_fn(3, 30, || {
        // rotate blocks so freezing doesn't accumulate into the timing
        session.frozen_mask[b % 22] = 0.0;
        let _ = encoder::encode_block(&mut session, b % 22).unwrap();
        b += 1;
    });
    let k = 1u64 << cfg.c_loc_bits;
    report_bench(
        &format!("encode_block (K={k}, k_chunk=64)"),
        &samples,
        Some((k as f64, "candidates")),
    );

    let lsp = vec![-2.0f32; arts.meta.s];
    let samples = bench_fn(3, 50, || {
        let _ = encoder::decode_block_row(&arts, 7, 3, 17, &lsp).unwrap();
    });
    report_bench("decode_block_row", &samples, None);
    Ok(())
}

fn bench_lenet_hotpath(rt: &Runtime) -> Result<()> {
    println!("\n-- paper-scale hot path (lenet_synth) --");
    let arts = runtime::load(rt, "lenet_synth")?;
    let train = data::synth_mnist(1024, 1);
    let cfg = MiracleCfg { i0: 0, c_loc_bits: 12, data_scale: 1024.0, ..Default::default() };
    let n_blocks = arts.meta.b;
    let label = format!(
        "train_step (B={},S={},batch={})",
        arts.meta.b, arts.meta.s, arts.meta.batch
    );
    let mut session = Session::new(&arts, &train, &cfg)?;
    let samples = bench_fn(2, 15, || {
        session.train_step(true).unwrap();
    });
    report_bench(&label, &samples, None);

    let mut b = 0usize;
    let samples = bench_fn(2, 15, || {
        session.frozen_mask[b % n_blocks] = 0.0;
        let _ = encoder::encode_block(&mut session, b % n_blocks).unwrap();
        b += 1;
    });
    let k = 1u64 << cfg.c_loc_bits;
    report_bench(
        &format!("encode_block (K={k}, k_chunk={})", arts.meta.k_chunk),
        &samples,
        Some((k as f64, "candidates")),
    );
    // per-entry cumulative stats gathered by the runtime
    for (name, n, secs) in arts.invocation_stats() {
        if n > 0 {
            println!(
                "   {name:<24} {n:>6} calls  {:>8.3} ms/call",
                secs * 1e3 / n as f64
            );
        }
    }
    Ok(())
}

fn bench_bitstream() {
    println!("\n-- bitstream / huffman substrate --");
    let mut rng = Pcg64::seed(3);
    let vals: Vec<u64> = (0..10_000).map(|_| rng.next_u64() & 0xfff).collect();
    let samples = bench_fn(3, 50, || {
        let mut w = BitWriter::new();
        for &v in &vals {
            w.write_bits(v, 12);
        }
        std::hint::black_box(w.finish());
    });
    report_bench("bitwriter 10k x 12-bit", &samples, Some((10_000.0, "sym")));

    let mut w = BitWriter::new();
    for &v in &vals {
        w.write_bits(v, 12);
    }
    let bytes = w.finish();
    let samples = bench_fn(3, 50, || {
        let mut r = BitReader::new(&bytes);
        let mut acc = 0u64;
        for _ in 0..vals.len() {
            acc ^= r.read_bits(12).unwrap();
        }
        std::hint::black_box(acc);
    });
    report_bench("bitreader 10k x 12-bit", &samples, Some((10_000.0, "sym")));

    let syms: Vec<u32> = (0..20_000)
        .map(|_| {
            // geometric-ish
            let mut s = 0u32;
            while rng.next_f64() < 0.5 && s < 15 {
                s += 1;
            }
            s
        })
        .collect();
    let samples = bench_fn(2, 20, || {
        let _ = huffman::encode_stream(&syms).unwrap();
    });
    report_bench("huffman build+encode 20k syms", &samples, Some((20_000.0, "sym")));
}

fn bench_server(rt: &Runtime) -> Result<()> {
    println!("\n-- inference server (tiny_mlp, closed-loop clients) --");
    let arts = runtime::load(rt, "tiny_mlp")?;
    let mrc = MrcFile {
        model: "tiny_mlp".into(),
        layout_seed: 0xABCD,
        protocol_seed: 7,
        backend: arts.backend_family(),
        b: arts.meta.b,
        s: arts.meta.s,
        k_chunk: arts.meta.k_chunk,
        c_loc_bits: 10,
        lsp: vec![-2.0f32; arts.meta.n_layers],
        indices: (0..arts.meta.b as u64).map(|i| (i * 37) % 1024).collect(),
    };
    let test = data::synth_protos(256, 16, 4, 9);
    let feat = test.feature_dim();
    let examples: Vec<Vec<f32>> = (0..test.len())
        .map(|i| test.x[i * feat..(i + 1) * feat].to_vec())
        .collect();
    for clients in [1usize, 4, 16] {
        let mut server = Server::new(&arts, &mrc, ServerCfg::default())?;
        let (rx, join) = spawn_clients(
            examples.clone(),
            clients,
            256 / clients,
            std::time::Duration::ZERO,
        );
        let stats = server.run(rx)?;
        let _ = join.join();
        println!(
            "   {clients:>2} clients: {:>7.0} req/s   p50 {:>7.2} ms   p99 {:>7.2} ms   avg batch {:.1}",
            stats.served as f64 / stats.wall_secs,
            stats.latency.p50 * 1e3,
            stats.latency.p99 * 1e3,
            stats.served as f64 / stats.batches.max(1) as f64,
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    common::banner("Runtime perf microbenches");
    let rt = Runtime::cpu()?;
    bench_artifacts(&rt)?;
    bench_lenet_hotpath(&rt)?;
    bench_bitstream();
    bench_server(&rt)?;
    Ok(())
}
