//! Ablations the paper calls out in §3.3/§4:
//!
//! * **A1 — hashing trick**: §3.3 reports ~1.5x better compression at equal
//!   quality. We compare the hashed config against the dense config at the
//!   same total coding budget and report error + effective ratio.
//! * **A2 — intermediate iterations I**: "crucial for good performance" —
//!   sweep I ∈ {0, 1, 5} and report error at fixed budget.
//! * **A3 — local budget C_loc**: K = 2^C_loc grows exponentially (encode
//!   time) while quality improves — the practical-tractability trade-off of
//!   §3.3. Reports encode wall time per block alongside error.

mod common;

use common::{banner, datasets_for, miracle_iters, scale};
use miracle::coordinator::{self, MiracleCfg};
use miracle::metrics::{fmt_size, Table};
use miracle::runtime::{self, Runtime};
use miracle::util::Result;

fn cfg_base(i0: usize, i: usize, bits: u8, train_len: usize) -> MiracleCfg {
    MiracleCfg {
        c_loc_bits: bits,
        i0,
        i_intermediate: i,
        lr: 2e-3,
        beta0: 1e-4,
        eps_beta: 0.01,
        data_scale: train_len as f32,
        ..Default::default()
    }
}

fn main() -> Result<()> {
    // runs entirely on the native backend: lenet_synth is a built-in config
    banner("Ablations — hashing trick, intermediate iterations, C_loc");
    let s = scale();
    let rt = Runtime::cpu()?;
    let (i0, _) = miracle_iters(s);

    // ---- A1: hashing trick (hashed vs dense parameterization) ----
    {
        let (train, test) = datasets_for("lenet_synth", s);
        let mut t = Table::new(
            "A1 — hashing trick (lenet_synth, C_loc=12b)",
            &["variant", "slots", "size", "test error %"],
        );
        for (name, label) in [("lenet_synth", "hashed (~3.7x fewer slots)"),
                              ("lenet_synth_dense", "dense (no hashing)")] {
            let arts = runtime::load(&rt, name)?;
            let cfg = cfg_base(i0, 1, 12, train.len());
            let r = coordinator::compress(&arts, &train, &test, &cfg)?;
            t.row(vec![
                label.to_string(),
                arts.meta.n_slots.to_string(),
                fmt_size(r.total_bits as f64 / 8.0),
                format!("{:.2}", r.test_error * 100.0),
            ]);
        }
        print!("{}", t.render());
        t.save_csv("bench_ablation_hashing.csv")?;
    }

    // ---- A2: intermediate iterations I ----
    {
        let arts = runtime::load(&rt, "lenet_synth")?;
        let (train, test) = datasets_for("lenet_synth", s);
        // the tight-budget regime is where compensating for earlier coded
        // blocks matters (paper: "crucial for good performance")
        let mut t = Table::new(
            "A2 — intermediate variational iterations (lenet_synth, C_loc=3b)",
            &["I", "test error %", "mean block KL bits"],
        );
        for i in [0usize, 1, 5] {
            let cfg = cfg_base(i0, i, 3, train.len());
            let r = coordinator::compress(&arts, &train, &test, &cfg)?;
            t.row(vec![
                i.to_string(),
                format!("{:.2}", r.test_error * 100.0),
                format!("{:.2}", r.mean_block_kl_bits),
            ]);
        }
        print!("{}", t.render());
        t.save_csv("bench_ablation_intermediate.csv")?;
    }

    // ---- A3: C_loc / K trade-off ----
    {
        let arts = runtime::load(&rt, "lenet_synth")?;
        let (train, test) = datasets_for("lenet_synth", s);
        let mut t = Table::new(
            "A3 — local budget C_loc (K = 2^C_loc candidates/block)",
            &["C_loc bits", "K", "encode ms/block", "size", "test error %"],
        );
        for bits in [6u8, 10, 14] {
            let cfg = cfg_base(i0, 1, bits, train.len());
            let r = coordinator::compress(&arts, &train, &test, &cfg)?;
            t.row(vec![
                bits.to_string(),
                (1u64 << bits).to_string(),
                format!("{:.2}", r.encode_secs * 1e3 / r.mrc.b as f64),
                fmt_size(r.total_bits as f64 / 8.0),
                format!("{:.2}", r.test_error * 100.0),
            ]);
        }
        print!("{}", t.render());
        t.save_csv("bench_ablation_cloc.csv")?;
    }
    Ok(())
}
