//! Theory benches (§3.2 Theorem 3.2 + Appendix A) — backend-free: these
//! exercise the pure coding-theory layer (`grc`, `prng`) only.
//!
//! * **C1** — bias of the proxy distribution q̃ vs the sample budget
//!   K = exp(KL + t): |E_q̃[f] − E_q[f]| should fall as t grows and is
//!   already small at t = 0 (the paper's operating point K = exp(KL)).
//! * **C2** — greedy rejection sampling (Algorithm 3): expected prefix-free
//!   code length obeys E|l(i*)| ≤ KL + 2 log(KL + 1) + O(1) (Eq. 15), and
//!   the empirical sample distribution matches q (unbiasedness).
//! * **C3** — Algorithm 1 vs Algorithm 3 code lengths across a KL sweep:
//!   both track the KL lower bound; Alg 1 pays a fixed C_loc, Alg 3 pays
//!   the VL-coded stopping index.

use miracle::grc::{greedy_rejection_sample, minimal_random_code_sample, Discrete};
use miracle::metrics::Table;
use miracle::prng::Pcg64;
use miracle::util::Result;

fn qp_with_kl(target_kl_nats: f64, n: usize) -> (Discrete, Discrete, f64) {
    // shift a discretized Gaussian against a unit one until KL matches
    let p = Discrete::gauss(n, 0.0, 1.0, 6.0);
    let mut lo = 0.0f64;
    let mut hi = 6.0f64;
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        let q = Discrete::gauss(n, mid, 0.6, 6.0);
        if q.kl(&p) < target_kl_nats {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let q = Discrete::gauss(n, 0.5 * (lo + hi), 0.6, 6.0);
    let kl = q.kl(&p);
    (q, p, kl)
}

fn c1_proxy_bias() -> Result<()> {
    let mut t = Table::new(
        "C1 — Theorem 3.2: proxy bias |E_q̃[f]-E_q[f]| vs t  (K=exp(KL+t))",
        &["t (nats)", "K", "mean |bias|", "rel. to f-range"],
    );
    let (q, p, kl) = qp_with_kl(3.0, 256);
    let f = |w: usize| (w as f64 / 255.0) * 2.0 - 1.0; // f in [-1,1]
    let e_q: f64 = q.p.iter().enumerate().map(|(w, &qq)| f(w) * qq).sum();
    for &t_nats in &[-1.0f64, 0.0, 1.0, 2.0, 3.0] {
        let k = ((kl + t_nats).exp().ceil() as usize).max(1);
        let trials = 400;
        let mut bias = 0.0;
        for trial in 0..trials {
            let mut rng = Pcg64::seed(1000 + trial);
            let (_, _, wts, cands) = minimal_random_code_sample(&q, &p, k, &mut rng);
            let e: f64 = wts.iter().zip(&cands).map(|(&w, &c)| w * f(c)).sum();
            bias += (e - e_q).abs();
        }
        bias /= trials as f64;
        t.row(vec![
            format!("{t_nats:+.0}"),
            k.to_string(),
            format!("{bias:.4}"),
            format!("{:.2}%", bias / 2.0 * 100.0),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_theory_c1.csv")?;
    Ok(())
}

fn c2_grc_bounds() -> Result<()> {
    let mut t = Table::new(
        "C2 — Algorithm 3 (greedy rejection): code length vs Eq. 15 bound",
        &["KL bits", "E[l(i*)] bits", "bound KL+2log(KL+1)+4", "TV(samples, q)"],
    );
    for &kl_target in &[1.0f64, 2.0, 4.0, 6.0] {
        let (q, p, kl) = qp_with_kl(kl_target * std::f64::consts::LN_2, 64);
        let kl_bits = kl / std::f64::consts::LN_2;
        let mut rng = Pcg64::seed(5);
        let trials = 3000;
        let mut bits = 0.0;
        let mut counts = vec![0f64; q.p.len()];
        for _ in 0..trials {
            let s = greedy_rejection_sample(&q, &p, &mut rng);
            bits += s.code_bits as f64;
            counts[s.value] += 1.0;
        }
        bits /= trials as f64;
        let tv: f64 = counts
            .iter()
            .zip(&q.p)
            .map(|(&c, &qq)| (c / trials as f64 - qq).abs())
            .sum::<f64>()
            / 2.0;
        let bound = kl_bits + 2.0 * (kl_bits + 1.0).log2() + 4.0;
        t.row(vec![
            format!("{kl_bits:.2}"),
            format!("{bits:.2}"),
            format!("{bound:.2}"),
            format!("{tv:.3}"),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_theory_c2.csv")?;
    Ok(())
}

fn c3_alg1_vs_alg3() -> Result<()> {
    let mut t = Table::new(
        "C3 — Algorithm 1 (fixed C_loc) vs Algorithm 3 (VL index) code cost",
        &["KL bits", "Alg1 bits (K=e^KL)", "Alg3 E[bits]", "lower bound (KL)"],
    );
    for &kl_target in &[2.0f64, 4.0, 6.0, 8.0] {
        let (q, p, kl) = qp_with_kl(kl_target * std::f64::consts::LN_2, 64);
        let kl_bits = kl / std::f64::consts::LN_2;
        // Algorithm 1: index into K = exp(KL) candidates -> log2 K bits
        let alg1_bits = (kl.exp().ceil()).log2();
        let mut rng = Pcg64::seed(11);
        let trials = 1500;
        let alg3_bits: f64 = (0..trials)
            .map(|_| greedy_rejection_sample(&q, &p, &mut rng).code_bits as f64)
            .sum::<f64>()
            / trials as f64;
        t.row(vec![
            format!("{kl_bits:.2}"),
            format!("{alg1_bits:.2}"),
            format!("{alg3_bits:.2}"),
            format!("{kl_bits:.2}"),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_theory_c3.csv")?;
    Ok(())
}

fn main() -> Result<()> {
    println!("\n############################################################");
    println!("# Coding-theory benches (Theorem 3.2, Appendix A)");
    println!("############################################################");
    c1_proxy_bias()?;
    c2_grc_bounds()?;
    c3_alg1_vs_alg3()?;
    Ok(())
}
