//! Regenerates **Figure 1**: test-error vs compressed-size trade-off curves
//! for both benchmarks (runs on the default native backend; set
//! `MIRACLE_BACKEND=xla` for the PJRT path). MIRACLE's series comes from
//! sweeping the per-block budget `C_loc` at fixed B (the paper's protocol
//! for VGG); baseline series from sweeping their own operating knobs.
//!
//! Expected shape (paper): the MIRACLE curve lies down-and-left of every
//! baseline curve (Pareto dominance); error rises as size shrinks.

mod common;

use common::{banner, datasets_for, dense_steps, miracle_iters, scale, Scale};
use miracle::baselines::runner;
use miracle::coordinator::{self, MiracleCfg};
use miracle::metrics::Table;
use miracle::runtime::{self, Runtime};
use miracle::util::Result;

fn series_for(rt: &Runtime, model: &str, lr: f32) -> Result<Table> {
    let s = scale();
    let arts = runtime::load(rt, model)?;
    let dense_arts = runtime::load(rt, &format!("{model}_dense"))?;
    let (train, test) = datasets_for(model, s);
    let (i0, i_int) = miracle_iters(s);

    let mut t = Table::new(
        &format!("Figure 1 — {model} (error vs size)"),
        &["series", "point", "size bits", "test error %"],
    );

    // the interesting regime on this substrate sits at very tight budgets:
    // >=6 bits/block is already lossless on the synthetic tasks
    let budgets: &[u8] = match s {
        Scale::Quick => &[2, 3, 4, 6, 10],
        Scale::Full => &[2, 3, 4, 5, 6, 8, 10, 14],
    };
    for &bits in budgets {
        let cfg = MiracleCfg {
            c_loc_bits: bits,
            i0,
            i_intermediate: i_int,
            lr,
            beta0: 1e-4,
            eps_beta: 0.01,
            data_scale: train.len() as f32,
            ..Default::default()
        };
        let r = coordinator::compress(&arts, &train, &test, &cfg)?;
        t.row(vec![
            "MIRACLE".into(),
            format!("C_loc={bits}b"),
            r.total_bits.to_string(),
            format!("{:.2}", r.test_error * 100.0),
        ]);
    }

    let post = runner::train_dense(
        &dense_arts,
        &train,
        dense_steps(s),
        lr,
        train.len() as f32,
        7,
    )?;
    let dc_points: &[(f64, usize)] = match s {
        Scale::Quick => &[(0.5, 32), (0.8, 16), (0.95, 8)],
        Scale::Full => &[(0.3, 64), (0.5, 32), (0.7, 32), (0.8, 16), (0.9, 16), (0.95, 8)],
    };
    for p in runner::deepcomp_sweep(&dense_arts, &post, &test, dc_points)? {
        t.row(vec![
            "DeepComp".into(),
            p.label,
            p.bits.to_string(),
            format!("{:.2}", p.test_error * 100.0),
        ]);
    }
    let bc_points: &[f32] = match s {
        Scale::Quick => &[0.5, 1.0, 2.0],
        Scale::Full => &[0.25, 0.5, 1.0, 1.5, 2.0, 3.0],
    };
    for p in runner::bayescomp_sweep(&dense_arts, &post, &test, bc_points)? {
        t.row(vec![
            "BayesComp".into(),
            p.label,
            p.bits.to_string(),
            format!("{:.2}", p.test_error * 100.0),
        ]);
    }
    let wl_points: &[(f64, usize, u32)] = match s {
        Scale::Quick => &[(0.8, 16, 6), (0.95, 8, 4)],
        Scale::Full => &[(0.5, 32, 8), (0.8, 16, 6), (0.9, 16, 4), (0.95, 8, 4)],
    };
    for p in runner::weightless_sweep(&dense_arts, &post, &test, wl_points)? {
        t.row(vec![
            "Weightless".into(),
            p.label,
            p.bits.to_string(),
            format!("{:.2}", p.test_error * 100.0),
        ]);
    }
    Ok(t)
}

fn main() -> Result<()> {
    banner("Figure 1 — error vs compression trade-off curves");
    let rt = Runtime::cpu()?;
    for (model, csv) in [
        ("lenet_synth", "bench_figure1_lenet.csv"),
        ("conv_synth", "bench_figure1_conv.csv"),
    ] {
        let t = series_for(&rt, model, 2e-3)?;
        print!("{}", t.render());
        t.save_csv(csv)?;
    }
    println!("\nCSV written: bench_figure1_lenet.csv bench_figure1_conv.csv");
    Ok(())
}
