//! Pareto frontier sweep (Figure 1 driver, small-scale interactive version).
//!
//! Sweeps the per-block coding budget `C_loc` — exactly how the paper traces
//! its trade-off curve for VGG ("C_loc was varied between 15 and 5 bits, B
//! kept constant") — and prints the (size, test error) series for MIRACLE
//! next to the Deep-Compression and Bayesian-Compression baselines.
//!
//! ```text
//! cargo run --release --example pareto_sweep [-- --model tiny_mlp --fast]
//! ```
//! Use `--model lenet_synth` for the paper-scale benchmark (a few minutes).

use miracle::baselines::runner;
use miracle::coordinator::{self, MiracleCfg};
use miracle::data;
use miracle::metrics::{fmt_size, Table};
use miracle::runtime::{self, Runtime};
use miracle::util::args::Args;
use miracle::util::Result;

fn main() -> Result<()> {
    let args = Args::parse(&["fast"])?;
    let model = args.str("model", "tiny_mlp");
    let fast = args.flag("fast") || model == "tiny_mlp";
    args.finish()?;

    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, &model)?;
    let dense_name = if model == "tiny_mlp" {
        "tiny_mlp".to_string()
    } else {
        format!("{model}_dense")
    };
    let dense_arts = runtime::load(&rt, &dense_name)?;

    let (train, test) = if model.starts_with("conv") {
        (
            data::synth_cifar(2048, 16, 16, 1234),
            data::synth_cifar(1024, 16, 16, 99),
        )
    } else if model.starts_with("lenet") {
        (data::synth_mnist(4096, 1234), data::synth_mnist(2048, 99))
    } else {
        (
            data::synth_protos(512, 16, 4, 1234),
            data::synth_protos(512, 16, 4, 99),
        )
    };

    let (i0, i_int, steps_dense) = if fast { (1200, 1, 600) } else { (4000, 1, 3000) };

    let mut table = Table::new(
        &format!("Pareto sweep — {model}"),
        &["method", "size", "bits", "test error %"],
    );

    // MIRACLE series: sweep C_loc at fixed B (the paper's VGG protocol)
    let budgets: &[u8] = if fast { &[6, 10, 14] } else { &[5, 8, 10, 12, 14] };
    for &bits in budgets {
        let cfg = MiracleCfg {
            c_loc_bits: bits,
            i0,
            i_intermediate: i_int,
            lr: if model == "tiny_mlp" { 5e-3 } else { 2e-3 },
            beta0: 1e-4,
            eps_beta: 0.01,
            data_scale: train.len() as f32,
            ..Default::default()
        };
        let r = coordinator::compress(&arts, &train, &test, &cfg)?;
        table.row(vec![
            format!("MIRACLE C_loc={bits}b"),
            fmt_size(r.total_bits as f64 / 8.0),
            r.total_bits.to_string(),
            format!("{:.2}", r.test_error * 100.0),
        ]);
    }

    // baselines on the dense (no-hashing) net
    let post = runner::train_dense(
        &dense_arts,
        &train,
        steps_dense,
        2e-3,
        train.len() as f32,
        7,
    )?;
    let un = miracle::baselines::uncompressed(&post.mu_full, false);
    table.row(vec![
        "Uncompressed fp32".into(),
        fmt_size(un.bits as f64 / 8.0),
        un.bits.to_string(),
        format!(
            "{:.2}",
            coordinator::eval_error_full(&dense_arts, &un.weights, &test)? * 100.0
        ),
    ]);
    for p in runner::deepcomp_sweep(
        &dense_arts,
        &post,
        &test,
        &[(0.5, 32), (0.8, 16), (0.95, 8)],
    )? {
        table.row(vec![
            p.label,
            fmt_size(p.bits as f64 / 8.0),
            p.bits.to_string(),
            format!("{:.2}", p.test_error * 100.0),
        ]);
    }
    for p in runner::bayescomp_sweep(&dense_arts, &post, &test, &[0.5, 1.0, 2.0])? {
        table.row(vec![
            p.label,
            fmt_size(p.bits as f64 / 8.0),
            p.bits.to_string(),
            format!("{:.2}", p.test_error * 100.0),
        ]);
    }

    print!("{}", table.render());
    Ok(())
}
