//! Table-1-style comparison on one benchmark: uncompressed, Deep
//! Compression, Bayesian Compression, MIRACLE (lowest error) and MIRACLE
//! (highest compression).
//!
//! ```text
//! cargo run --release --example baseline_comparison [-- --model tiny_mlp]
//! ```
//! `--model lenet_synth` runs the paper-scale benchmark (several minutes).

use miracle::baselines::bayescomp::BayesCompCfg;
use miracle::baselines::deepcomp::DeepCompCfg;
use miracle::baselines::runner;
use miracle::coordinator::{self, MiracleCfg};
use miracle::data;
use miracle::metrics::{fmt_size, Table};
use miracle::runtime::{self, Runtime};
use miracle::util::args::Args;
use miracle::util::Result;

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    let model = args.str("model", "tiny_mlp");
    args.finish()?;

    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, &model)?;
    let dense_name = if model == "tiny_mlp" {
        "tiny_mlp".to_string()
    } else {
        format!("{model}_dense")
    };
    let dense_arts = runtime::load(&rt, &dense_name)?;

    let (train, test) = if model.starts_with("conv") {
        (
            data::synth_cifar(2048, 16, 16, 1234),
            data::synth_cifar(1024, 16, 16, 99),
        )
    } else if model.starts_with("lenet") {
        (data::synth_mnist(4096, 1234), data::synth_mnist(2048, 99))
    } else {
        (
            data::synth_protos(512, 16, 4, 1234),
            data::synth_protos(512, 16, 4, 99),
        )
    };
    let fast = model == "tiny_mlp";
    let (i0, steps_dense) = if fast { (1500, 800) } else { (4000, 3000) };
    let lr = if fast { 5e-3 } else { 2e-3 };

    let n_bits_fp32 = dense_arts.meta.n_total * 32;
    let mut table = Table::new(
        &format!("Table 1 (ours) — {model}"),
        &["Compression", "Size", "Ratio", "Test error"],
    );
    let mut add = |label: &str, bits: usize, err: f64| {
        table.row(vec![
            label.to_string(),
            fmt_size(bits as f64 / 8.0),
            format!("{:.0}x", n_bits_fp32 as f64 / bits as f64),
            format!("{:.2} %", err * 100.0),
        ]);
    };

    // baselines on the dense net
    let post =
        runner::train_dense(&dense_arts, &train, steps_dense, lr, train.len() as f32, 7)?;
    let suite = runner::baseline_suite(
        &dense_arts,
        &post,
        &test,
        &DeepCompCfg { sparsity: 0.9, clusters: 16, ..Default::default() },
        &BayesCompCfg::default(),
    )?;
    for p in &suite {
        add(&p.label, p.bits, p.test_error);
    }

    // MIRACLE at two operating points
    for (tag, bits) in [("MIRACLE (lowest error)", 14u8), ("MIRACLE (highest compression)", 6)] {
        let cfg = MiracleCfg {
            c_loc_bits: bits,
            i0,
            i_intermediate: 1,
            lr,
            beta0: 1e-4,
            eps_beta: 0.01,
            data_scale: train.len() as f32,
            ..Default::default()
        };
        let r = coordinator::compress(&arts, &train, &test, &cfg)?;
        add(tag, r.total_bits, r.test_error);
    }

    print!("{}", table.render());
    Ok(())
}
