//! Edge-deployment scenario: compress once, then serve predictions straight
//! from the `.mrc` — the paper §5 "inference machine" that reconstructs
//! weights from the pseudo-random generator instead of storing them.
//!
//! ```text
//! cargo run --release --example serve_compressed [-- --clients 8 --requests 64]
//! ```
//!
//! Reports decode time, end-to-end request latency percentiles, batching
//! behaviour and throughput.

use miracle::coordinator::{self, MiracleCfg};
use miracle::data;
use miracle::metrics::fmt_size;
use miracle::runtime::{self, Runtime};
use miracle::server::{spawn_clients, Server, ServerCfg};
use miracle::util::args::Args;
use miracle::util::Result;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::parse(&["lazy"])?;
    let n_clients = args.usize("clients", 8)?;
    let per_client = args.usize("requests", 64)?;
    let max_batch = args.usize("max-batch", 64)?;
    let lazy = args.flag("lazy");
    args.finish()?;

    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, "tiny_mlp")?;
    let train = data::synth_protos(512, 16, 4, 1234);
    let test = data::synth_protos(512, 16, 4, 99);

    // 1. compress (fast settings; quality matters less than the serving demo)
    let cfg = MiracleCfg {
        c_loc_bits: 10,
        i0: 800,
        i_intermediate: 1,
        lr: 5e-3,
        beta0: 1e-3,
        eps_beta: 0.02,
        data_scale: train.len() as f32,
        ..Default::default()
    };
    let result = coordinator::compress(&arts, &train, &test, &cfg)?;
    println!(
        "compressed model: {} (error {:.2}%)",
        fmt_size(result.total_bits as f64 / 8.0),
        result.test_error * 100.0
    );

    // 2. serve it: router + dynamic batcher over the mpsc channel
    let server_cfg = ServerCfg {
        max_batch,
        batch_window: Duration::from_millis(2),
        lazy_decode: lazy,
        ..Default::default()
    };
    let mut server = Server::new(&arts, &result.mrc, server_cfg)?;
    let feat = test.feature_dim();
    let examples: Vec<Vec<f32>> = (0..test.len())
        .map(|i| test.x[i * feat..(i + 1) * feat].to_vec())
        .collect();
    let (rx, clients) = spawn_clients(examples, n_clients, per_client, Duration::ZERO);
    let stats = server.run(rx)?;
    let responses = clients.join().expect("clients");

    println!("--- serving stats ---");
    println!(
        "requests:    {} over {} batches ({:.1} avg batch)",
        stats.served,
        stats.batches,
        stats.served as f64 / stats.batches.max(1) as f64
    );
    println!(
        "throughput:  {:.0} req/s",
        stats.served as f64 / stats.wall_secs
    );
    println!(
        "latency ms:  p50 {:.2}  p95 {:.2}  p99 {:.2}  max {:.2}",
        stats.latency.p50 * 1e3,
        stats.latency.p95 * 1e3,
        stats.latency.p99 * 1e3,
        stats.latency.max * 1e3
    );
    println!("exec/batch:  {:.2} ms", stats.exec_time.mean * 1e3);
    println!("decode:      {:.3} s for {} blocks", stats.decode_secs, result.mrc.b);
    let agree = responses
        .iter()
        .filter(|r| r.prediction().map(|p| p.pred < 4).unwrap_or(false))
        .count();
    assert_eq!(agree, responses.len());
    Ok(())
}
