//! Quickstart: compress a small model with MIRACLE end to end.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Trains a variational MLP on a synthetic 4-class task, runs Algorithm 2
//! (block-wise minimal random coding), writes the `.mrc`, decodes it back
//! and reports size + test error.

use miracle::coordinator::{self, MiracleCfg};
use miracle::data;
use miracle::metrics::fmt_size;
use miracle::runtime::{self, Runtime};
use miracle::util::Result;

fn main() -> Result<()> {
    // 1. runtime backend (pure-Rust native by default — nothing to build)
    let rt = Runtime::cpu()?;
    let arts = runtime::load(&rt, "tiny_mlp")?;

    // 2. synthetic benchmark data (train/test disjoint by seed)
    let train = data::synth_protos(512, 16, 4, 1234);
    let test = data::synth_protos(512, 16, 4, 1234 ^ 0x7E57);

    // 3. MIRACLE hyper-parameters: a 10-bit-per-block coding goal
    let cfg = MiracleCfg {
        c_loc_bits: 10,
        i0: 1500,
        i_intermediate: 2,
        lr: 5e-3,
        beta0: 1e-3,
        eps_beta: 0.02,
        data_scale: train.len() as f32,
        ..Default::default()
    };

    // 4. compress = train + encode (Algorithm 2)
    let result = coordinator::compress(&arts, &train, &test, &cfg)?;
    let n = arts.meta.n_total;

    println!("--- MIRACLE quickstart ---");
    println!("weights:     {n}");
    println!("uncompressed {}", fmt_size(n as f64 * 4.0));
    println!(
        "compressed   {} ({:.0}x)",
        fmt_size(result.total_bits as f64 / 8.0),
        (n * 32) as f64 / result.total_bits as f64
    );
    println!("test error   {:.2}%", result.test_error * 100.0);
    println!(
        "block KL     {:.2} bits (goal {})",
        result.mean_block_kl_bits, cfg.c_loc_bits
    );

    // 5. the .mrc round-trips: decode is pure shared-randomness replay
    let path = std::env::temp_dir().join("quickstart.mrc");
    result.mrc.save(path.to_str().unwrap())?;
    let loaded = miracle::codec::MrcFile::load(path.to_str().unwrap())?;
    let w = coordinator::decode_model(&arts, &loaded)?;
    let layout = miracle::model::Layout::generate(&arts.meta, loaded.layout_seed);
    let err = coordinator::eval_error(&arts, &layout.assemble_map, &w, &test)?;
    assert_eq!(
        err, result.test_error,
        "decode must reproduce the encoder's weights"
    );
    println!("round-trip OK: decoded model scores identically");
    Ok(())
}
