//! API stub of the PJRT-backed `xla` crate.
//!
//! The `miracle` crate's optional `xla` feature compiles the PJRT runtime
//! backend against this exact surface. The stub exists so that
//! `cargo build --features xla` (and `cargo doc`, CI, clippy) succeed on
//! machines without a PJRT toolchain; every constructor that would touch a
//! real device returns [`Error`] at runtime. To actually execute AOT HLO
//! artifacts, replace this package with a real PJRT binding via a
//! `[patch]` entry (see `docs/adr/001-backend-abstraction.md`).

use std::fmt;

/// Error type mirroring the real binding's.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn stub(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: the `xla` package linked into this build is a \
                 compile-time stub; patch in a real PJRT-backed crate to \
                 execute HLO artifacts"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side array shape.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of a literal: array or tuple.
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Host literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    pub fn shape(&self) -> Result<Shape> {
        Err(Error::stub("Literal::shape"))
    }
}

/// A device owned by a [`PjRtClient`].
#[derive(Debug, Clone)]
pub struct PjRtDevice {
    _private: (),
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle. The stub's `cpu()` always fails, so no other stubbed
/// method is reachable through the miracle runtime.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}
